"""CI perf-regression gate for the tracked speedup benchmarks.

Compares the freshly produced ``bench_results.json`` against the committed
``bench_baseline.json`` and exits non-zero when a tracked metric regresses
more than ``--tolerance`` (default 20%).  The tracked metrics are wall-clock
*ratios* (scalar / batched on the same machine), so they transfer across
runner hardware far better than absolute microseconds.

Usage:
    python -m benchmarks.check_regression              # gate (CI)
    python -m benchmarks.check_regression --refresh    # rewrite the baseline
                                                       # from current results

Refreshing the baseline is the intended workflow after a change that
legitimately shifts a tracked metric — run the smoke benchmarks locally,
eyeball the numbers, then commit the refreshed file (see ROADMAP.md, CI
section).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")

# (benchmark key in bench_results.json, metric key) — all tracked metrics
# are higher-is-better ratios; current < baseline*(1-tol) fails.
# multi_tenant/speedup is the coordinated-vs-static-partitioning ratio and
# tail_latency/speedup the sync-vs-async p99 ratio (both simulated us,
# deterministic — see paper_tables.multi_tenant / paper_tables.tail_latency).
# The workload-suite keys (benchmarks/workloads.py) are likewise
# deterministic simulated metrics: ycsb_a/hit_ratio is the sync local hit
# ratio under hotset rotation, ml_trace/speedup the sync/async simulated
# wall-clock ratio on the activation-cycling trace, and
# mixed_tenant_workload/fairness Jain's index over per-tenant
# coordinated-vs-static speedups.  serve_qps/tokens_per_s is the
# zero-restore vs bulk-restore serving speedup (sim-time ratio on identical
# request streams, geomean across archs — benchmarks/serve_qps.py); it
# regresses if bulk KV scatters creep back into the restore path.
# fault_recovery/durability is recovered/(recovered+lost) for a replica-
# covered single-peer crash (1.0 when the recovery sweep finds every
# replica) and fault_recovery/degraded_throughput the SUSPECT-phase us/op
# ratio against the healthy baseline (the retry/backoff degradation bound)
# — both from the seeded sync schedule in benchmarks/fault_recovery.py.
# cluster_tenant/replica_availability is recovered/(recovered+lost) for a
# whole-rack correlated crash under strictly cross-domain replica
# placement (must be 1.0 — the bench also hard-asserts it) and
# cluster_tenant/fairness Jain's index over the full-run survivor
# containers' throughput under host churn (benchmarks/cluster_tenant.py).
TRACKED = [
    ("batch_speedup", "speedup"),
    ("pressure_speedup", "speedup"),
    ("reclaim_speedup", "speedup"),
    ("reclaim_floor", "speedup"),
    ("multi_tenant", "speedup"),
    ("tail_latency", "speedup"),
    ("ycsb_a", "hit_ratio"),
    ("ml_trace", "speedup"),
    ("mixed_tenant_workload", "fairness"),
    ("serve_qps", "tokens_per_s"),
    ("fault_recovery", "durability"),
    ("fault_recovery", "degraded_throughput"),
    ("cluster_tenant", "replica_availability"),
    ("cluster_tenant", "fairness"),
]


def load_json(path: str, what: str):
    """Load a JSON file with a clear diagnostic instead of a traceback."""
    if not os.path.exists(path):
        print(f"FAIL: {what} file not found: {path} "
              f"(run `python -m benchmarks.run --only "
              f"{','.join(b for b, _ in TRACKED)}` first)")
        return None
    try:
        with open(path) as f:
            obj = json.load(f)
    except ValueError as e:
        print(f"FAIL: {what} file {path} is not valid JSON: {e}")
        return None
    if not isinstance(obj, dict):
        print(f"FAIL: {what} file {path} must hold a JSON object, "
              f"got {type(obj).__name__}")
        return None
    return obj


def lookup(results: dict, bench: str, metric: str):
    """Fetch results[bench][metric] tolerating absent/malformed entries.

    Non-numeric values count as missing (a string or list here must FAIL
    with the clear message, not crash float()/format, and must never be
    written into a refreshed baseline)."""
    entry = results.get(bench)
    if not isinstance(entry, dict):
        return None
    val = entry.get(metric)
    if isinstance(val, bool) or not isinstance(val, (int, float)):
        return None
    return val


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(ART,
                                                      "bench_results.json"))
    ap.add_argument("--baseline", default=os.path.join(ART,
                                                       "bench_baseline.json"))
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional regression (0.2 = 20%%)")
    ap.add_argument("--refresh", action="store_true",
                    help="write the baseline from current results and exit")
    args = ap.parse_args()

    results = load_json(args.results, "results")
    if results is None:
        return 2

    if args.refresh:
        # refuse a partial refresh: a baseline written from incomplete
        # results would silently drop gates for the missing benchmarks
        baseline = {}
        missing = []
        for bench, metric in TRACKED:
            val = lookup(results, bench, metric)
            if val is None:
                missing.append(f"{bench}/{metric}")
                continue
            baseline.setdefault(bench, {})[metric] = val
        if missing:
            print(f"refresh REFUSED: {', '.join(missing)} missing from "
                  f"{args.results} (run `python -m benchmarks.run --only "
                  f"{','.join(b for b, _ in TRACKED)}` first)")
            return 2
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"baseline refreshed -> {args.baseline}")
        return 0

    baseline = load_json(args.baseline, "baseline")
    if baseline is None:
        return 2

    lines = ["| benchmark | metric | baseline | current | floor | status |",
             "|---|---|---|---|---|---|"]
    failed = False
    for bench, metric in TRACKED:
        base = lookup(baseline, bench, metric)
        if base is None:
            print(f"warning: {bench}/{metric} not in baseline — skipped "
                  f"(refresh the baseline to start gating it)")
            continue
        cur = lookup(results, bench, metric)
        if cur is None:
            print(f"FAIL: {bench}/{metric} missing from results "
                  f"(benchmark did not run?)")
            failed = True
            lines.append(f"| {bench} | {metric} | {base:.2f} | MISSING | "
                         f"- | ❌ |")
            continue
        cur = float(cur)
        floor = base * (1.0 - args.tolerance)
        ok = cur >= floor
        status = "✅" if ok else "❌"
        lines.append(f"| {bench} | {metric} | {base:.2f} | {cur:.2f} | "
                     f"{floor:.2f} | {status} |")
        print(f"{bench}/{metric}: current={cur:.2f} baseline={base:.2f} "
              f"floor={floor:.2f} -> {'OK' if ok else 'REGRESSION'}")
        if not ok:
            failed = True

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Benchmark regression gate\n\n")
            f.write("\n".join(lines) + "\n")

    if failed:
        print(f"benchmark regression gate FAILED "
              f"(tolerance {args.tolerance:.0%}); if the shift is expected, "
              f"refresh the baseline: python -m benchmarks.check_regression "
              f"--refresh")
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
