"""CI perf-regression gate for the tracked speedup benchmarks.

Compares the freshly produced ``bench_results.json`` against the committed
``bench_baseline.json`` and exits non-zero when a tracked metric regresses
more than ``--tolerance`` (default 20%).  The tracked metrics are wall-clock
*ratios* (scalar / batched on the same machine), so they transfer across
runner hardware far better than absolute microseconds.

Usage:
    python -m benchmarks.check_regression              # gate (CI)
    python -m benchmarks.check_regression --refresh    # rewrite the baseline
                                                       # from current results

Refreshing the baseline is the intended workflow after a change that
legitimately shifts a tracked metric — run the smoke benchmarks locally,
eyeball the numbers, then commit the refreshed file (see ROADMAP.md, CI
section).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")

# (benchmark key in bench_results.json, metric key) — all tracked metrics
# are higher-is-better speedup ratios; current < baseline*(1-tol) fails
TRACKED = [
    ("batch_speedup", "speedup"),
    ("reclaim_speedup", "speedup"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(ART,
                                                      "bench_results.json"))
    ap.add_argument("--baseline", default=os.path.join(ART,
                                                       "bench_baseline.json"))
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional regression (0.2 = 20%%)")
    ap.add_argument("--refresh", action="store_true",
                    help="write the baseline from current results and exit")
    args = ap.parse_args()

    with open(args.results) as f:
        results = json.load(f)

    if args.refresh:
        baseline = {}
        for bench, metric in TRACKED:
            if bench not in results:
                print(f"refresh: {bench} missing from results "
                      f"(run `python -m benchmarks.run --only "
                      f"{','.join(b for b, _ in TRACKED)}` first)")
                return 2
            baseline.setdefault(bench, {})[metric] = results[bench][metric]
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"baseline refreshed -> {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)

    lines = ["| benchmark | metric | baseline | current | floor | status |",
             "|---|---|---|---|---|---|"]
    failed = False
    for bench, metric in TRACKED:
        base = baseline.get(bench, {}).get(metric)
        if base is None:
            print(f"warning: {bench}/{metric} not in baseline — skipped")
            continue
        if bench not in results or metric not in results[bench]:
            print(f"FAIL: {bench}/{metric} missing from results "
                  f"(benchmark did not run?)")
            failed = True
            lines.append(f"| {bench} | {metric} | {base:.2f} | MISSING | "
                         f"- | ❌ |")
            continue
        cur = float(results[bench][metric])
        floor = base * (1.0 - args.tolerance)
        ok = cur >= floor
        status = "✅" if ok else "❌"
        lines.append(f"| {bench} | {metric} | {base:.2f} | {cur:.2f} | "
                     f"{floor:.2f} | {status} |")
        print(f"{bench}/{metric}: current={cur:.2f} baseline={base:.2f} "
              f"floor={floor:.2f} -> {'OK' if ok else 'REGRESSION'}")
        if not ok:
            failed = True

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Benchmark regression gate\n\n")
            f.write("\n".join(lines) + "\n")

    if failed:
        print(f"benchmark regression gate FAILED "
              f"(tolerance {args.tolerance:.0%}); if the shift is expected, "
              f"refresh the baseline: python -m benchmarks.check_regression "
              f"--refresh")
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
