"""``bench: cluster_tenant`` — seeded multi-host churn over a cluster pool.

Three hosts (two containers each) register with one ``ClusterCoordinator``;
every container sees the same seeded heterogeneous peer set — 8 remote
peers striped over 2 failure domains (racks) via ``draw_peer_profiles``,
with per-peer extra latency so the scalar per-op pricing path runs.  One
seeded trace per container is driven round-robin in event-aligned segments
while the canonical churn schedule fires:

  ~40%  rack crash       — every domain-1 peer drops on every live store.
        Replica placement is strictly cross-domain, so the crash must lose
        nothing: ``replica_availability`` (gated ``== 1.0``) is
        recovered / (recovered + lost) summed over every store's crash
        log.  With the far rack dead, re-replication has nowhere legal to
        go — repair backlogs grow, the hosts report degraded, and the
        cluster sheds their slab admission to floor.
  ~50%  host failure     — one host dies; ``fail_host`` reclaims its whole
        slab and opens a recovery-storm window (staggered-backoff grants).
  ~65%  host rejoin      — the host comes back empty with a fresh
        coordinator and fresh containers (their dead-rack peers are failed
        at birth), opening a second storm window.
  ~70%  rack rejoin      — every dead peer rejoins on every live store;
        the REJOINING warm-up ramp phases them back into placement while
        background repair drains the accumulated backlog cross-host.

``fairness`` (gated ``>= 0.9``) is Jain's index over the full-run
survivors' per-container throughput (ops per simulated us): churn on one
host must not starve the containers on the others.  The run ends with a
drain + repair barrier and ``ClusterInvariantChecker
.check_recovery_converged()`` — cluster slab conservation, every DOWN
slab reclaimed, per-store invariants including the cross-domain replica
law, and full replication restored on every surviving store.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import drive_arrays, emit
from benchmarks.paper_tables import _config, _populate
from benchmarks.workloads import _jain
from repro.core import (ClusterCoordinator, ClusterInvariantChecker,
                        FaultInjector, TieredPageStore, cluster_schedule,
                        domain_recovery_storm, draw_peer_profiles,
                        peers_in_domain)

N_OPS = 12_000
N_PAGES = 1024
N_HOSTS = 3
CONTAINERS_PER_HOST = 2
N_PEERS = 8
N_DOMAINS = 2
POOL = 256                      # per-container pool ceiling (pages)
MIN_POOL = 64                   # per-container floor
BLOCKS = 1024                   # base peer capacity (profiles scatter it)
MIN_SLAB = 160                  # per-host floor: 2 container floors + slack,
                                # small enough that growth must lease slab
                                # (so the rejoin storm actually gates calls)
MAX_SLAB = 1024                 # per-host slab lease cap
CLUSTER_PAGES = 4096            # cluster-wide pool
SEED = 17
LATENCY_SCALE_US = 2.0          # heterogeneous per-peer extra read latency

RACK_CRASH = 2 * N_OPS // 5
HOST_FAIL = N_OPS // 2
HOST_REJOIN = 13 * N_OPS // 20
RACK_REJOIN = 7 * N_OPS // 10


def _trace(seed: int, n_ops: int):
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, N_PAGES, size=n_ops, dtype=np.int64)
    is_write = rng.random(n_ops) < 0.3
    return pages, is_write


def cluster_tenant(rows):
    """``bench: cluster_tenant`` — gated replica availability + fairness."""
    profiles = draw_peer_profiles(N_PEERS, N_DOMAINS, seed=SEED,
                                  base_capacity_blocks=BLOCKS,
                                  latency_scale_us=LATENCY_SCALE_US)
    domains = [p.domain for p in profiles]
    rack = max(domains)
    rack_peers = peers_in_domain(domains, rack)

    cluster = ClusterCoordinator(CLUSTER_PAGES)
    stores_by_host = {}
    containers = []

    def _mk_store(coord, name, seed):
        return TieredPageStore.from_config(
            _config("valet", pool=POOL, min_pool=MIN_POOL, peers=N_PEERS,
                    blocks=BLOCKS, seed=seed, coordinator=coord,
                    container_name=name, peer_profiles=profiles))

    def _add_container(coord, hid, name, seed, start, events, pre_fail=()):
        st = _mk_store(coord, name, seed)
        for p in pre_fail:             # born into a cluster with a dead rack
            st.fail_peer(p)
        _populate(st, N_PAGES)
        st.drain()
        containers.append({
            "name": name, "hid": hid, "store": st, "start": start,
            "trace": _trace(seed, N_OPS - start), "alive": True,
            "sim_us": 0.0, "ops": 0,
            "inj": FaultInjector(st, events, ops=start),
        })
        stores_by_host[hid].append(st)

    for h in range(N_HOSTS):
        coord = cluster.register_host(min_slab=MIN_SLAB, max_slab=MAX_SLAB,
                                      name=f"host{h}")
        hid = coord.host_id
        stores_by_host[hid] = []
        for c in range(CONTAINERS_PER_HOST):
            _add_container(coord, hid, f"h{h}c{c}", SEED + 10 * h + c,
                           0, cluster_schedule(N_OPS, domains,
                                               crash_domain=rack))
    fail_hid = max(stores_by_host)     # the last host is the churn victim

    cuts = sorted({0, RACK_CRASH, HOST_FAIL, HOST_REJOIN, RACK_REJOIN,
                   N_OPS})
    for a, b in zip(cuts, cuts[1:]):
        for cont in containers:
            if not cont["alive"]:
                continue
            st = cont["store"]
            lo, hi = a - cont["start"], b - cont["start"]
            pages, is_write = cont["trace"]
            t0 = st.stats.time_us
            drive_arrays(st, pages[lo:hi], is_write[lo:hi],
                         tick_every=256, batch=256)
            cont["sim_us"] += st.stats.time_us - t0
            cont["ops"] += hi - lo
            cont["inj"].advance(b - a)
        if b == HOST_FAIL:
            cluster.fail_host(fail_hid)
            for cont in containers:
                if cont["hid"] == fail_hid:
                    cont["alive"] = False
        elif b == HOST_REJOIN:
            coord = cluster.rejoin_host(fail_hid)
            stores_by_host[fail_hid] = []
            for c in range(CONTAINERS_PER_HOST):
                # rack is still dead when the host comes back: its fresh
                # containers fail those peers at birth and rejoin them via
                # their own (already-partly-elapsed) schedule
                _add_container(
                    coord, fail_hid, f"h{fail_hid}r{c}",
                    SEED + 100 + c, HOST_REJOIN,
                    domain_recovery_storm(domains, rack, RACK_REJOIN),
                    pre_fail=rack_peers)

    live = [c for c in containers if c["alive"]]
    for cont in live:
        cont["store"].drain()
        cont["store"].repair_quiesce()
    ClusterInvariantChecker(cluster, stores_by_host) \
        .check_recovery_converged()

    # gated: the rack crash must lose nothing (strict cross-domain replicas)
    crashes = [(op, peer, res) for c in containers
               for (op, kind, peer, res) in c["inj"].log if kind == "crash"]
    recovered = sum(r[2][0] for r in crashes)
    lost = sum(r[2][1] for r in crashes)
    availability = recovered / max(recovered + lost, 1)
    assert lost == 0, f"rack crash lost {lost} replicated pages"

    # gated: churn on one host must not starve the survivors on the others
    survivors = [c for c in live if c["start"] == 0]
    tputs = [c["ops"] / max(c["sim_us"], 1e-9) for c in survivors]
    fairness = _jain(tputs)
    assert fairness >= 0.9, f"survivor fairness collapsed: {fairness:.3f}"

    cs = cluster.stats
    total_ops = sum(c["ops"] for c in containers)
    total_us = sum(c["sim_us"] for c in containers)
    art = {
        "replica_availability": availability,       # gated == 1.0
        "fairness": fairness,                       # gated >= 0.9
        "recovered": recovered, "lost": lost,
        "us_per_op": total_us / max(total_ops, 1),
        "survivor_tputs": tputs,
        "containers": {c["name"]: {"ops": c["ops"],
                                   "sim_us": c["sim_us"],
                                   "alive": c["alive"]}
                       for c in containers},
        "cluster": {
            "n_storms": cs.n_storms,
            "n_storm_denials": cs.n_storm_denials,
            "storm_wait_us": cs.storm_wait_us,
            "n_slab_lease_calls": cs.n_slab_lease_calls,
            "pages_slab_leased": cs.pages_slab_leased,
            "n_degraded_reports": cs.n_degraded_reports,
            "n_degraded_clears": cs.n_degraded_clears,
            "n_host_failures": cs.n_host_failures,
            "n_host_rejoins": cs.n_host_rejoins,
        },
    }
    emit(rows, "cluster_tenant/cluster", art["us_per_op"],
         replica_availability=round(availability, 4),
         fairness=round(fairness, 4),
         storms=cs.n_storms, storm_denials=cs.n_storm_denials)
    return art
