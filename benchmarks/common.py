"""Shared helpers for the benchmark harness."""
import json
import os
import time

import numpy as np

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
os.makedirs(ART, exist_ok=True)


def emit(rows, name, us_per_call, **derived):
    """Append one CSV row: name,us_per_call,derived."""
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    rows.append(f"{name},{us_per_call:.3f},{d}")
    return rows


def save_json(name, obj):
    """Merge-update the artifact JSON: a partial run (``--only fig23``)
    refreshes only the benchmarks it ran instead of clobbering the rest
    (the regression gate reads tracked entries from this file)."""
    path = os.path.join(ART, f"{name}.json")
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(obj)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, default=float)
    return path


def latency_summary(stats):
    """Percentile summary of a run via the ``Stats``/``EngineStats`` latency
    accessors (the bounded deterministic reservoir — see
    ``repro.core.reservoir``).  Keys: count, p50_us, p90_us, p99_us,
    p999_us, max_us."""
    out = stats.lat.summary()
    out["p50_us"] = stats.latency_p50()
    out["p99_us"] = stats.latency_p99()
    out["p999_us"] = stats.latency_p999()
    return out


def drive_arrays(store, pages, is_write, tick_every=32, batch=256):
    """Drive (pages, is_write) arrays through ``access_batch`` in chunks.

    Chunk boundaries land exactly where the scalar loop ran its
    ``background_tick`` (after every op index divisible by ``tick_every``),
    so the result is bitwise identical to the old per-op loop — just much
    faster.  Returns the per-op critical-path latency array."""
    pages = np.ascontiguousarray(pages, np.int64)
    is_write = np.ascontiguousarray(is_write, bool)
    n = len(pages)
    lats = np.empty(n, np.float64)
    i = 0
    while i < n:
        nxt = i if i % tick_every == 0 else (i // tick_every + 1) * tick_every
        end = min(n, i + batch, nxt + 1)
        lats[i:end] = store.access_batch(pages[i:end], is_write[i:end])
        if (end - 1) % tick_every == 0:
            store.background_tick()
        i = end
    store.background_tick()
    return lats


def timeit(fn, *args, n=20, warmup=3):
    """Median wall time of a jitted call in us."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
