"""``bench: fault_recovery`` — seeded fault schedule, recovery + degradation.

Replays one seeded trace against a replication-1 Valet store while the
``FaultInjector`` fires the canonical four-phase ``standard_schedule``
(paper §5.1/§5.3, Table 3):

  phase 1  transient blip   — one peer turns SUSPECT: every access to it
           pays the retry/backoff ladder, placement routes around it, and
           the phase's us/op against the healthy baseline is the
           ``degraded_throughput`` ratio (gated; higher is better, < 1).
  phase 2  permanent crash  — one peer drops; the batched recovery sweep
           repoints every page to its replica.  ``durability`` (gated) is
           recovered / (recovered + lost) for this crash — with one
           replica per block and no prior failure it must be exactly 1.0.
  phase 3  correlated crash — two peers die at once (rack failure); pages
           whose primary and only replica shared the pair are genuinely
           lost.  Reported (``durability_correlated``), not gated.
  phase 4  recovery storm   — all three dead peers rejoin; background
           repair re-replicates onto them.  After a drain barrier the run
           asserts ``check_replication_restored()`` plus the full
           ``InvariantChecker`` — recovery must end *complete*, not
           merely quiet.

The schedule runs against the sync store and the async engine (events land
between driven chunks, i.e. mid-epoch for async); the gated keys come from
the sync run, whose numbers are deterministic simulated microseconds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import drive_arrays, emit
from benchmarks.paper_tables import _config, _populate
from repro.core import (FaultInjector, InvariantChecker, TieredPageStore,
                        standard_schedule)

N_OPS = 30_000
N_PAGES = 2048
POOL = 256
PEERS = 6
BLOCKS = 1024
SEED = 11
# the blip phase must stay SUSPECT for its full scheduled window — the
# escalation timeout is exercised by unit tests, not the benchmark
NO_TIMEOUT_US = 1e15


def _trace(seed: int):
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, N_PAGES, size=N_OPS, dtype=np.int64)
    is_write = rng.random(N_OPS) < 0.3
    return pages, is_write


def _run_schedule(async_mode: bool):
    """Drive the trace in event-aligned segments; return phase metrics."""
    st = TieredPageStore.from_config(
        _config("valet", pool=POOL, min_pool=POOL, peers=PEERS,
                blocks=BLOCKS, seed=SEED, async_mode=async_mode,
                suspect_timeout_us=NO_TIMEOUT_US))
    _populate(st, N_PAGES)
    st.drain()
    pages, is_write = _trace(SEED)
    events = standard_schedule(N_OPS, blip_peer=0, crash_peer=1,
                               correlated_peers=(2, 3))
    inj = FaultInjector(st, events)
    cuts = sorted({0, N_OPS, *(e.at_op for e in events)})
    seg_us = {}
    s = st.stats
    for a, b in zip(cuts, cuts[1:]):
        t0 = s.time_us
        drive_arrays(st, pages[a:b], is_write[a:b], tick_every=256,
                     batch=256)
        seg_us[a] = (s.time_us - t0) / max(b - a, 1)
        inj.advance(b - a)
    st.drain()
    st.repair_quiesce()
    chk = InvariantChecker(st)
    chk.check()
    chk.check_replication_restored()

    blip_at = events[0].at_op
    heal_at = events[1].at_op
    crashes = [(op, peer, res) for (op, kind, peer, res) in inj.log
               if kind == "crash"]
    single = crashes[0]
    rec, lost = single[2]
    corr_rec = sum(r[2][0] for r in crashes[1:])
    corr_lost = sum(r[2][1] for r in crashes[1:])
    return {
        "healthy_us_per_op": seg_us[0],
        "degraded_us_per_op": seg_us[blip_at],
        "degraded_throughput": seg_us[0] / max(seg_us[blip_at], 1e-12),
        "recovered": rec, "lost": lost,
        "durability": rec / max(rec + lost, 1),
        "correlated_recovered": corr_rec, "correlated_lost": corr_lost,
        "durability_correlated": corr_rec / max(corr_rec + corr_lost, 1),
        "repair_pages": s.repair_pages, "repair_us": s.repair_us,
        "retries": s.retries, "retry_wait_us": s.retry_wait_us,
        "repair_backlog": len(st.repairq),
        "health_transitions": len(st.health.transitions),
        "events_fired": len(inj.log),
        "post_heal_us_per_op": seg_us[heal_at],
    }


def fault_recovery(rows):
    """``bench: fault_recovery`` — gated durability + degraded throughput."""
    sync = _run_schedule(async_mode=False)
    asy = _run_schedule(async_mode=True)
    art = {
        # gated: replica-covered crash loses nothing
        "durability": sync["durability"],
        # gated: retry/backoff degrades, it must not collapse
        "degraded_throughput": sync["degraded_throughput"],
        "sync": sync, "async": asy,
    }
    emit(rows, "fault_recovery/sync", sync["degraded_us_per_op"],
         durability=round(sync["durability"], 4),
         degraded_throughput=round(sync["degraded_throughput"], 4),
         repair_pages=sync["repair_pages"])
    emit(rows, "fault_recovery/async", asy["degraded_us_per_op"],
         durability=round(asy["durability"], 4),
         degraded_throughput=round(asy["degraded_throughput"], 4),
         repair_pages=asy["repair_pages"])
    return art
