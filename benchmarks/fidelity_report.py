"""Paper-fidelity report: measured reproduction vs the paper's published
numbers, plus the SLO-grade workload matrix — rendered as markdown.

``python -m benchmarks.fidelity_report`` reads ``bench_results.json``
(merge-updated by ``benchmarks.run``), writes
``benchmarks/artifacts/fidelity_report.md``, prints it, and appends it to
``$GITHUB_STEP_SUMMARY`` when CI sets it — so "does the reproduction still
match the paper?" is answered on every push, as an artifact, not a one-off
claim.

Three sections:

* **Paper comparisons** — the paper's headline ratios (226x throughput /
  98% latency cut over OS swap; 5.5x / 78.4% over remote paging; the §3.4
  pooling and async-tail claims) against what ``paper_tables.py`` measured
  this run.  Our simulator reproduces the *mechanisms*, not the absolute
  hardware numbers, so the table reports both values side by side with the
  direction check (does the reproduction preserve the paper's ordering?).
* **Workload matrix** — per workload class (YCSB A-D, ML trace, mixed
  tenants): hit ratio, p50/p99/p999 simulated latency, throughput per GB
  of slab, and Jain fairness for the mixed-tenant case.
* **Serving (zero-restore)** — the ``serve_qps`` continuous-batching
  bench: per arch and restore mode, sim-time throughput,
  admission-to-first-token p50/p99/p999, the repoint/stream restore
  split, and the daemon fence-wait histogram (count/p50/p99).

Missing benches render as ``—`` (a smoke run only refreshes a subset).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def _get(results, *path):
    """Walk nested dicts (string keys; int keys retried as str)."""
    cur = results
    for p in path:
        if not isinstance(cur, dict):
            return None
        cur = cur.get(p, cur.get(str(p)))
        if cur is None:
            return None
    return cur


def _ratio(num, den):
    if num is None or den is None or not den:
        return None
    return num / den


def _cut(num, den):
    """Latency cut in percent: 1 - num/den."""
    r = _ratio(num, den)
    return None if r is None else (1.0 - r) * 100.0


def _fmt(v, spec="{:.2f}"):
    return "—" if v is None else spec.format(v)


def paper_rows(results):
    """(claim, paper value, measured value, unit, direction-held) rows.

    Measured analogues come from the trace benches on the paper's cost
    profile: fig10's RemoteOnly column is the paper's fully-oversubscribed
    regime (throughput ratio = inverse latency ratio on fixed op counts),
    ``tail_latency`` is the Remote-Sender-Thread async claim, and
    ``multi_tenant`` the §3.4 cross-container pooling claim.
    """
    v_lat = _get(results, "fig10", "valet", "RemoteOnly")
    os_lat = _get(results, "fig10", "os-swap", "RemoteOnly")
    is_lat = _get(results, "fig10", "infiniswap", "RemoteOnly")
    nb_lat = _get(results, "fig10", "nbdx", "RemoteOnly")
    remote_best = None
    if is_lat is not None or nb_lat is not None:
        remote_best = min(x for x in (is_lat, nb_lat) if x is not None)

    rows = []

    def claim(label, paper, measured, unit, better="higher"):
        held = None
        if measured is not None:
            held = measured > (1.0 if unit == "x" else 0.0)
        rows.append((label, paper, measured, unit, held))

    claim("Throughput vs OS swap (RemoteOnly)", "up to 226x",
          _ratio(os_lat, v_lat), "x")
    claim("Latency cut vs OS swap (RemoteOnly)", "up to 98%",
          _cut(v_lat, os_lat), "%")
    claim("Throughput vs remote paging (RemoteOnly)", "up to 5.5x",
          _ratio(remote_best, v_lat), "x")
    claim("Latency cut vs remote paging (RemoteOnly)", "up to 78.4%",
          _cut(v_lat, remote_best), "%")
    claim("Cross-container pooling vs static split (§3.4)", "> 1x",
          _get(results, "multi_tenant", "speedup"), "x")
    claim("Async orchestration p99 cut (Remote Sender Thread)", "tail ↓",
          _cut(_get(results, "tail_latency", "async_p99_us"),
               _get(results, "tail_latency", "sync_p99_us")), "%")
    return rows


def workload_rows(results):
    """(workload, hit ratio, p50, p99, p999, thr/GB, fairness) rows."""
    rows = []
    for name in ("ycsb_a", "ycsb_b", "ycsb_c", "ycsb_d", "ml_trace"):
        sync = _get(results, name, "sync")
        if sync is None:
            rows.append((name, None, None, None, None, None, None))
            continue
        rows.append((name, sync.get("hit_local"), sync.get("p50_us"),
                     sync.get("p99_us"), sync.get("p999_us"),
                     sync.get("throughput_per_gb"), None))
    mt = results.get("mixed_tenant_workload")
    if isinstance(mt, dict):
        for ten in mt.get("coordinated", []):
            rows.append((f"mixed/{ten['tenant']}", ten.get("hit_local"),
                         ten.get("p50_us"), ten.get("p99_us"),
                         ten.get("p999_us"), None, None))
        rows.append(("mixed (aggregate)", None, None, None, None,
                     mt.get("throughput_per_gb"), mt.get("fairness")))
    else:
        rows.append(("mixed_tenant_workload", None, None, None, None,
                     None, None))
    return rows


def serving_rows(results):
    """(arch/mode, tok/s, attft p50/p99/p999, repointed, streamed,
    fences, fence p50, fence p99) rows from the serve_qps bench."""
    sq = results.get("serve_qps")
    if not isinstance(sq, dict):
        return None, []
    rows = []
    for arch, entry in sq.items():
        if not isinstance(entry, dict) or "zero" not in entry:
            continue
        for mode in ("zero", "bulk"):
            r = entry.get(mode)
            if not isinstance(r, dict):
                continue
            f = r.get("fences") or {}
            rows.append((f"{arch}/{mode}", r.get("tok_s_sim"),
                         r.get("attft_p50_us"), r.get("attft_p99_us"),
                         r.get("attft_p999_us"), r.get("repointed"),
                         r.get("streamed"), f.get("count"),
                         f.get("p50_us"), f.get("p99_us")))
    return sq.get("tokens_per_s"), rows


def render(results) -> str:
    out = ["# Paper-fidelity report", ""]
    out += ["## Paper comparisons (measured this run vs published)", "",
            "| claim | paper | measured | direction held |",
            "|---|---|---|---|"]
    for label, paper, measured, unit, held in paper_rows(results):
        m = _fmt(measured, "{:.1f}" + ("x" if unit == "x" else "%"))
        h = "—" if held is None else ("✅" if held else "❌")
        out.append(f"| {label} | {paper} | {m} | {h} |")
    out += ["",
            "The simulator reproduces the paper's *mechanisms* on its cost",
            "profile (Table 1), not the absolute hardware numbers — the",
            "check is that every published ordering survives: Valet beats",
            "OS swap by orders of magnitude, beats remote paging, pooling",
            "beats static partitioning, and the async engine cuts the",
            "tail.", ""]
    out += ["## Workload matrix (SLO-grade, deterministic simulated us)",
            "",
            "| workload | hit ratio (local) | p50 us | p99 us | p999 us "
            "| ops/s/GB | Jain fairness |",
            "|---|---|---|---|---|---|---|"]
    for name, hit, p50, p99, p999, thr, fair in workload_rows(results):
        out.append("| {} | {} | {} | {} | {} | {} | {} |".format(
            name, _fmt(hit, "{:.4f}"), _fmt(p50), _fmt(p99), _fmt(p999),
            _fmt(thr, "{:,.0f}"), _fmt(fair, "{:.3f}")))
    speedup, srows = serving_rows(results)
    out += ["", "## Serving (zero-restore vs bulk restore, `serve_qps`)",
            ""]
    if srows:
        out += ["| arch/mode | tok/s (sim) | attft p50 us | attft p99 us "
                "| attft p999 us | repointed | streamed | fences "
                "| fence p50 us | fence p99 us |",
                "|---|---|---|---|---|---|---|---|---|---|"]
        for (name, tok_s, p50, p99, p999, rp, st, fc, fp50,
             fp99) in srows:
            out.append(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |"
                .format(name, _fmt(tok_s, "{:,.0f}"), _fmt(p50, "{:.1f}"),
                        _fmt(p99, "{:.1f}"), _fmt(p999, "{:.1f}"),
                        _fmt(rp, "{:d}"), _fmt(st, "{:d}"),
                        _fmt(fc, "{:d}"), _fmt(fp50, "{:.1f}"),
                        _fmt(fp99, "{:.1f}")))
        out += ["",
                f"Zero-restore throughput speedup (gated, geomean): "
                f"**{_fmt(speedup, '{:.3f}x')}** — restores that repoint "
                "cost nothing; only reused slots stream a page back.", ""]
    else:
        out += ["— (`serve_qps` not in this run)", ""]
    out += ["",
            "Async-mode deltas and per-tenant static-vs-coordinated",
            "breakdowns live in `bench_results.json` (uploaded as a CI",
            "artifact every run).", ""]
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results",
                    default=os.path.join(ART, "bench_results.json"))
    ap.add_argument("--out",
                    default=os.path.join(ART, "fidelity_report.md"))
    args = ap.parse_args()

    if not os.path.exists(args.results):
        print(f"FAIL: results file not found: {args.results} "
              f"(run `python -m benchmarks.run` first)")
        return 2
    try:
        with open(args.results) as f:
            results = json.load(f)
    except ValueError as e:
        print(f"FAIL: results file {args.results} is not valid JSON: {e}")
        return 2
    if not isinstance(results, dict):
        print(f"FAIL: results file {args.results} must hold a JSON object")
        return 2

    report = render(results)
    with open(args.out, "w") as f:
        f.write(report)
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(report + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
