"""Regenerate ROOFLINE.md from dry-run artifacts (baseline + optimized)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import ART  # noqa: E402
from benchmarks import roofline_table as RT  # noqa: E402


def main():
    out = ["# Roofline tables (generated from dry-run artifacts)", ""]
    out.append(RT.dryrun_markdown())
    out.append("")
    out.append("## Optimized (current code, post-§Perf)")
    for mesh in ("single", "multi"):
        out.append("")
        out.append(RT.roofline_markdown(mesh))
    base = os.path.join(ART, "dryrun", "baseline")
    if os.path.isdir(base):
        out.append("")
        out.append("## Baseline (paper-faithful first compile, archived)")
        import benchmarks.roofline_table as rt
        import glob, json

        def load_base(mesh):
            cells = {}
            for p in sorted(glob.glob(os.path.join(base, mesh, "*.json"))):
                rec = json.load(open(p))
                cells[(rec["arch"], rec["shape"])] = rec
            return cells

        rt_load = rt.load_cells
        rt.load_cells = load_base
        for mesh in ("single",):
            out.append("")
            out.append(RT.roofline_markdown(mesh).replace(
                "### Roofline", "### Baseline roofline"))
        rt.load_cells = rt_load
    path = os.path.join(os.path.dirname(ART), "..", "ROOFLINE.md")
    path = os.path.abspath(path)
    open(path, "w").write("\n".join(out) + "\n")
    print("wrote", path)


if __name__ == "__main__":
    main()
