"""One benchmark per paper table/figure (Valet, MemSys '20).

Each function returns (csv_rows, artifact_dict).  The trace-driven ones use
``TieredPageStore`` with the paper's cost profile (Table 1 measurements) or
the TPU-adapted profile; the engine-driven ones run the REAL serving engine
on a small model so the data plane (spill/restore/recompute) is exact.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import drive_arrays, emit, latency_summary, timeit
from repro.core import (OrchestrationConfig, TieredPageStore, POLICIES,
                        PAPER_COSTS, TPU_COSTS)
from repro.data.pipeline import TraceConfig, generate_trace


def _config(policy, costs=PAPER_COSTS, *, pool=512, min_pool=None, peers=6,
            blocks=256, seed=0, **kw):
    return OrchestrationConfig(
        policy=POLICIES[policy] if isinstance(policy, str) else policy,
        costs=costs, pool_capacity=pool,
        min_pool=min_pool or max(pool // 8, 8), max_pool=pool,
        n_peers=peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed, **kw)


def _store(policy, costs=PAPER_COSTS, *, pool=512, min_pool=None, peers=6,
           blocks=256, seed=0, **kw):
    return TieredPageStore.from_config(
        _config(policy, costs, pool=pool, min_pool=min_pool, peers=peers,
                blocks=blocks, seed=seed, **kw))


def _trace_arrays(trace):
    ops = list(trace)
    pages = np.fromiter((p for _, p in ops), np.int64, len(ops))
    is_write = np.fromiter((op == "write" for op, _ in ops), bool, len(ops))
    return pages, is_write


def _drive(store, trace, tick_every=32, batch=256):
    """Drive a ("read"|"write", page) trace through ``access_batch`` with the
    standard tick cadence (see ``common.drive_arrays`` for the chunking
    contract).  Returns the per-op critical-path latency array."""
    pages, is_write = _trace_arrays(trace)
    return drive_arrays(store, pages, is_write, tick_every, batch)


def _populate(store, n_pages, tick_every=32, batch=256):
    """Write pages 0..n_pages-1 with the standard tick cadence (batched)."""
    pages = np.arange(n_pages, dtype=np.int64)
    i = 0
    while i < n_pages:
        nxt = i if i % tick_every == 0 else (i // tick_every + 1) * tick_every
        end = min(n_pages, i + batch, nxt + 1)
        store.access_batch(pages[i:end], True)
        if (end - 1) % tick_every == 0:
            store.background_tick()
        i = end
    return store


# -- Table 1: latency impact on the critical path -----------------------------

def table1_critical_path(rows):
    """Per-operation critical-path costs, paper profile vs TPU adaptation,
    plus MEASURED jitted data-plane ops (append/gather on this host)."""
    import jax
    import jax.numpy as jnp
    from repro.core import device_ops as dev

    art = {"paper_profile_us": {}, "tpu_profile_us": {}, "measured_us": {}}
    for name, cm in (("paper", PAPER_COSTS), ("tpu", TPU_COSTS)):
        prof = {
            "local_write": cm.local_write, "local_read": cm.local_read,
            "remote_write": cm.remote_write, "remote_read": cm.remote_read,
            "cold_read": cm.cold_read, "cold_write": cm.cold_write,
            "connect": cm.connect, "map_block": cm.map_block,
        }
        art[f"{name}_profile_us"] = prof
        for k, v in prof.items():
            emit(rows, f"table1/{name}/{k}", v)

    pool = dev.make_kv_pool(64, 16, 4, 64, jnp.float32)
    k = jnp.ones((8, 4, 64))
    v = jnp.ones((8, 4, 64))
    slot = jnp.arange(8, dtype=jnp.int32)
    off = jnp.zeros(8, jnp.int32)
    append = jax.jit(dev.append_token)
    us = timeit(append, pool, k, v, slot, off)
    emit(rows, "table1/measured/pool_append", us)
    art["measured_us"]["pool_append"] = us

    bt = jnp.arange(24, dtype=jnp.int32).reshape(8, 3)
    gather = jax.jit(dev.gather_pages)
    us = timeit(gather, pool, bt)
    emit(rows, "table1/measured/pool_gather", us)
    art["measured_us"]["pool_gather"] = us
    return art


# -- Figure 8: local/remote hit ratio vs mempool size --------------------------

def fig8_hit_ratio(rows):
    """Local vs remote hit ratio as the mempool grows (ETC mix, zipf keys).

    Pages are fully populated first; the measured phase uses ONE trace so
    the hot set is consistent.  Larger pools keep more of the hot set local."""
    art = {}
    n_pages = 4000
    trace = list(generate_trace(TraceConfig(n_pages, 20_000, 0.95, seed=2)))
    for pool in (64, 128, 256, 512, 1024, 2048):
        store = _store("valet", pool=pool, min_pool=pool, blocks=512)
        _populate(store, n_pages)
        store.drain()
        store.stats.local_hits = store.stats.remote_hits = 0
        store.stats.host_hits = store.stats.cold_hits = 0
        t0 = store.stats.time_us
        _drive(store, trace)
        hr = store.stats.hit_ratio()
        art[pool] = hr
        emit(rows, f"fig8/pool{pool}",
             (store.stats.time_us - t0) / len(trace),
             local=round(hr["local"], 4), remote=round(hr["remote"], 4))
    return art


# -- Figure 9: write latency vs block-I/O (page) size ---------------------------

def fig9_block_size(rows):
    """Valet decouples logical page size from transfer size (§3.3): the
    critical-path append is page-size independent (donated in-place update),
    while the coalesced block send grows with the transfer unit."""
    import time as _time
    import jax
    import jax.numpy as jnp
    from repro.core import device_ops as dev
    art = {}
    for page in (8, 16, 32, 64, 128):
        k = jnp.ones((4, 4, 64))
        v = jnp.ones((4, 4, 64))
        slot = jnp.arange(4, dtype=jnp.int32)
        off = jnp.zeros(4, jnp.int32)
        append = jax.jit(dev.append_token, donate_argnums=0)
        copy = jax.jit(dev.copy_block, donate_argnums=0)

        def chain(fn, *args, n=50):
            pool = dev.make_kv_pool(32, page, 4, 64, jnp.float32)
            pool = fn(pool, *args)                 # compile + warm
            jax.block_until_ready(pool.k)
            t0 = _time.perf_counter()
            for _ in range(n):
                pool = fn(pool, *args)
            jax.block_until_ready(pool.k)
            return (_time.perf_counter() - t0) / n * 1e6

        us_append = chain(append, k, v, slot, off)
        us_copy = chain(copy, jnp.int32(0), jnp.int32(1))
        art[page] = {"append_us": us_append, "block_copy_us": us_copy}
        emit(rows, f"fig9/page{page}", us_append,
             block_copy_us=round(us_copy, 2))
    return art


# -- Figures 10 & 21: host/remote distribution ----------------------------------

def fig10_21_distribution(rows):
    """Latency vs local:remote working-set split, per system."""
    art = {}
    n_pages = 2000
    total_ops = 20_000
    for policy in ("valet", "infiniswap", "nbdx", "os-swap"):
        art[policy] = {}
        for frac_name, pool in (("LocalOnly", 4096), ("75:25", 1536),
                                ("50:50", 1024), ("25:75", 512),
                                ("RemoteOnly", 16)):
            store = _store(policy, pool=pool, min_pool=pool, blocks=512)
            _populate(store, n_pages)
            store.drain()
            t0 = store.stats.time_us
            trace = generate_trace(TraceConfig(n_pages, total_ops, 0.75,
                                               seed=3))
            _drive(store, trace)
            lat = (store.stats.time_us - t0) / total_ops
            art[policy][frac_name] = lat
            emit(rows, f"fig10/{policy}/{frac_name}", lat)
    return art


# -- Figures 19/20: completion time vs working-set fit (REAL engine) -----------

def fig19_20_working_set(rows):
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.models import transformer as T
    from repro.serve import ValetServeEngine

    cfg = reduced(ARCHS["granite-3-8b"])
    ctx = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(6)]
    # total KV working set = 6 requests x 24 tokens / page 4 = 36 pages
    total_pages = 36
    art = {}
    for policy in ("valet", "infiniswap", "os-swap"):
        art[policy] = {}
        for fit_name, frac in (("100%", 1.2), ("75%", 0.75), ("50%", 0.5),
                               ("25%", 0.25)):
            slots = max(int(total_pages * frac), 6)
            eng = ValetServeEngine(params, cfg, ctx, max_batch=3, max_seq=64,
                                   page=4, pool_slots=slots,
                                   policy=POLICIES[policy])
            for p in prompts:
                eng.submit(p, max_new=16)
            reqs = eng.run(max_steps=600)
            done = sum(r.status == "done" for r in reqs)
            s = eng.stats
            completion_us = s.sim_time_us
            art[policy][fit_name] = {
                "completion_us": completion_us, "done": done,
                "spilled": s.spilled_pages, "recomputes": s.recomputes,
                "tokens": s.tokens,
            }
            emit(rows, f"fig19/{policy}/fit{fit_name}",
                 completion_us / max(s.tokens, 1),
                 completion_ms=round(completion_us / 1e3, 2), done=done)
    return art


# -- Figure 22: scalability with workload size -----------------------------------

def fig22_scalability(rows):
    """Throughput + p99 as the workload grows past the fixed local pool
    (the paper's VoltDB scalability sweep, SYS mix)."""
    art = {}
    for policy in ("valet", "infiniswap", "nbdx"):
        art[policy] = {}
        for n_pages in (1000, 2000, 4000, 8000):
            store = _store(policy, pool=256, min_pool=256, blocks=1024,
                           peers=6)
            _populate(store, n_pages)              # populate working set
            store.drain()
            trace = generate_trace(TraceConfig(n_pages, 4 * n_pages,
                                               0.75, seed=4))
            lat = _drive(store, trace)
            thr = 1e6 / max(np.mean(lat), 1e-9)
            p99 = float(np.percentile(lat, 99))
            art[policy][n_pages] = {"ops_per_s": thr, "p99_us": p99}
            emit(rows, f"fig22/{policy}/pages{n_pages}", float(np.mean(lat)),
                 ops_per_s=round(thr), p99_us=round(p99, 1))
    return art


# -- Beyond-paper: NAD vs attention-mass victim selection ------------------------

def victim_quality(rows):
    """Valet's Non-Activity-Duration vs the attention-mass variant
    (DESIGN.md §2): under a skewed re-read pattern, mass-based victims evict
    genuinely cold blocks, NAD evicts by write age (paper-faithful).  We
    measure post-eviction hit ratios on the hot set."""
    from repro.core import (ActivityTracker, select_victims_nad,
                            select_victims_mass)
    rng = np.random.default_rng(0)
    n_blocks = 256
    tracker = ActivityTracker()
    # all blocks written early (same age ordering), but a hot 10% keeps
    # receiving attention mass
    for b in range(n_blocks):
        tracker.on_write([b], step=b)
    hot = set(rng.choice(n_blocks, n_blocks // 10, replace=False).tolist())
    for step in range(2000):
        blocks = [b for b in rng.choice(n_blocks, 8)
                  if b in hot or rng.random() < 0.05]
        tracker.on_read_mass(blocks, [1.0] * len(blocks))
    art = {}
    for name, fn in (("nad", select_victims_nad),
                     ("mass", select_victims_mass)):
        victims = fn(tracker, list(range(n_blocks)), 64, step=3000)
        hot_evicted = len(hot.intersection(victims))
        art[name] = {"victims": 64, "hot_evicted": hot_evicted,
                     "hot_survival": 1 - hot_evicted / len(hot)}
        emit(rows, f"victim/{name}", float(hot_evicted),
             hot_survival=round(art[name]["hot_survival"], 3))
    return art


# -- Figure 23: eviction amount vs throughput (migration vs delete) --------------

def fig23_eviction(rows):
    art = {}
    n_pages = 3000
    for policy in ("valet", "infiniswap"):
        art[policy] = {}
        for evict_blocks in (0, 4, 8, 16, 32):
            store = _store(policy, pool=128, min_pool=128, blocks=512,
                           peers=6)
            _populate(store, n_pages)
            store.drain()
            store.peer_pressure(0, evict_blocks)
            lat = store.access_batch(np.arange(n_pages), False)
            thr = 1e6 / max(np.mean(lat), 1e-9)
            art[policy][evict_blocks] = {
                "ops_per_s": thr, "cold_hits": store.stats.cold_hits,
                "migrations": store.stats.migrations,
                "evictions": store.stats.evictions,
            }
            emit(rows, f"fig23/{policy}/evict{evict_blocks}",
                 float(np.mean(lat)), ops_per_s=round(thr),
                 cold=store.stats.cold_hits)
    return art


# -- Beyond-paper: batched critical-path orchestration --------------------------

def batch_speedup(rows):
    """``bench: batch_speedup`` — wall-clock of the scalar write()/read()
    loop vs ``access_batch`` at batch size 256, on the ETC hot-set mix
    (working set resident, the paper's serving steady state).

    Timed region is the critical path; ``background_tick`` (the paper's
    asynchronous Remote Sender Thread, which the simulator happens to run
    inline) executes between timed chunks at the same cadence for both
    drivers.  Stats parity between the two drivers is asserted, so the
    speedup is measured on bit-identical work.  An end-to-end number
    (ticks included in the timed region) is reported alongside.
    """
    import time as _time

    batch = 256
    n_pages = 1500
    trace = list(generate_trace(TraceConfig(n_pages, 50_000, 0.95, seed=2)))
    pages, is_write = _trace_arrays(trace)
    n = len(pages)

    def fresh():
        store = _store("valet", pool=4096, min_pool=4096, blocks=512,
                       peers=6)
        _populate(store, n_pages, tick_every=batch, batch=batch)
        store.drain()
        return store

    def run_scalar(store):
        crit = total = 0.0
        i = 0
        while i < n:
            end = min(n, i + batch)
            t0 = _time.perf_counter()
            for k in range(i, end):
                if is_write[k]:
                    store.write(int(pages[k]))
                else:
                    store.read(int(pages[k]))
            crit += _time.perf_counter() - t0
            t0 = _time.perf_counter()
            store.background_tick()
            total += _time.perf_counter() - t0
            i = end
        return crit, crit + total

    def run_batched(store):
        crit = total = 0.0
        i = 0
        while i < n:
            end = min(n, i + batch)
            t0 = _time.perf_counter()
            store.access_batch(pages[i:end], is_write[i:end])
            crit += _time.perf_counter() - t0
            t0 = _time.perf_counter()
            store.background_tick()
            total += _time.perf_counter() - t0
            i = end
        return crit, crit + total

    # min wall-clock per driver, independently across trials: noise only
    # ever inflates a wall-clock sample, so per-driver minima are the
    # least-noisy estimates and their ratio is not biased upward the way
    # picking the best single-trial ratio would be
    crit_ss, crit_bs, tot_ss, tot_bs = [], [], [], []
    for _ in range(5):
        s, b = fresh(), fresh()
        crit_s, tot_s = run_scalar(s)
        crit_b, tot_b = run_batched(b)
        assert s.stats == b.stats, "scalar/batched drivers diverged"
        crit_ss.append(crit_s)
        crit_bs.append(crit_b)
        tot_ss.append(tot_s)
        tot_bs.append(tot_b)
    crit_s, crit_b = min(crit_ss), min(crit_bs)
    tot_s, tot_b = min(tot_ss), min(tot_bs)
    best = {"scalar_us_per_op": crit_s * 1e6 / n,
            "batched_us_per_op": crit_b * 1e6 / n,
            "speedup": crit_s / crit_b,
            "scalar_e2e_us_per_op": tot_s * 1e6 / n,
            "batched_e2e_us_per_op": tot_b * 1e6 / n,
            "e2e_speedup": tot_s / tot_b}
    art = dict(best, batch=batch, ops=n, n_pages=n_pages)
    emit(rows, "batch_speedup/scalar", best["scalar_us_per_op"])
    emit(rows, "batch_speedup/batched", best["batched_us_per_op"],
         speedup=round(best["speedup"], 2),
         e2e_speedup=round(best["e2e_speedup"], 2))
    return art


# -- Beyond-paper: batched critical path UNDER MEMORY PRESSURE -------------------

def pressure_speedup(rows):
    """``bench: pressure_speedup`` — wall-clock of the scalar write()/read()
    loop vs ``access_batch`` at batch 256 on a TIGHT pool
    (``pool_capacity == min_pool``, near-flat working set ~16x the pool),
    i.e. the oversubscribed regime the paper actually targets: every batch
    overruns the free list ~a dozen times, so the batched path must absorb
    reclaim boundary events without degenerating to per-batch re-analysis
    (the pre-plan-once engine measured ~0.6x scalar here; see ROADMAP).

    Same measurement discipline as ``batch_speedup``: the timed region is
    the critical path; ``background_tick`` (the paper's asynchronous Remote
    Sender Thread) runs between timed chunks at the same cadence for both
    drivers, draining the staged queue fully so the timed region isolates
    critical-path orchestration rather than deferred send work.  Stats
    parity is asserted, so the speedup is measured on bit-identical work;
    per-driver minima over trials.
    """
    import time as _time

    batch = 256
    pool = 256                     # == min_pool: no headroom, ever
    n_pages = 4096                 # working set 16x the pool
    # zipf_a 1.05: near-flat popularity — a zipf head fits any pool, so a
    # flat set far beyond the pool is the regime where every batch pays
    # eviction pressure (same reasoning as the multi_tenant trace shape)
    trace = list(generate_trace(TraceConfig(n_pages, 40_000, 0.6,
                                            zipf_a=1.05, seed=5)))
    pages, is_write = _trace_arrays(trace)
    n = len(pages)
    drain = 1 << 12                # full async drain per tick

    def fresh():
        store = _store("valet", pool=pool, min_pool=pool, blocks=1024,
                       peers=6)
        _populate(store, n_pages, tick_every=batch, batch=batch)
        store.drain()
        return store

    def run_scalar(store):
        crit = 0.0
        i = 0
        while i < n:
            end = min(n, i + batch)
            t0 = _time.perf_counter()
            for k in range(i, end):
                if is_write[k]:
                    store.write(int(pages[k]))
                else:
                    store.read(int(pages[k]))
            crit += _time.perf_counter() - t0
            store.background_tick(drain)
            i = end
        return crit

    def run_batched(store):
        crit = 0.0
        i = 0
        while i < n:
            end = min(n, i + batch)
            t0 = _time.perf_counter()
            store.access_batch(pages[i:end], is_write[i:end])
            crit += _time.perf_counter() - t0
            store.background_tick(drain)
            i = end
        return crit

    # min wall-clock per driver across trials (noise only inflates samples)
    ts, tb = [], []
    for _ in range(5):
        s, b = fresh(), fresh()
        t_s = run_scalar(s)
        t_b = run_batched(b)
        assert s.stats == b.stats, "scalar/batched pressure drivers diverged"
        ts.append(t_s)
        tb.append(t_b)
    t_s, t_b = min(ts), min(tb)
    art = {"scalar_us_per_op": t_s * 1e6 / n,
           "batched_us_per_op": t_b * 1e6 / n,
           "speedup": t_s / t_b,
           "batch": batch, "ops": n, "pool": pool, "n_pages": n_pages}
    emit(rows, "pressure_speedup/scalar", art["scalar_us_per_op"])
    emit(rows, "pressure_speedup/batched", art["batched_us_per_op"],
         speedup=round(art["speedup"], 2))
    return art


# -- Tentpole: async orchestration tail latency ----------------------------------

def tail_latency(rows):
    """``bench: tail_latency`` — critical-path p50/p99 (simulated us) of the
    synchronous store vs the ``AsyncOrchestrator`` on the oversubscribed
    pressure trace (same shape as ``pressure_speedup``: pool == min_pool,
    working set 16x the pool, near-flat popularity).

    The synchronous store stalls the critical path whenever a write finds
    the free list and the staging queue both full — it must flush inline
    (the paper's pre-Remote-Sender-Thread strawman for that op).  The async
    engine drains staging and restocks the free list at epoch boundaries on
    the daemon's own clock, so the same op pays only a fence *if the daemon
    is behind*; on this trace the daemon keeps up and the write-tail stall
    disappears entirely from the foreground distribution.

    Both runs are deterministic simulated microseconds out of the
    ``LatencyReservoir`` (reset after the populate phase so only measured
    ops are sampled), so the tracked ``speedup`` (sync p99 / async p99) is
    run-to-run stable and CI-gated.  The async run also re-checks the full
    ``InvariantChecker`` at the end — a tail number earned by dropping
    writes would fail here, not ship.
    """
    from repro.core import InvariantChecker

    batch = 256
    pool = 256                     # == min_pool: no headroom, ever
    n_pages = 4096                 # working set 16x the pool
    trace = list(generate_trace(TraceConfig(n_pages, 40_000, 0.6,
                                            zipf_a=1.05, seed=5)))

    def run(async_mode):
        st = TieredPageStore.from_config(
            _config("valet", pool=pool, min_pool=pool, peers=6, blocks=1024,
                    async_mode=async_mode))
        _populate(st, n_pages, tick_every=batch, batch=batch)
        st.drain()
        st.stats.lat.reset()       # sample only the measured phase
        _drive(st, trace, tick_every=1024, batch=batch)
        if async_mode:
            InvariantChecker(st).check()
        return st.stats

    sync = run(False)
    asy = run(True)
    s_sum, a_sum = latency_summary(sync), latency_summary(asy)
    speedup = s_sum["p99_us"] / max(a_sum["p99_us"], 1e-9)
    art = {
        "speedup": speedup,
        "sync_p50_us": s_sum["p50_us"], "sync_p99_us": s_sum["p99_us"],
        "async_p50_us": a_sum["p50_us"], "async_p99_us": a_sum["p99_us"],
        "sync_write_stall_us": sync.write_stall_us,
        "async_write_stall_us": asy.write_stall_us,
        "fences": asy.fences, "fence_wait_us": asy.fence_wait_us,
        "daemon_us": asy.daemon_us,
        "ops": len(trace), "pool": pool, "n_pages": n_pages,
    }
    emit(rows, "tail_latency/sync", s_sum["p99_us"],
         p50_us=round(s_sum["p50_us"], 2),
         stall_us=round(sync.write_stall_us, 1))
    emit(rows, "tail_latency/async", a_sum["p99_us"],
         p50_us=round(a_sum["p50_us"], 2), speedup=round(speedup, 2),
         fences=asy.fences, daemon_us=round(asy.daemon_us, 1))
    return art


# -- §3.4: multi-container host memory coordination ------------------------------

def multi_tenant(rows):
    """``bench: multi_tenant`` — N co-located containers under skewed,
    phase-rotating demand: one ``HostMemoryCoordinator`` arbitrating a
    shared host slab vs. static equal partitioning of the same slab.

    Each phase makes a different container "hot" (working set ~3x the
    static share) while the others idle on small sets, so pooled memory
    wins exactly when demand skew lets idle containers donate (§3.4; the
    Pond/FluidMem multi-tenant scenario).  The slab is oversubscribed —
    the sum of per-phase demands exceeds it — so coordinated growth runs
    through weighted-fair reclamation, not just the free pool.

    All numbers are deterministic simulated microseconds (seeded traces,
    seeded stores), so the tracked ``speedup`` (static aggregate time /
    coordinated aggregate time) is run-to-run stable and CI-gated the same
    way as the wall-clock ratio benchmarks.  ``fairness`` is Jain's index
    over the per-container speedups — a coordinator that starved the idle
    tenants to feed the hot one would show a low index, not just a high
    aggregate.
    """
    from repro.core.coordinator import HostMemoryCoordinator

    n_containers = 4
    total = 2048                       # shared host slab (pages)
    static_share = total // n_containers
    min_pool = 64                      # guaranteed per-container floor
    hot_pages, cold_pages = 1400, 96
    hot_ops, cold_ops = 6000, 400
    slice_ops = 128                    # round-robin time slice

    def traces_for(c):
        """Uniform accesses over the phase working set (ETC 95/5 mix).

        Uniform, not zipfian: a zipf head fits any pool, so pooled memory
        would show nothing.  A flat working set ~3x the static share is the
        regime where hit ratio tracks pool size — the skew here is *across
        containers over time*, which is the §3.4 claim under test."""
        out = []
        for ph in range(n_containers):
            hot = ph == c
            rng = np.random.default_rng(100 + 10 * c + ph)
            n_ops = hot_ops if hot else cold_ops
            pages = rng.integers(0, hot_pages if hot else cold_pages,
                                 size=n_ops, dtype=np.int64)
            is_write = rng.random(n_ops) >= 0.95
            out.append((pages, is_write))
        return out

    traces = [traces_for(c) for c in range(n_containers)]

    def run(coordinated):
        coord = HostMemoryCoordinator(total) if coordinated else None
        stores = []
        for c in range(n_containers):
            if coordinated:
                st = TieredPageStore.from_config(OrchestrationConfig(
                    policy=POLICIES["valet"], costs=PAPER_COSTS,
                    pool_capacity=total, min_pool=min_pool,
                    max_pool=total - (n_containers - 1) * min_pool,
                    n_peers=4, peer_capacity_blocks=2048, pages_per_block=16,
                    seed=c, grow_step=128,    # lease whole demand slabs
                    coordinator=coord, container_name=f"c{c}"))
            else:
                st = TieredPageStore.from_config(OrchestrationConfig(
                    policy=POLICIES["valet"], costs=PAPER_COSTS,
                    pool_capacity=static_share, min_pool=static_share,
                    max_pool=static_share, n_peers=4,
                    peer_capacity_blocks=2048, pages_per_block=16, seed=c))
            stores.append(st)

        def rr_drive(arrays):
            """Round-robin the containers in ``slice_ops`` chunks so demand
            overlaps in time (what a host actually sees)."""
            cursors = [0] * n_containers
            live = True
            while live:
                live = False
                for c, (pages, is_write) in enumerate(arrays):
                    i = cursors[c]
                    if i >= len(pages):
                        continue
                    live = True
                    end = min(i + slice_ops, len(pages))
                    stores[c].access_batch(pages[i:end], is_write[i:end])
                    stores[c].background_tick()
                    cursors[c] = end

        # populate every container's full page-id space so the measured
        # phases never pay first-touch cold reads
        pop = np.arange(hot_pages, dtype=np.int64)
        rr_drive([(pop, np.ones(hot_pages, bool))] * n_containers)
        for st in stores:
            st.drain()
        t0 = [st.stats.time_us for st in stores]
        for ph in range(n_containers):
            rr_drive([traces[c][ph] for c in range(n_containers)])
        per_container = [st.stats.time_us - t0[c]
                         for c, st in enumerate(stores)]
        nonlocal_hits = sum(st.stats.remote_hits + st.stats.host_hits
                            + st.stats.cold_hits for st in stores)
        if coord is not None:
            coord.check_invariants()
        return per_container, nonlocal_hits, coord

    static_us, static_misses, _ = run(coordinated=False)
    coord_us, coord_misses, coord = run(coordinated=True)

    speedup = sum(static_us) / sum(coord_us)
    per_speedup = [s / c for s, c in zip(static_us, coord_us)]
    fairness = (sum(per_speedup) ** 2
                / (n_containers * sum(x * x for x in per_speedup)))
    art = {
        "speedup": speedup,
        "fairness": fairness,
        "static_us": sum(static_us),
        "coordinated_us": sum(coord_us),
        "static_nonlocal_hits": static_misses,
        "coordinated_nonlocal_hits": coord_misses,
        "per_container_speedup": per_speedup,
        "containers": n_containers,
        "slab_pages": total,
        "pages_reclaimed": coord.stats.pages_reclaimed,
        "reclaim_events": coord.stats.n_reclaim_events,
    }
    emit(rows, "multi_tenant/static", sum(static_us) / 1e3,
         nonlocal_hits=static_misses)
    emit(rows, "multi_tenant/coordinated", sum(coord_us) / 1e3,
         nonlocal_hits=coord_misses, speedup=round(speedup, 2),
         fairness=round(fairness, 3))
    return art


# -- Beyond-paper: batched reclaim/flush/migration pipeline ----------------------

def reclaim_speedup(rows):
    """``bench: reclaim_speedup`` — wall-clock of the scalar off-critical-path
    pipeline (per-write-set flush placement, per-block victim
    selection/migration, per-page repoints) vs the vectorized one
    (``batch_reclaim=True``: bulk placement pass, dense top-k victims,
    ``migrate_batch`` scatter cutover), at pressure-batch 256.

    The timed region covers exactly the reclaim machinery: ``_flush`` +
    ``_reclaim`` after each staged write burst, then repeated
    ``peer_pressure`` rounds that migrate 256 blocks per call.  Writes are
    staged through the (shared) batched critical path untimed.  Stats parity
    between the two drivers is asserted, so the speedup is measured on
    bit-identical work.
    """
    import time as _time

    pressure_batch = 256
    chunk = 1024            # pool-sized write bursts staged between flushes
    rounds = 16             # 16k pages -> ~2k MR blocks across the peers
    n_peers = 6

    def fresh(batched):
        return TieredPageStore.from_config(
            _config("valet", pool=chunk, min_pool=chunk, peers=n_peers,
                    blocks=4096, batch_reclaim=batched))

    def run(store):
        timed = 0.0
        base = 0
        for _ in range(rounds):
            pgs = np.arange(base, base + chunk, dtype=np.int64)
            base += chunk
            store.access_batch(pgs, True)          # staging: untimed
            t0 = _time.perf_counter()
            store._flush(1 << 15)
            store._reclaim(chunk)
            timed += _time.perf_counter() - t0
        for _ in range(2):
            for p in range(n_peers):
                t0 = _time.perf_counter()
                store.peer_pressure(p, pressure_batch)
                timed += _time.perf_counter() - t0
        return timed

    # min wall-clock per driver across trials (noise only inflates samples)
    ts, tb = [], []
    for _ in range(5):
        s, b = fresh(False), fresh(True)
        t_s = run(s)
        t_b = run(b)
        assert s.stats == b.stats, "scalar/batched reclaim drivers diverged"
        ts.append(t_s)
        tb.append(t_b)
    t_s, t_b = min(ts), min(tb)
    n_ops = rounds * chunk
    art = {"scalar_s": t_s, "batched_s": t_b,
           "speedup": t_s / t_b,
           "scalar_us_per_page": t_s * 1e6 / n_ops,
           "batched_us_per_page": t_b * 1e6 / n_ops,
           "pressure_batch": pressure_batch, "pages": n_ops,
           "peers": n_peers}
    emit(rows, "reclaim_speedup/scalar", art["scalar_us_per_page"])
    emit(rows, "reclaim_speedup/batched", art["batched_us_per_page"],
         speedup=round(art["speedup"], 2))
    return art


# -- Beyond-paper: the reclaim bookkeeping floor ---------------------------------

def reclaim_floor(rows):
    """``bench: reclaim_floor`` — nanoseconds of PURE reclaim bookkeeping
    per reclaimed page: the scalar reference (``reclaim_up_to``: per-entry
    queue pops, per-slot state transitions) vs the dense engine
    (``reclaim_bulk``: masked gathers/scatters over the structure-of-arrays
    pool/queue metadata), on identical queue contents.

    This isolates the floor that caps ``pressure_speedup`` — the
    parity-mandated bookkeeping both the scalar loop and the plan-once
    batch engine pay on every eviction-pressure boundary — so the floor
    itself is tracked by CI, not just the end-to-end ratio.  The queue
    carries one stale (already freed, re-pushed) entry per four live ones,
    the shape pressure produces: reclaim pops more entries than it frees
    and the dense path's first-occurrence dedup is exercised.  Tracked
    ratio = scalar_ns / dense_ns; wall-clock minima per mode over trials.
    """
    import time as _time

    from repro.core.pool import ValetMempool
    from repro.core.queues import WritePipeline, WriteSet

    n_slots = 4096
    burst = 16                  # pages_per_block-sized reclaim bursts
    rounds = 8

    def run(dense: bool) -> float:
        pool = ValetMempool(n_slots, min_pages=n_slots, max_pages=n_slots)
        wp = WritePipeline(pool, queue_len=1 << 16)
        timed = 0.0
        for _ in range(rounds):
            # fill the pool (one single-page write-set per slot), send all
            slot_of = {}
            for pg in range(n_slots):
                ws = wp.write((pg,), pg)
                if pg % 4 == 0:
                    slot_of[pg] = ws.slots[0]
            wp.flush(n_slots, lambda w: None)
            # stale layer: re-push every 4th entry's (page, slot) pair —
            # after the first occurrence frees the slot, the twin is a
            # stale pop, exactly like §5.2 re-queues / rewritten pages
            for pg, slot in slot_of.items():
                wp.reclaimable.push(WriteSet(-1, (pg,), (slot,)))
            t0 = _time.perf_counter()
            if dense:
                while len(wp.reclaimable):
                    wp.reclaim_bulk(burst)
            else:
                while len(wp.reclaimable):
                    wp.reclaim(burst)
            timed += _time.perf_counter() - t0
        return timed

    n_pages_total = rounds * n_slots
    ts, td = [], []
    for _ in range(3):
        ts.append(run(dense=False))
        td.append(run(dense=True))
    t_s, t_d = min(ts), min(td)
    art = {"scalar_ns_per_page": t_s * 1e9 / n_pages_total,
           "dense_ns_per_page": t_d * 1e9 / n_pages_total,
           "speedup": t_s / t_d,
           "slots": n_slots, "burst": burst, "rounds": rounds}
    emit(rows, "reclaim_floor/scalar_ns", art["scalar_ns_per_page"])
    emit(rows, "reclaim_floor/dense_ns", art["dense_ns_per_page"],
         speedup=round(art["speedup"], 2))
    return art
