"""Aggregate the dry-run artifacts into the §Dry-run and §Roofline tables."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART


def load_cells(mesh="single"):
    out = {}
    for path in sorted(glob.glob(os.path.join(ART, "dryrun", mesh,
                                              "*.json"))):
        rec = json.load(open(path))
        if "shape" not in rec:
            continue                      # extra artifacts (migrate/pp)
        key = rec["shape"]
        if rec.get("kv_dtype", "bf16") != "bf16":
            key += f"+{rec['kv_dtype']}"
        out[(rec["arch"], key)] = rec
    return out


def roofline_markdown(mesh="single"):
    cells = load_cells(mesh)
    lines = [
        f"### Roofline — {mesh} mesh "
        f"({'2x16x16' if mesh == 'multi' else '16x16'})",
        "",
        "| arch | shape | t_compute | t_memory | t_collective | bound |"
        " useful | roofline frac | HBM GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), rec in sorted(cells.items()):
        if rec.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | skip |"
                         f" — | — | ({rec['reason'][:40]}…) |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
            continue
        r = rec["roofline"]
        mem = rec["memory"]["peak_per_device"] / 2**30
        lines.append(
            f"| {arch} | {shape} "
            f"| {r['t_compute_s']*1e3:.1f}ms "
            f"| {r['t_memory_s']*1e3:.1f}ms "
            f"| {r['t_collective_s']*1e3:.1f}ms "
            f"| {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {mem:.2f} |")
    return "\n".join(lines)


def dryrun_markdown():
    lines = ["### Dry-run status (lower+compile, per mesh)", ""]
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        ok = sum(1 for r in cells.values() if r.get("status") == "ok")
        skip = sum(1 for r in cells.values() if r.get("status") == "skipped")
        err = sum(1 for r in cells.values() if r.get("status") == "error")
        fits = sum(1 for r in cells.values()
                   if r.get("status") == "ok" and r.get("fits_hbm_16g"))
        lines.append(f"* **{mesh}**: {ok} compiled ok ({fits} fit 16GiB "
                     f"HBM), {skip} skipped per brief, {err} errors "
                     f"of {len(cells)} cells")
    return "\n".join(lines)


def run(rows):
    art = {"single": {}, "multi": {}}
    for mesh in ("single", "multi"):
        for (arch, shape), rec in load_cells(mesh).items():
            if rec.get("status") != "ok":
                continue
            r = rec["roofline"]
            art[mesh][f"{arch}__{shape}"] = r
            bound_us = max(r["t_compute_s"], r["t_memory_s"],
                           r["t_collective_s"]) * 1e6
            rows.append(
                f"roofline/{mesh}/{arch}/{shape},{bound_us:.1f},"
                f"bottleneck={r['bottleneck']};"
                f"frac={r['roofline_fraction']:.3f}")
    return art
