# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one entry per paper table/figure + the roofline
aggregation.  ``python -m benchmarks.run [--only fig8,fig23]``."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: table1,fig8,fig9,fig10,fig19,fig22,"
                         "fig23,batch_speedup,pressure_speedup,"
                         "reclaim_speedup,reclaim_floor,tail_latency,"
                         "multi_tenant,roofline")
    args = ap.parse_args()
    only = None if args.only == "all" else set(args.only.split(","))

    from benchmarks import paper_tables as PT
    from benchmarks import roofline_table as RT
    from benchmarks.common import save_json

    benches = [
        ("table1", PT.table1_critical_path),
        ("fig8", PT.fig8_hit_ratio),
        ("fig9", PT.fig9_block_size),
        ("fig10", PT.fig10_21_distribution),
        ("fig19", PT.fig19_20_working_set),
        ("fig22", PT.fig22_scalability),
        ("fig23", PT.fig23_eviction),
        ("batch_speedup", PT.batch_speedup),
        ("pressure_speedup", PT.pressure_speedup),
        ("reclaim_speedup", PT.reclaim_speedup),
        ("reclaim_floor", PT.reclaim_floor),
        ("tail_latency", PT.tail_latency),
        ("multi_tenant", PT.multi_tenant),
        ("victim", PT.victim_quality),
        ("roofline", RT.run),
    ]
    rows = ["name,us_per_call,derived"]
    arts = {}
    for name, fn in benches:
        if only is not None and name not in only:
            continue
        t0 = time.time()
        arts[name] = fn(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    save_json("bench_results", arts)
    print("\n".join(rows))


if __name__ == '__main__':
    main()
