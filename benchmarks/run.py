# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one entry per paper table/figure, the beyond-paper
speedup benchmarks, the trace-driven workload suite, and the roofline
aggregation.  ``python -m benchmarks.run [--only fig8,ycsb_a]``.

The registry below is the single source of truth: the ``--only`` help text
and name validation are generated from it, so the CLI documentation cannot
drift from the registered benches (it did once — ``victim`` was registered
but undocumented).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

# name -> (module under benchmarks/, function).  Modules resolve lazily so
# ``--help`` stays instant and a broken bench module only breaks its own
# entries.
BENCHES = [
    ("table1", "paper_tables", "table1_critical_path"),
    ("fig8", "paper_tables", "fig8_hit_ratio"),
    ("fig9", "paper_tables", "fig9_block_size"),
    ("fig10", "paper_tables", "fig10_21_distribution"),
    ("fig19", "paper_tables", "fig19_20_working_set"),
    ("fig22", "paper_tables", "fig22_scalability"),
    ("fig23", "paper_tables", "fig23_eviction"),
    ("batch_speedup", "paper_tables", "batch_speedup"),
    ("pressure_speedup", "paper_tables", "pressure_speedup"),
    ("reclaim_speedup", "paper_tables", "reclaim_speedup"),
    ("reclaim_floor", "paper_tables", "reclaim_floor"),
    ("tail_latency", "paper_tables", "tail_latency"),
    ("multi_tenant", "paper_tables", "multi_tenant"),
    ("victim", "paper_tables", "victim_quality"),
    ("ycsb_a", "workloads", "ycsb_a"),
    ("ycsb_b", "workloads", "ycsb_b"),
    ("ycsb_c", "workloads", "ycsb_c"),
    ("ycsb_d", "workloads", "ycsb_d"),
    ("ml_trace", "workloads", "ml_trace_bench"),
    ("mixed_tenant_workload", "workloads", "mixed_tenant_workload"),
    ("roofline", "roofline_table", "run"),
    ("serve_qps", "serve_qps", "serve_qps"),
    ("fault_recovery", "fault_recovery", "fault_recovery"),
    ("cluster_tenant", "cluster_tenant", "cluster_tenant"),
]

BENCH_NAMES = [name for name, _, _ in BENCHES]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list of benches (default: all): "
                         + ",".join(BENCH_NAMES))
    args = ap.parse_args()
    only = None if args.only == "all" else set(args.only.split(","))
    if only is not None:
        unknown = only.difference(BENCH_NAMES)
        if unknown:
            ap.error(f"unknown bench name(s): {','.join(sorted(unknown))} "
                     f"(available: {','.join(BENCH_NAMES)})")

    from benchmarks.common import save_json

    rows = ["name,us_per_call,derived"]
    arts = {}
    for name, module, func in BENCHES:
        if only is not None and name not in only:
            continue
        fn = getattr(importlib.import_module(f"benchmarks.{module}"), func)
        t0 = time.time()
        arts[name] = fn(rows)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    save_json("bench_results", arts)
    print("\n".join(rows))


if __name__ == '__main__':
    main()
