"""``bench: serve_qps`` — continuous-batching serving under high QPS.

Drives ``ValetServeEngine.step()`` directly with open-loop Poisson arrivals
stamped in *simulated* time: requests are submitted when the engine's sim
clock passes their arrival timestamp, and the clock fast-forwards across
idle gaps.  The same request stream (prompts, arrival times, decode budget)
runs twice — zero-restore on, then legacy bulk restore — so the gated
metric is a deterministic sim-time ratio on identical work.

Reported per arch and mode:

* ``tok_s_sim``   — tokens per simulated second (critical-path throughput);
* ``attft_*``     — admission-to-first-token latency percentiles
  (``Request.first_token_us - Request.submit_us``), the serving-side tail
  the zero-restore repoint path is built to protect;
* ``fences``      — daemon fence-wait summary (count/p50/p99), showing how
  often restores actually waited on in-flight flush traffic.

Gated key (``serve_qps/tokens_per_s``): the geometric mean over archs of
``sim_time(bulk) / sim_time(zero)`` — the zero-restore throughput speedup.
Repoints cost nothing on the critical path, so this ratio is >= 1 whenever
preemption pressure exists, and it regresses if bulk scatters creep back
into the restore path.
"""
from __future__ import annotations

import math
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import ARCHS, reduced
from repro.core.policies import POLICIES
from repro.models import transformer as T
from repro.serve import ValetServeEngine

CTX = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)

# (arch, n requests, pool slots, stream seed): pools sized just under the
# live working set (3 active seqs x ~6-7 pages), so growth past page
# boundaries forces preempt/restore churn while leaving enough slack that a
# healthy fraction of demoted slots survives unreused until resume — the
# regime where repoints (free) beat streams (host_read each)
STREAMS = [("granite-3-8b", 32, 15, 0), ("gemma3-4b", 20, 15, 1)]
MAX_NEW = 18
PROMPT_BUCKETS = (4, 8)        # few distinct lengths bounds prefill compiles
MEAN_GAP_US = 20.0             # mean inter-arrival; ~50k QPS in sim time


def _make_stream(vocab, n, seed):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(MEAN_GAP_US, size=n))
    prompts = [rng.integers(2, vocab, size=int(rng.choice(PROMPT_BUCKETS)))
               for _ in range(n)]
    return arrivals, prompts


def _drive(eng, arrivals, prompts, max_steps=4000):
    """Open-loop arrival injection around ``engine.step()``."""
    t0 = time.monotonic()
    i, n = 0, len(prompts)
    while max_steps > 0:
        max_steps -= 1
        while i < n and arrivals[i] <= eng.stats.sim_time_us:
            eng.submit(prompts[i], MAX_NEW, submit_us=arrivals[i])
            i += 1
        if not eng.step():
            if i >= n:
                break
            # idle with future arrivals: fast-forward the sim clock
            eng.stats.sim_time_us = max(eng.stats.sim_time_us,
                                        float(arrivals[i]))
    eng._flush_demoted(None)     # charge any still-demoted write-backs
    eng.stats.wall_time_s += time.monotonic() - t0
    return list(eng._requests.values())


def _run(params, cfg, arrivals, prompts, slots, zero):
    eng = ValetServeEngine(params, cfg, CTX, max_batch=3, max_seq=64,
                           page=4, pool_slots=slots,
                           policy=POLICIES["valet"], async_mode=True,
                           zero_restore=zero)
    reqs = _drive(eng, arrivals, prompts)
    s = eng.stats
    attft = np.asarray([r.first_token_us - r.submit_us for r in reqs
                        if r.first_token_us >= 0])
    return {
        "done": sum(r.status == "done" for r in reqs),
        "tokens": s.tokens,
        "sim_time_us": s.sim_time_us,
        "tok_s_sim": s.tokens / s.sim_time_us * 1e6,
        "tok_s_wall": s.tokens / max(s.wall_time_s, 1e-9),
        "attft_p50_us": float(np.percentile(attft, 50)),
        "attft_p99_us": float(np.percentile(attft, 99)),
        "attft_p999_us": float(np.percentile(attft, 99.9)),
        "pauses": s.pauses,
        "demoted": s.demoted_pages, "repointed": s.repointed_pages,
        "streamed": s.streamed_pages, "flushed": s.flushed_pages,
        "fences": s.fence_summary(),
    }


def serve_qps(rows):
    """``bench: serve_qps`` — zero-restore vs bulk restore at high QPS."""
    art = {}
    speedups = []
    for arch, n, slots, seed in STREAMS:
        cfg = reduced(ARCHS[arch])
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        arrivals, prompts = _make_stream(cfg.vocab, n, seed)
        zero = _run(params, cfg, arrivals, prompts, slots, True)
        bulk = _run(params, cfg, arrivals, prompts, slots, False)
        assert zero["done"] == bulk["done"] == n, \
            f"{arch}: stream did not complete ({zero['done']}/{bulk['done']})"
        speedup = bulk["sim_time_us"] / zero["sim_time_us"]
        speedups.append(speedup)
        art[arch] = {"zero": zero, "bulk": bulk, "speedup": speedup}
        for mode, r in (("zero", zero), ("bulk", bulk)):
            emit(rows, f"serve_qps/{arch}/{mode}",
                 r["sim_time_us"] / max(r["tokens"], 1),
                 tok_s_sim=round(r["tok_s_sim"]),
                 attft_p50_us=round(r["attft_p50_us"], 1),
                 attft_p99_us=round(r["attft_p99_us"], 1),
                 attft_p999_us=round(r["attft_p999_us"], 1),
                 repointed=r["repointed"], streamed=r["streamed"])
    # gated key: deterministic sim-time speedup, geomean across archs
    art["tokens_per_s"] = float(math.exp(np.mean(np.log(speedups))))
    emit(rows, "serve_qps/speedup", 0.0,
         tokens_per_s=round(art["tokens_per_s"], 3))
    return art
