"""Trace-driven workload benchmarks with SLO-grade metrics (ROADMAP item 5).

One bench per workload class from ``repro.data.workloads``: YCSB A-D,
the ML-training working-set trace, and the mixed-tenant combination over a
``HostMemoryCoordinator`` slab.  Every metric written into
``bench_results.json`` is **deterministic simulated microseconds** (seeded
traces, seeded stores, the ``LatencyReservoir`` percentiles) — two runs
produce identical artifacts, which is what lets ``check_regression`` gate
``ycsb_a/hit_ratio``, ``ml_trace/speedup`` and
``mixed_tenant_workload/fairness`` without runner-noise margins.

SLO-grade metrics per run (``fidelity_report.py`` renders the matrix):

* per-workload hit ratio (local/remote/host/cold),
* p50 / p99 / p999 critical-path latency (reservoir percentiles),
* throughput per GB of slab (ops/s per GB at the paper's 4 KiB pages),
* Jain fairness across tenants for the mixed-tenant case.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import drive_arrays, emit, latency_summary
from benchmarks.paper_tables import _config, _populate
from repro.core import TieredPageStore, OrchestrationConfig, POLICIES, \
    PAPER_COSTS, InvariantChecker
from repro.data.workloads import (MLTraceConfig, MixedTenantConfig,
                                  YCSBConfig, interleave_tenants,
                                  mixed_tenant_traces, ml_trace,
                                  phase_segments, tenant_lifetimes,
                                  ycsb_trace)

PAGE_KIB = 4                      # the paper's 4 KiB page
_GIB_PAGES = (1 << 30) // (PAGE_KIB << 10)    # pages per GB of slab


def _slab_gb(pool_pages: int) -> float:
    return pool_pages / _GIB_PAGES


def _jain(xs) -> float:
    xs = list(xs)
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))


def _run_trace(trace, *, pool, async_mode, peers=6, blocks=1024, seed=0,
               tick_every=256, batch=256):
    """Replay one workload trace; return its SLO metric dict.

    The page space is fully populated first (the measured phase never pays
    first-touch cold writes), then the hit counters and the latency
    reservoir are reset so every reported number covers only the measured
    ops.  Async runs re-check the full ``InvariantChecker`` — a tail earned
    by dropping writes fails here, not ships.
    """
    st = TieredPageStore.from_config(
        _config("valet", pool=pool, min_pool=pool, peers=peers,
                blocks=blocks, seed=seed, async_mode=async_mode))
    _populate(st, trace.n_pages)
    st.drain()
    s = st.stats
    s.lat.reset()
    s.local_hits = s.remote_hits = s.host_hits = s.cold_hits = 0
    t0 = s.time_us
    drive_arrays(st, trace.pages, trace.is_write, tick_every, batch)
    if async_mode:
        InvariantChecker(st).check()
    sim_us = s.time_us - t0
    lat = latency_summary(s)
    hr = s.hit_ratio()
    thr = len(trace) / max(sim_us / 1e6, 1e-12)      # ops per simulated s
    return {
        "ops": len(trace), "sim_us": sim_us,
        "hit_local": hr["local"], "hit_remote": hr["remote"],
        "hit_host": hr["host"], "hit_cold": hr["cold"],
        "p50_us": lat["p50_us"], "p99_us": lat["p99_us"],
        "p999_us": lat["p999_us"],
        "throughput_per_gb": thr / _slab_gb(pool),
        "write_stall_us": s.write_stall_us,
    }


# -- YCSB-style key-value mixes (hotset rotation, sync + async) ---------------

def _ycsb(rows, letter: str, *, pool=512, n_pages=2048, n_ops=24_000,
          seed=7):
    trace = ycsb_trace(YCSBConfig(letter, n_pages=n_pages, n_ops=n_ops,
                                  seed=seed))
    sync = _run_trace(trace, pool=pool, async_mode=False)
    asy = _run_trace(trace, pool=pool, async_mode=True)
    art = {
        "workload": letter, "pool": pool, "n_pages": n_pages,
        # gated key (issue: ``ycsb_hit_ratio``): the sync run's local hit
        # ratio — deterministic, moves only when orchestration or the
        # trace shape changes
        "hit_ratio": sync["hit_local"],
        "async_p99_speedup": sync["p99_us"] / max(asy["p99_us"], 1e-9),
        "sync": sync, "async": asy,
    }
    name = f"ycsb_{letter.lower()}"
    emit(rows, f"{name}/sync", sync["p99_us"],
         hit_local=round(sync["hit_local"], 4),
         p999_us=round(sync["p999_us"], 2),
         thr_per_gb=round(sync["throughput_per_gb"]))
    emit(rows, f"{name}/async", asy["p99_us"],
         p999_us=round(asy["p999_us"], 2),
         speedup=round(art["async_p99_speedup"], 2))
    return art


def ycsb_a(rows):
    """``bench: ycsb_a`` — update-heavy 50/50 mix, hotset rotation."""
    return _ycsb(rows, "A")


def ycsb_b(rows):
    """``bench: ycsb_b`` — read-mostly 95/5 mix, hotset rotation."""
    return _ycsb(rows, "B")


def ycsb_c(rows):
    """``bench: ycsb_c`` — read-only mix, hotset rotation."""
    return _ycsb(rows, "C")


def ycsb_d(rows):
    """``bench: ycsb_d`` — latest-skewed reads over a growing keyspace."""
    return _ycsb(rows, "D")


# -- ML-training working-set trace --------------------------------------------

def ml_trace_bench(rows):
    """``bench: ml_trace`` — layer activations cycling through the pool.

    The forward sweep's writes oversubscribe the pool ~4x, so early layers
    spill remote mid-forward and the backward sweep pays the remote-read
    tail; the tracked ``speedup`` (issue: ``ml_trace_speedup``) is the
    sync/async ratio of end-to-end simulated critical-path time — the async
    daemon absorbs the inline flush stalls the sync store pays at every
    pool-full boundary.  Deterministic simulated us, like ``tail_latency``.
    """
    cfg = MLTraceConfig(arch="granite-3-8b", n_steps=3, total_pages=2048,
                        seed=7)
    trace = ml_trace(cfg)
    pool = 512
    sync = _run_trace(trace, pool=pool, async_mode=False)
    asy = _run_trace(trace, pool=pool, async_mode=True)
    art = {
        "arch": cfg.arch, "pool": pool, "n_pages": trace.n_pages,
        "speedup": sync["sim_us"] / max(asy["sim_us"], 1e-9),
        "async_p99_speedup": sync["p99_us"] / max(asy["p99_us"], 1e-9),
        "sync": sync, "async": asy,
    }
    emit(rows, "ml_trace/sync", sync["sim_us"] / len(trace),
         p99_us=round(sync["p99_us"], 2), p999_us=round(sync["p999_us"], 2),
         hit_local=round(sync["hit_local"], 4))
    emit(rows, "ml_trace/async", asy["sim_us"] / len(trace),
         p99_us=round(asy["p99_us"], 2), speedup=round(art["speedup"], 2),
         thr_per_gb=round(asy["throughput_per_gb"]))
    return art


# -- Mixed tenants on one coordinated slab ------------------------------------

def mixed_tenant_workload(rows):
    """``bench: mixed_tenant_workload`` — KV + ML tenants on one slab.

    2 YCSB tenants (B read-mostly, A update-heavy) and 1 ML tenant share a
    host slab with phase-staggered demand (tenant t is hot in phase t, the
    others trickle or idle — see ``MixedTenantConfig``): coordinated
    (``HostMemoryCoordinator``) vs static equal partitioning of the same
    slab.  The tracked ``fairness`` (issue: ``mixed_tenant_fairness``) is
    Jain's index over the per-tenant coordinated-vs-static speedups — a
    coordinator that fed the bursty ML tenant by starving the KV tenants
    would crater it.  All simulated us.
    """
    from repro.core.coordinator import HostMemoryCoordinator

    cfg = MixedTenantConfig()
    traces = mixed_tenant_traces(cfg)
    segments = [phase_segments(tr) for tr in traces]
    n_tenants = len(traces)
    n_phases = len(segments[0])
    total = 1536                   # shared slab (pages); oversubscribed:
    static_share = total // n_tenants        # hot working sets 2-4x share
    min_pool = 64

    def run(coordinated):
        coord = HostMemoryCoordinator(total) if coordinated else None
        stores = []
        for t, trace in enumerate(traces):
            if coordinated:
                st = TieredPageStore.from_config(OrchestrationConfig(
                    policy=POLICIES["valet"], costs=PAPER_COSTS,
                    pool_capacity=total, min_pool=min_pool,
                    max_pool=total - (n_tenants - 1) * min_pool,
                    n_peers=4, peer_capacity_blocks=2048,
                    pages_per_block=16, seed=t, grow_step=128,
                    coordinator=coord, container_name=trace.name))
            else:
                st = TieredPageStore.from_config(OrchestrationConfig(
                    policy=POLICIES["valet"], costs=PAPER_COSTS,
                    pool_capacity=static_share, min_pool=static_share,
                    max_pool=static_share, n_peers=4,
                    peer_capacity_blocks=2048, pages_per_block=16, seed=t))
            stores.append(st)

        def rr_drive(arrays):
            # arrays: per-tenant (pages, is_write, start, end) for one phase
            sched = interleave_tenants([end - start
                                        for _, _, start, end in arrays],
                                       cfg.slice_ops)
            for t, i, end in sched:
                pages, is_write, start, _ = arrays[t]
                stores[t].access_batch(pages[start + i:start + end],
                                       is_write[start + i:start + end])
                stores[t].background_tick()

        # populate every tenant's page space so the measured phases never
        # pay first-touch cold reads
        rr_drive([(np.arange(tr.n_pages, dtype=np.int64),
                   np.ones(tr.n_pages, bool), 0, tr.n_pages)
                  for tr in traces])
        for st in stores:
            st.drain()
            st.stats.lat.reset()
            st.stats.local_hits = st.stats.remote_hits = 0
            st.stats.host_hits = st.stats.cold_hits = 0
        t0 = [st.stats.time_us for st in stores]
        for ph in range(n_phases):
            rr_drive([(tr.pages, tr.is_write, *segments[t][ph])
                      for t, tr in enumerate(traces)])
        if coord is not None:
            coord.check_invariants()
        per_us = [st.stats.time_us - t0[t] for t, st in enumerate(stores)]
        per = []
        for t, st in enumerate(stores):
            lat = latency_summary(st.stats)
            hr = st.stats.hit_ratio()
            per.append({"tenant": traces[t].name, "sim_us": per_us[t],
                        "hit_local": hr["local"],
                        "p50_us": lat["p50_us"], "p99_us": lat["p99_us"],
                        "p999_us": lat["p999_us"]})
        return per_us, per

    static_us, static_per = run(coordinated=False)
    coord_us, coord_per = run(coordinated=True)

    per_speedup = [s / c for s, c in zip(static_us, coord_us)]
    total_ops = sum(len(tr) for tr in traces)
    thr_per_gb = (total_ops / max(sum(coord_us) / 1e6, 1e-12)
                  / _slab_gb(total))
    art = {
        "tenants": [tr.name for tr in traces],
        "slab_pages": total, "static_share": static_share,
        "speedup": sum(static_us) / sum(coord_us),
        # gated key (issue: ``mixed_tenant_fairness``)
        "fairness": _jain(per_speedup),
        "per_tenant_speedup": per_speedup,
        "throughput_per_gb": thr_per_gb,
        "static": static_per, "coordinated": coord_per,
    }
    emit(rows, "mixed_tenant_workload/static", sum(static_us) / 1e3)
    emit(rows, "mixed_tenant_workload/coordinated", sum(coord_us) / 1e3,
         speedup=round(art["speedup"], 2),
         fairness=round(art["fairness"], 3),
         thr_per_gb=round(thr_per_gb))
    art["churn"] = _mixed_tenant_churn(rows)
    return art


def _mixed_tenant_churn(rows):
    """Tenant-churn sub-run (ROADMAP item 5 follow-up, reported not gated):
    the same coordinated slab plus one churn KV tenant that registers with
    the coordinator when its lifetime window opens and deregisters (whole
    lease, floor included, back to the slab) when it closes.  Asserts op
    conservation — every tenant drives exactly its trace, churn included —
    and the coordinator's slab-conservation invariants after the leave."""
    from repro.core.coordinator import HostMemoryCoordinator

    cfg = MixedTenantConfig(churn_kv=(
        YCSBConfig("A", n_pages=512, n_ops=6_000, seed=21),))
    traces = mixed_tenant_traces(cfg)
    segments = [phase_segments(tr) for tr in traces]
    lifetimes = tenant_lifetimes(cfg)
    n_tenants = len(traces)
    n_phases = len(segments[0])
    total = 1536
    min_pool = 64
    max_pool = total - (n_tenants - 1) * min_pool

    coord = HostMemoryCoordinator(total)
    stores = [None] * n_tenants
    driven = [0] * n_tenants
    t0 = [0.0] * n_tenants
    sim_us = [0.0] * n_tenants

    def admit(t):
        st = TieredPageStore.from_config(OrchestrationConfig(
            policy=POLICIES["valet"], costs=PAPER_COSTS,
            pool_capacity=total, min_pool=min_pool, max_pool=max_pool,
            n_peers=4, peer_capacity_blocks=2048, pages_per_block=16,
            seed=t, grow_step=128, coordinator=coord,
            container_name=traces[t].name))
        # pre-touch the tenant's page space so its measured slices never
        # pay first-touch cold reads, then reset the measured window
        n = traces[t].n_pages
        st.access_batch(np.arange(n, dtype=np.int64), np.ones(n, bool))
        st.background_tick()
        st.drain()
        st.stats.lat.reset()
        stores[t] = st
        t0[t] = st.stats.time_us

    def retire(t):
        st = stores[t]
        st.drain()
        sim_us[t] = st.stats.time_us - t0[t]
        coord.deregister(st._lease.cid)
        stores[t] = None

    for ph in range(n_phases):
        for t in range(n_tenants):
            if stores[t] is None and lifetimes[t][0] == ph:
                admit(t)
        live = [t for t in range(n_tenants) if stores[t] is not None]
        arrs = [(t, *segments[t][ph]) for t in live]
        sched = interleave_tenants([end - start for _, start, end in arrs],
                                   cfg.slice_ops)
        for k, i, j in sched:
            t, start, _ = arrs[k]
            tr = traces[t]
            stores[t].access_batch(tr.pages[start + i:start + j],
                                   tr.is_write[start + i:start + j])
            stores[t].background_tick()
            driven[t] += j - i
        for t in range(n_tenants):
            if stores[t] is not None and lifetimes[t][1] == ph + 1:
                retire(t)
    for t in range(n_tenants):
        if stores[t] is not None:
            retire(t)

    # op conservation: churn included, every tenant drove its whole trace
    for t, tr in enumerate(traces):
        assert driven[t] == len(tr), \
            f"tenant {tr.name}: drove {driven[t]} of {len(tr)} ops"
    coord.check_invariants()
    assert coord.stats.n_deregistrations == n_tenants, \
        "every tenant must have deregistered cleanly"

    thr = [len(tr) / max(sim_us[t], 1e-9) for t, tr in enumerate(traces)]
    n_base = n_tenants - len(cfg.churn_kv)
    art = {
        "tenants": [tr.name for tr in traces],
        "lifetimes": [list(lt) for lt in lifetimes],
        "ops": driven,
        "per_tenant_sim_us": sim_us,
        # fairness across the full-run tenants (ops per simulated us);
        # the churn tenant's throughput is reported alongside
        "fairness_base": _jain(thr[:n_base]),
        "churn_throughput": thr[n_base:],
        "n_deregistrations": coord.stats.n_deregistrations,
    }
    emit(rows, "mixed_tenant_workload/churn", sum(sim_us) / 1e3,
         fairness_base=round(art["fairness_base"], 3),
         churn_ops=sum(driven[n_base:]))
    return art
