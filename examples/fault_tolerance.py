"""Fault tolerance end-to-end: async replicated checkpoints, replica
corruption, elastic-recovery planning, and peer-failure page recovery.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import os
import tempfile


import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS, reduced
from repro.core import TieredPageStore, POLICIES, PAPER_COSTS
from repro.data import DataConfig, TrainDataset
from repro.models import transformer as T
from repro.train import (TrainConfig, ValetCheckpointer, fit,
                         ClusterSpec, make_recovery_plan)


def main():
    cfg = reduced(ARCHS["phi3-mini-3.8b"])
    ctx = T.ParallelCtx(remat=False, q_block=16, kv_block=16, loss_chunk=16,
                        compute_dtype=jnp.float32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32,
                       adamw=optim.AdamWConfig(lr=1e-3, warmup_steps=5,
                                               total_steps=40))
    ds = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    with tempfile.TemporaryDirectory() as d:
        ckpt = ValetCheckpointer(d, replicas=2)

        # train 20 steps, checkpoint asynchronously (staging = critical path)
        params, opt, hist = fit(params, cfg, ctx, tcfg, ds, n_steps=20,
                                log_every=10)
        stage_s = ckpt.save(20, {"params": params})
        ckpt.wait()
        print(f"[ckpt] staged in {stage_s*1e3:.1f} ms "
              f"(writer replicates to 2 dirs in the background)")

        # corrupt the primary replica -> restore falls back (Table 3)
        r0 = os.path.join(d, "replica0", "step_00000020", "arrays.npz")
        open(r0, "wb").write(b"corrupted!")
        step, restored = ckpt.restore(tree_like={"params": params})
        ok = bool(jnp.allclose(restored["params"]["embed"],
                               params["embed"]))
        print(f"[ckpt] primary corrupted -> restored step {step} from "
              f"replica 1, exact={ok}")

        # resume training from the snapshot: the deterministic pipeline
        # replays the exact stream position
        ds2 = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8), start_step=20)
        _, _, hist2 = fit(restored["params"], cfg, ctx, tcfg, ds2,
                          n_steps=5, log_every=2)
        print(f"[resume] loss continues from {hist[-1]['loss']:.3f} -> "
              f"{hist2[-1]['loss']:.3f}")
        ckpt.close()

    # elastic: lose 37 of 512 devices -> recovery plan keeps TP=16
    spec = ClusterSpec(n_pods=2, data_parallel=16, model_parallel=16)
    plan = make_recovery_plan(spec, alive_devices=list(range(512 - 37)),
                              restore_step=20)
    m = plan["mesh"]
    print(f"[elastic] 512->{512-37} devices: new mesh pods={m.n_pods} "
          f"dp={m.data_parallel} tp={m.model_parallel} "
          f"({m.n_devices} used), resume at step {plan['restore_step']}")

    # remote peer failure: replicated pages recover without data loss
    store = TieredPageStore(POLICIES["valet"], PAPER_COSTS,
                            pool_capacity=256, min_pool=32,
                            n_peers=6, peer_capacity_blocks=128,
                            pages_per_block=16)
    for p in range(1000):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(2)
    print(f"[peer-failure] peer 2 died: {recovered} pages repointed to "
          f"replicas, {lost} lost")


if __name__ == "__main__":
    main()
