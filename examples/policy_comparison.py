"""Compare Valet against the paper's baselines end-to-end.

    PYTHONPATH=src python examples/policy_comparison.py

Serves the same request stream with valet / infiniswap / os-swap under a
pool that fits only ~25% of the KV working set, and prints the paper's
headline comparison (completion time + behaviour counters).  All policies
produce identical tokens; they differ in what memory pressure costs.
"""
import numpy as np

import jax

from repro.configs import ARCHS, reduced
from repro.core.policies import POLICIES
from repro.models import transformer as T
from repro.serve import ValetServeEngine


def main():
    cfg = reduced(ARCHS["granite-3-8b"])
    ctx = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(6)]

    results = {}
    for policy in ("valet", "infiniswap", "os-swap"):
        eng = ValetServeEngine(params, cfg, ctx, max_batch=3, max_seq=64,
                               page=4, pool_slots=10,
                               policy=POLICIES[policy])
        for p in prompts:
            eng.submit(p, max_new=12)
        reqs = eng.run(max_steps=500)
        outs = [r.tokens_out for r in sorted(reqs, key=lambda r: r.rid)]
        results[policy] = (outs, eng.stats)

    ref = results["valet"][0]
    print(f"{'policy':12s} {'sim ms':>10s} {'pauses':>7s} {'spill':>6s} "
          f"{'restore':>8s} {'recompute':>9s} {'exact':>6s}")
    for policy, (outs, s) in results.items():
        print(f"{policy:12s} {s.sim_time_us/1e3:10.2f} {s.pauses:7d} "
              f"{s.spilled_pages:6d} {s.restored_pages:8d} "
              f"{s.recomputes:9d} {str(outs == ref):>6s}")
    v = results["valet"][1].sim_time_us
    i = results["infiniswap"][1].sim_time_us
    print(f"\nValet speedup over delete-eviction remote paging: {i/v:.1f}x")


if __name__ == "__main__":
    main()
