"""Quickstart: train a small LM and serve it through the Valet engine.

    PYTHONPATH=src python examples/quickstart.py

Runs on CPU in ~2 minutes: 30 training steps on the synthetic copy task,
then generation under memory pressure with the Valet policy (outputs are
identical to a pressure-free engine — the point of the paper).
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS, reduced
from repro.core.policies import POLICIES
from repro.data import DataConfig, TrainDataset
from repro.models import transformer as T
from repro.serve import ValetServeEngine
from repro.train import TrainConfig, fit


def main():
    cfg = reduced(ARCHS["gemma3-4b"])          # tiny same-family config
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab}")

    ctx = T.ParallelCtx(remat=False, q_block=16, kv_block=16, loss_chunk=16,
                        compute_dtype=jnp.float32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    # -- train ---------------------------------------------------------------
    tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32,
                       adamw=optim.AdamWConfig(lr=1e-3, warmup_steps=5,
                                               total_steps=40))
    ds = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
    params, _, hist = fit(params, cfg, ctx, tcfg, ds, n_steps=30,
                          log_every=10)
    for h in hist:
        print(f"step {h['step']:3d}  loss {h['loss']:.3f}")

    # -- serve under memory pressure ------------------------------------------
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(4)]

    def generate(pool_slots):
        eng = ValetServeEngine(params, cfg, ctx, max_batch=2, max_seq=48,
                               page=4, pool_slots=pool_slots,
                               policy=POLICIES["valet"])
        for p in prompts:
            eng.submit(p, max_new=8)
        reqs = eng.run()
        return ([r.tokens_out for r in sorted(reqs, key=lambda r: r.rid)],
                eng.stats)

    full, _ = generate(pool_slots=64)          # everything fits
    tight, stats = generate(pool_slots=5)      # ~25% working-set fit
    print(f"\npool pressure: pauses={stats.pauses} "
          f"spilled={stats.spilled_pages} restored={stats.restored_pages}")
    print("outputs identical under pressure:", full == tight)
    for i, toks in enumerate(tight):
        print(f"  req{i}: {toks}")


if __name__ == "__main__":
    main()
