"""Valet reproduction: orchestration of host and remote shared memory for
memory-intensive workloads (MemSys '20).

The stable public API surface:

* ``OrchestrationConfig`` — one frozen config object for every knob
* ``TieredPageStore`` — the tiered (HBM/peer/host/cold) page store
* ``ValetServeEngine`` — the paged-KV serving engine built on it
* ``HostMemoryCoordinator`` — §3.4 multi-container host memory sharing

Construct stores/engines via ``.from_config(...)``; the sprawling keyword
constructors remain as deprecated aliases.
"""
from repro.core.config import OrchestrationConfig
from repro.core.coordinator import HostMemoryCoordinator
from repro.core.tiering import TieredPageStore
from repro.serve.engine import ValetServeEngine

__all__ = [
    "OrchestrationConfig",
    "TieredPageStore",
    "ValetServeEngine",
    "HostMemoryCoordinator",
]
