"""Config registry: ``get_arch(name)``, ``ARCHS``, ``SHAPES``."""
from repro.configs.base import (
    ArchConfig, MoEConfig, SSMConfig, ShapeConfig,
    SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    shape_applicable, reduced, replace,
)

from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.qwen2_moe_a27b import CONFIG as _qwen2
from repro.configs.mamba2_27b import CONFIG as _mamba2
from repro.configs.hymba_15b import CONFIG as _hymba
from repro.configs.gemma3_4b import CONFIG as _gemma3
from repro.configs.phi3_mini_38b import CONFIG as _phi3
from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.llama32_vision_11b import CONFIG as _llama_vision
from repro.configs.whisper_large_v3 import CONFIG as _whisper

ARCHS = {
    c.name: c
    for c in (
        _deepseek, _qwen2, _mamba2, _hymba, _gemma3,
        _phi3, _granite, _danube, _llama_vision, _whisper,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """Yield every (arch, shape, applicable, skip_reason) cell — 40 total."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = shape_applicable(arch, shape)
            yield arch, shape, ok, why
