"""Architecture & shape configuration for the repro framework.

Every assigned architecture is expressed as an ``ArchConfig``; every
benchmark shape as a ``ShapeConfig``.  Configs are plain frozen dataclasses so
they are hashable (usable as jit static args) and serializable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    n_experts: int                 # routed experts
    top_k: int                     # routed experts per token
    n_shared: int = 0              # always-on shared experts
    d_expert: int = 0              # per-expert FFN hidden size
    capacity_factor: float = 1.25  # per-rank dispatch capacity multiplier
    router_aux_coef: float = 0.01  # load-balance aux loss coefficient
    router_z_coef: float = 1e-3    # router z-loss coefficient
    renorm_topk: bool = False      # renormalize top-k gates to sum to 1


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class ArchConfig:
    """A full architecture description (one per assigned arch)."""
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # Attention pattern ------------------------------------------------
    window: int = 0                # 0 = full attention; >0 = sliding window
    global_every: int = 0          # e.g. 6 -> layers (i+1) % 6 == 0 are global
    rope_theta: float = 10_000.0

    # Optional blocks ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    n_dense_layers: int = 0        # leading dense-FFN layers in MoE archs
    dense_d_ff: int = 0            # their FFN width

    # Cross-modal -------------------------------------------------------
    xattn_every: int = 0           # vlm: cross-attention every k-th layer
    n_frontend_tokens: int = 0     # vlm patches / audio frames (stub input)
    encoder_layers: int = 0        # audio (enc-dec): encoder depth

    # Misc ---------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    notes: str = ""

    # Derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 so embedding tables shard over any TP degree.

        Logits beyond ``vocab`` are masked in the loss/sampler; parameter
        counts use the true vocab."""
        return -(-self.vocab // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_subquadratic(self) -> bool:
        """True if the decode working set is bounded (SSM / SWA / hybrid)."""
        if self.family == "ssm":
            return True
        if self.window > 0:          # sliding window bounds most/all layers
            return True
        return False

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step.  All assigned archs decode."""
        return True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab
        n = v * d                                   # embed
        if not self.tie_embeddings:
            n += v * d                              # unembed
        hd = self.resolved_head_dim
        for layer in range(self.n_layers):
            if self.family != "ssm":
                # attention
                n += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                n += (self.n_heads * hd) * d
            if self.ssm is not None:
                d_in = self.ssm.expand * d
                n += d * (2 * d_in + 2 * self.ssm.n_groups * self.ssm.d_state)
                n += d_in * d + d_in * self.ssm.conv_kernel
            if self.moe is not None and layer >= self.n_dense_layers:
                e = self.moe.n_experts + self.moe.n_shared
                n += e * 3 * d * self.moe.d_expert
                n += d * self.moe.n_experts        # router
            elif self.family in ("dense", "hybrid", "vlm", "audio") or (
                self.moe is not None and layer < self.n_dense_layers
            ):
                ff = self.dense_d_ff if (self.moe is not None and layer < self.n_dense_layers) else self.d_ff
                if ff:
                    n += 3 * d * ff                # SwiGLU
            n += 2 * d                             # norms
        if self.xattn_every:
            n_x = self.n_layers // self.xattn_every
            n += n_x * (2 * d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd))
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                n += 4 * d * (self.n_heads * hd) + 3 * d * self.d_ff + 2 * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_moe_layers = self.n_layers - self.n_dense_layers
        all_experts = (self.moe.n_experts + self.moe.n_shared) * 3 * d * self.moe.d_expert
        active_experts = (self.moe.top_k + self.moe.n_shared) * 3 * d * self.moe.d_expert
        return total - n_moe_layers * (all_experts - active_experts)


@dataclass(frozen=True)
class ShapeConfig:
    """A benchmark input shape (one per assigned shape)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned shapes -------------------------------------------------
TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the brief.

    ``long_500k`` needs a sub-quadratic decode working set: run for SSM /
    hybrid / sliding-window archs, skip for pure full-attention archs.
    """
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "pure full-attention arch: 500k decode working set unbounded (skip per brief)"
    if shape.is_decode and not arch.has_decode:
        return False, "encoder-only arch has no decode step"
    return True, ""


def reduced(arch: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=arch.name + "-smoke",
        family=arch.family,
        n_layers=2,
        d_model=64,
        n_heads=4 if arch.n_heads else 0,
        n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads else 0,
        d_ff=128,
        vocab=256,
        head_dim=16 if arch.n_heads else 0,
        window=min(arch.window, 16) if arch.window else 0,
        global_every=arch.global_every if arch.global_every else 0,
        rope_theta=arch.rope_theta,
        n_dense_layers=min(arch.n_dense_layers, 1),
        dense_d_ff=128 if arch.dense_d_ff else 0,
        xattn_every=2 if arch.xattn_every else 0,
        n_frontend_tokens=8 if arch.n_frontend_tokens else 0,
        encoder_layers=2 if arch.encoder_layers else 0,
        tie_embeddings=arch.tie_embeddings,
    )
    if arch.moe is not None:
        # high capacity factor -> no token drops -> smoke tests are exact
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, n_shared=min(arch.moe.n_shared, 1),
                              d_expert=32, capacity_factor=8.0,
                              renorm_topk=arch.moe.renorm_topk)
    if arch.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, head_dim=16, expand=2, conv_kernel=4,
                              chunk_size=8, n_groups=1)
    if arch.global_every:
        kw["global_every"] = arch.global_every
    return ArchConfig(**kw)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
