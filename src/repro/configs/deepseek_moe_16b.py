"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf]  28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400.  First layer uses a dense FFN (as in the release).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    n_dense_layers=1,
    dense_d_ff=10_944,
    rope_theta=10_000.0,
    notes="fine-grained expert segmentation; shared expert isolation",
)
