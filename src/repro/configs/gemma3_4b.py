"""gemma3-4b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10_240,
    vocab=262_144,
    head_dim=256,
    window=1024,
    global_every=6,        # every 6th layer is global full-attention
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    notes="5:1 local(sliding-1024):global; huge vocab stresses embedding sharding",
)
