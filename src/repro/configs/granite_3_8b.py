"""granite-3-8b — dense GQA.

[hf:ibm-granite/granite-3.0-2b-base; hf]  40L d_model=4096 32H (GQA kv=8)
d_ff=12800 vocab=49155.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12_800,
    vocab=49_155,
    head_dim=128,
    rope_theta=10_000.0,
    notes="largest dense arch in the pool; heaviest KV per token",
)
