"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10_240,
    vocab=32_000,
    head_dim=120,
    window=4096,           # mistral-style SWA on all layers
    rope_theta=10_000.0,
    notes="SWA bounds decode KV -> long_500k runnable with ring buffers",
)
