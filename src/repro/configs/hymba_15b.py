"""hymba-1.5b — hybrid-head: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001 ssm_state=16.  Most layers use sliding-window attention;
layers {0, mid, last} are global (per the paper).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    head_dim=64,
    window=1024,           # SWA layers; global layers tracked separately
    global_every=16,       # layers 15, 31 global (+ layer 0 special-cased)
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256, n_groups=1),
    notes="parallel attn+SSM heads, outputs mean-combined after per-branch norm",
)
