"""llama-3.2-vision-11b — LM backbone with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256.  The vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=128_256,
    head_dim=128,
    xattn_every=5,             # cross-attention every 5th layer (8 layers)
    n_frontend_tokens=6656,    # 4 tiles x 1601 patches, padded to 512-multiple
    rope_theta=500_000.0,
    notes="cross-attn KV is static per request -> lives in a pinned pool region",
)
