"""mamba2-2.7b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  64L d_model=2560 ssm_state=128.
d_inner = expand*d_model = 5120, head_dim 64 -> 80 SSD heads.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256, n_groups=1),
    notes="attention-free; Valet KV paging inapplicable (O(1) decode state); "
          "pool reused for SSD chunk-state checkpoints in prefill",
)
