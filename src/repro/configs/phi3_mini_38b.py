"""phi3-mini-3.8b — dense MHA, RoPE SwiGLU.

[arXiv:2404.14219; unverified]  32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_064,
    head_dim=96,
    rope_theta=10_000.0,
    notes="pure full attention (MHA)",
)
