"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (GQA kv=16)
d_ff=1408 vocab=151936.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151_936,
    head_dim=128,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_expert=1408,
                  renorm_topk=True),
    rope_theta=1_000_000.0,
    notes="shared-expert MoE, upcycled from dense",
)
