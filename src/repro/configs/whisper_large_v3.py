"""whisper-large-v3 — encoder-decoder; conv frontend is a STUB.

[arXiv:2212.04356; unverified]  32L d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866.  ``input_specs()`` provides precomputed frame
embeddings (1500 frames) in place of the mel+conv frontend.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,               # decoder depth
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    head_dim=64,
    n_frontend_tokens=1536,    # encoder frames (stub, padded to 512-multiple)
    rope_theta=0.0,            # whisper uses learned/sinusoidal positions
    notes="enc-dec: decoder self-KV paged; cross-KV static per request",
)
