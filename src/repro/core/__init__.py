"""Valet core: host/remote shared-memory orchestration (the paper's
contribution), adapted to the TPU memory hierarchy.  See DESIGN.md §2-§4."""
from repro.core.pool import ValetMempool, SlotState
from repro.core.coordinator import (HostMemoryCoordinator, LeaseClient,
                                    ContainerRecord, CoordinatorStats)
from repro.core.queues import WritePipeline, StagingQueue, ReclaimableQueue, WriteSet
from repro.core.page_table import GlobalPageTable, Location, Tier
from repro.core.activity import (ActivityTracker, select_victims_nad,
                                 select_victims_mass, select_victims_random,
                                 select_victims_topk, power_of_two_choices)
from repro.core.migration import MigrationEngine, Migration, Phase
from repro.core.replication import (ReplicaPlacer, FaultConfig, fail_peer,
                                    fail_peer_batched)
from repro.core.faults import (HealthState, PeerHealth, RepairQueue,
                               FaultEvent, FaultInjector, transient_blip,
                               crash, correlated_crash, recovery_storm,
                               standard_schedule, random_schedule,
                               peers_in_domain, domain_correlated_crash,
                               domain_recovery_storm, cluster_schedule)
from repro.core.cluster import (ClusterCoordinator, ClusterStats,
                                ClusterInvariantChecker, HostRecord,
                                HostState, PeerProfile, draw_peer_profiles,
                                profile_domains)
from repro.core.policies import (Policy, CostModel, POLICIES, VALET,
                                 VALET_MASS, INFINISWAP, NBDX, OS_SWAP,
                                 PAPER_COSTS, TPU_COSTS)
from repro.core.tiering import TieredPageStore, PeerState, Stats
from repro.core.tiers import PageTier, DeviceTier, HostTier
from repro.core.config import (OrchestrationConfig, config_from_legacy_kwargs,
                               LEGACY_STORE_KWARGS, LEGACY_SERVE_KWARGS)
from repro.core.async_engine import AsyncOrchestrator, DaemonClock
from repro.core.invariants import (InvariantChecker, InvariantError,
                                   stats_close, stats_delta)
from repro.core.reservoir import LatencyReservoir, LatencyStatsMixin
from repro.core import device_ops
