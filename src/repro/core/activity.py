"""Activity tracking + victim selection (paper §3.5).

``Non-Activity-Duration = now - last_write_activity`` per MR block; the
eviction victim is the block with the longest duration — likely in its idle
phase of the write->read->idle activity cycle the paper observes.  No
queries to sender nodes are needed: the timestamp tag lives with the block.

Two schemes:

* ``select_victims_nad`` — the paper's, on write timestamps.
* ``select_victims_mass`` — beyond-paper: for KV pages, "activity" can be the
  *attention mass* a page received recently (free from the flash-decode
  partials).  Same interface, better victims for read-heavy KV workloads.

``select_victims_topk`` is the batched fast path: an ``argpartition`` top-k
over the tracker's dense arrays that returns exactly the same victims (same
order, same tie-breaks) as ``select_victims_nad`` without a full sort.

Plus power-of-two-choices peer selection (§2.1 / §4.3) for placement and
migration destinations.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


class ActivityTracker:
    """Per-block last-activity timestamps + optional attention-mass EMA.

    Dense-array backed: block ids index straight into grow-on-demand numpy
    arrays, so a whole candidate set's Non-Activity-Durations come from one
    vectorized gather (``nad``) instead of per-block dict probes — the
    enabling piece of the batched victim-selection path.  The paper's
    per-block metadata tag is exactly this: a timestamp updated on write.
    """

    def __init__(self, n_blocks: int = 0, mass_decay: float = 0.9):
        cap = max(int(n_blocks), 1024)
        self._last = np.zeros(cap, np.int64)
        self._mass: Optional[np.ndarray] = None   # lazily allocated
        self.mass_decay = mass_decay
        self._mass_age = 0

    def _ensure(self, max_id: int):
        """Grow the dense arrays to cover ``max_id`` (geometric growth)."""
        n = self._last.shape[0]
        if max_id < n:
            return
        new = max(n * 2, max_id + 1)
        grown = np.zeros(new, np.int64)
        grown[:n] = self._last
        self._last = grown
        if self._mass is not None:
            gm = np.zeros(new, np.float64)
            gm[:n] = self._mass
            self._mass = gm

    def on_write(self, blocks: Sequence[int], step: int):
        b = np.asarray(blocks, np.int64)
        if b.size == 0:
            return
        self._ensure(int(b.max()))
        self._last[b] = step

    def touch(self, block: int, step: int):
        """Single-block ``on_write`` (hot path helper)."""
        block = int(block)
        self._ensure(block)
        self._last[block] = step

    def on_write_at(self, blocks: Sequence[int], steps: Sequence[int]):
        """Scatter per-block write timestamps (blocks must be unique)."""
        b = np.asarray(blocks, np.int64)
        if b.size == 0:
            return
        self._ensure(int(b.max()))
        self._last[b] = np.asarray(steps, np.int64)

    def on_write_map(self, touch) -> None:
        """``on_write_at`` from a ``{block id: step}`` dict — the shape the
        bulk placement pass accumulates — without materializing two
        intermediate Python lists (one ``fromiter`` per array instead)."""
        n = len(touch)
        if not n:
            return
        b = np.fromiter(touch.keys(), np.int64, count=n)
        self._ensure(int(b.max()))
        self._last[b] = np.fromiter(touch.values(), np.int64, count=n)

    def on_read_mass(self, blocks: Sequence[int], mass: Sequence[float]):
        """Accumulate attention-mass observations (beyond-paper activity).

        Kept sequential: a block repeated within one call decays once per
        occurrence, like the original per-observation update."""
        self._mass_age += 1
        b = np.asarray(blocks, np.int64)
        if b.size == 0:
            return
        self._ensure(int(b.max()))
        if self._mass is None:
            self._mass = np.zeros(self._last.shape[0], np.float64)
        m_arr = self._mass
        decay = self.mass_decay
        for blk, m in zip(b.tolist(), mass):
            m_arr[blk] = m_arr[blk] * decay + float(m)

    def last(self, block: int) -> int:
        block = int(block)
        if block >= self._last.shape[0]:
            return 0
        return int(self._last[block])

    def nad(self, blocks: Sequence[int], step: int) -> np.ndarray:
        b = np.asarray(blocks, np.int64) if not isinstance(blocks, np.ndarray) \
            else blocks
        if b.size == 0:
            return np.empty(0, np.int64)
        self._ensure(int(b.max()))
        return step - self._last[b]

    def mass_of(self, blocks: Sequence[int]) -> np.ndarray:
        b = np.asarray(blocks, np.int64)
        if b.size == 0:
            return np.empty(0, np.float64)
        if self._mass is None:
            return np.zeros(b.size, np.float64)
        self._ensure(int(b.max()))
        return self._mass[b].astype(np.float64)


def select_victims_nad(tracker: ActivityTracker, candidates: Sequence[int],
                       n: int, step: int) -> List[int]:
    """Paper's activity-based victim selection: longest Non-Activity-Duration."""
    cand = np.asarray(candidates, np.int64)
    if cand.size == 0 or n <= 0:
        return []
    nad = tracker.nad(cand, step)
    order = np.argsort(-nad, kind="stable")
    return cand[order[:n]].tolist()


def select_victims_topk(tracker: ActivityTracker, candidates: Sequence[int],
                        n: int, step: int) -> List[int]:
    """Dense top-k victim selection: same result as ``select_victims_nad``
    (same victims, same order, same candidate-order tie-breaks) via
    ``argpartition`` instead of a full stable sort — O(C + k log k); accepts
    the dense candidate arrays ``peer_pressure`` now produces without a
    Python-list round trip."""
    cand = np.asarray(candidates, np.int64)
    if cand.size == 0 or n <= 0:
        return []
    neg = -tracker.nad(cand, step)
    if n >= cand.size:
        order = np.argsort(neg, kind="stable")
        return cand[order].tolist()
    kth = np.partition(neg, n - 1)[n - 1]
    strict = np.flatnonzero(neg < kth)
    ties = np.flatnonzero(neg == kth)[: n - strict.size]
    sel = np.concatenate([strict, ties])
    # stable argsort order == primary key neg ascending, ties by index
    sel = sel[np.lexsort((sel, neg[sel]))]
    return cand[sel].tolist()


def select_victims_mass(tracker: ActivityTracker, candidates: Sequence[int],
                        n: int, step: int) -> List[int]:
    """Beyond-paper: evict lowest recent attention mass (ties -> oldest)."""
    cand = np.asarray(candidates, np.int64)
    if cand.size == 0 or n <= 0:
        return []
    mass = tracker.mass_of(cand)
    nad = tracker.nad(cand, step)
    order = np.lexsort((-nad, mass))        # primary: low mass; tie: old
    return cand[order[:n]].tolist()


def select_victims_random(rng: np.random.Generator, candidates: Sequence[int],
                          n: int) -> List[int]:
    """Baseline (Infiniswap-like batched random selection, §6.5): same
    permutation draws as the list version, array-native candidates."""
    cand = np.asarray(candidates, np.int64)
    if not cand.size or n <= 0:
        return []
    idx = rng.permutation(cand.size)[:min(n, cand.size)]
    return cand[idx].tolist()


class PairSampler:
    """Buffered uniform distinct ordered pairs over ``range(k)``.

    ``power_of_two_choices`` needs one random peer pair per placement —
    once per flushed page on the remote-send path — and per-call Generator
    overhead dominates there.  Drawing a few thousand pairs at a time keeps
    the amortized cost near an array index.  Distribution is identical to
    the unbuffered two-draw scheme; only the stream consumption differs.

    ``draw_batch`` consumes exactly the pairs that the same number of
    sequential ``draw`` calls would (same buffer refill boundaries), so the
    batched flush path and the scalar reference stay on one pair stream.
    """

    def __init__(self, k: int, rng: np.random.Generator, buf: int = 4096):
        assert k >= 2
        self.k = k
        self.rng = rng
        self.buf = buf
        self._a = self._b = None
        self._i = 0

    def draw(self):
        if self._a is None or self._i >= self._a.shape[0]:
            self._a = self.rng.integers(0, self.k, size=self.buf)
            self._b = self.rng.integers(0, self.k - 1, size=self.buf)
            self._i = 0
        i = self._i
        self._i = i + 1
        a = int(self._a[i])
        b = int(self._b[i])
        if b >= a:
            b += 1
        return a, b

    def draw_batch(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized ``draw`` x n: returns (a, b) int arrays."""
        out_a = np.empty(n, np.int64)
        out_b = np.empty(n, np.int64)
        filled = 0
        while filled < n:
            if self._a is None or self._i >= self._a.shape[0]:
                self._a = self.rng.integers(0, self.k, size=self.buf)
                self._b = self.rng.integers(0, self.k - 1, size=self.buf)
                self._i = 0
            take = min(n - filled, self._a.shape[0] - self._i)
            out_a[filled:filled + take] = self._a[self._i:self._i + take]
            out_b[filled:filled + take] = self._b[self._i:self._i + take]
            self._i += take
            filled += take
        out_b[out_b >= out_a] += 1
        return out_a, out_b


def power_of_two_choices(free_counts: Sequence[int],
                         rng: np.random.Generator,
                         exclude: Sequence[int] = ()) -> Optional[int]:
    """Pick the freer of two random peers (paper §2.1, §4.3).

    The distinct pair is drawn with two ``integers`` draws (second index
    skips the first) — the same uniform ordered-pair distribution as
    ``rng.choice(k, 2, replace=False)`` at a fraction of its cost, which
    matters because placement runs once per flushed page.
    """
    if exclude:
        ex = set(exclude)
        peers = [i for i in range(len(free_counts)) if i not in ex]
    else:
        peers = list(range(len(free_counts)))
    if not peers:
        return None
    k = len(peers)
    if k == 1:
        return peers[0]
    a = int(rng.integers(k))
    b = int(rng.integers(k - 1))
    if b >= a:
        b += 1
    pa, pb = peers[a], peers[b]
    return pa if free_counts[pa] >= free_counts[pb] else pb
