"""Activity tracking + victim selection (paper §3.5).

``Non-Activity-Duration = now - last_write_activity`` per MR block; the
eviction victim is the block with the longest duration — likely in its idle
phase of the write->read->idle activity cycle the paper observes.  No
queries to sender nodes are needed: the timestamp tag lives with the block.

Two schemes:

* ``select_victims_nad`` — the paper's, on write timestamps.
* ``select_victims_mass`` — beyond-paper: for KV pages, "activity" can be the
  *attention mass* a page received recently (free from the flash-decode
  partials).  Same interface, better victims for read-heavy KV workloads.

Plus power-of-two-choices peer selection (§2.1 / §4.3) for placement and
migration destinations.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


class ActivityTracker:
    """Per-block last-activity timestamps + optional attention-mass EMA.

    Dict-backed: block ids are sparse (peer<<20 | slot).  The paper's
    per-block metadata tag is exactly this: a timestamp updated on write.
    """

    def __init__(self, n_blocks: int = 0, mass_decay: float = 0.9):
        self.last_activity: dict = {}
        self.mass: dict = {}
        self.mass_decay = mass_decay
        self._mass_age = 0

    def on_write(self, blocks: Sequence[int], step: int):
        for b in blocks:
            self.last_activity[int(b)] = step

    def on_read_mass(self, blocks: Sequence[int], mass: Sequence[float]):
        """Accumulate attention-mass observations (beyond-paper activity)."""
        self._mass_age += 1
        for b, m in zip(blocks, mass):
            b = int(b)
            self.mass[b] = self.mass.get(b, 0.0) * self.mass_decay + float(m)

    def last(self, block: int) -> int:
        return self.last_activity.get(int(block), 0)

    def nad(self, blocks: Sequence[int], step: int) -> np.ndarray:
        return np.array([step - self.last(b) for b in blocks], np.int64)

    def mass_of(self, blocks: Sequence[int]) -> np.ndarray:
        return np.array([self.mass.get(int(b), 0.0) for b in blocks])


def select_victims_nad(tracker: ActivityTracker, candidates: Sequence[int],
                       n: int, step: int) -> List[int]:
    """Paper's activity-based victim selection: longest Non-Activity-Duration."""
    cand = np.asarray(list(candidates), np.int64)
    if cand.size == 0 or n <= 0:
        return []
    nad = tracker.nad(cand, step)
    order = np.argsort(-nad, kind="stable")
    return cand[order[:n]].tolist()


def select_victims_mass(tracker: ActivityTracker, candidates: Sequence[int],
                        n: int, step: int) -> List[int]:
    """Beyond-paper: evict lowest recent attention mass (ties -> oldest)."""
    cand = np.asarray(list(candidates), np.int64)
    if cand.size == 0 or n <= 0:
        return []
    mass = tracker.mass_of(cand)
    nad = tracker.nad(cand, step)
    order = np.lexsort((-nad, mass))        # primary: low mass; tie: old
    return cand[order[:n]].tolist()


def select_victims_random(rng: np.random.Generator, candidates: Sequence[int],
                          n: int) -> List[int]:
    """Baseline (Infiniswap-like batched random selection, §6.5)."""
    cand = list(candidates)
    if not cand or n <= 0:
        return []
    idx = rng.permutation(len(cand))[:min(n, len(cand))]
    return [cand[i] for i in idx]


class PairSampler:
    """Buffered uniform distinct ordered pairs over ``range(k)``.

    ``power_of_two_choices`` needs one random peer pair per placement —
    once per flushed page on the remote-send path — and per-call Generator
    overhead dominates there.  Drawing a few thousand pairs at a time keeps
    the amortized cost near an array index.  Distribution is identical to
    the unbuffered two-draw scheme; only the stream consumption differs.
    """

    def __init__(self, k: int, rng: np.random.Generator, buf: int = 4096):
        assert k >= 2
        self.k = k
        self.rng = rng
        self.buf = buf
        self._a = self._b = None
        self._i = 0

    def draw(self):
        if self._a is None or self._i >= self._a.shape[0]:
            self._a = self.rng.integers(0, self.k, size=self.buf)
            self._b = self.rng.integers(0, self.k - 1, size=self.buf)
            self._i = 0
        i = self._i
        self._i = i + 1
        a = int(self._a[i])
        b = int(self._b[i])
        if b >= a:
            b += 1
        return a, b


def power_of_two_choices(free_counts: Sequence[int],
                         rng: np.random.Generator,
                         exclude: Sequence[int] = ()) -> Optional[int]:
    """Pick the freer of two random peers (paper §2.1, §4.3).

    The distinct pair is drawn with two ``integers`` draws (second index
    skips the first) — the same uniform ordered-pair distribution as
    ``rng.choice(k, 2, replace=False)`` at a fraction of its cost, which
    matters because placement runs once per flushed page.
    """
    if exclude:
        ex = set(exclude)
        peers = [i for i in range(len(free_counts)) if i not in ex]
    else:
        peers = list(range(len(free_counts)))
    if not peers:
        return None
    k = len(peers)
    if k == 1:
        return peers[0]
    a = int(rng.integers(k))
    b = int(rng.integers(k - 1))
    if b >= a:
        b += 1
    pa, pb = peers[a], peers[b]
    return pa if free_counts[pa] >= free_counts[pb] else pb
