"""AsyncOrchestrator — overlap reclaim/flush/migration with the critical path.

The synchronous ``TieredPageStore`` runs every flush, reclaim and migration
inline: when the pool runs dry mid-write, the op pays the whole coalesced
remote send (``_flush(in_critical_path=True)``) — exactly the stall Valet's
design hides behind the critical path (§3.2, §5: the Remote Sender Thread
sends lazily while the app keeps writing locally).  This engine restores the
overlap with an **epoch/fence protocol**:

* **Ops pin the current epoch.**  The foreground processes ops in epochs of
  ``epoch_len``; all daemon work scheduled during an epoch commits at the
  *next* epoch boundary, never mid-op.
* **The daemon runs at epoch boundaries** (simulated-clock mode): it flushes
  staged write-sets, restocks the free list by draining the reclaimable
  queue into *epoch-tagged holds* (``ValetMempool.hold_from_free``), and
  absorbs migration copy costs.  Its simulated work accrues to
  ``daemon_clock`` — time the daemon is busy — not to the critical path.
* **A fence is taken only when the pool is genuinely exhausted**: the op
  waits ``max(0, daemon_clock - now)`` (the daemon's in-flight work), all
  holds commit, and the op proceeds.  Only if the daemon had nothing in
  flight does the op fall back to the synchronous emergency flush.

Simulated-clock mode is **deterministic** (no threads, no wall clock): the
``tail_latency`` benchmark gates the sync/async p99 ratio on it.  The
optional ``real_thread`` mode runs the same daemon work on a real
``threading.Thread`` under a store-wide lock — not deterministic, verified
by the ``InvariantChecker`` and statistical ``Stats`` bounds instead.

**This deliberately breaks bitwise parity with the scalar reference** (flush
cadence, victim order and placement draws all shift).  Its verification tier
is ``repro.core.invariants.InvariantChecker`` — no lost writes, §5.2
write-set safety, slab/page conservation, replica-index consistency — plus
statistical-equivalence bounds on hit/miss/eviction counts vs sync mode.
Synchronous mode is untouched and keeps its bitwise-parity suites.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Optional

import numpy as np


class DaemonClock:
    """Simulated background-daemon clock (us, same axis as ``time_us``).

    ``at_us`` is the time at which the daemon becomes idle.  ``charge``
    appends work starting from ``max(at_us, now)`` (the daemon can't start
    before it is free *or* before the work exists); ``wait_for`` is the
    fence wait ``max(0, at_us - now)``.  ``AsyncOrchestrator`` keeps its
    inline ``daemon_clock`` float for bit-stability of the existing suites;
    the serve engine's async mode charges its demote/flush daemon through
    one of these.
    """

    def __init__(self):
        self.at_us = 0.0

    def charge(self, cost_us: float, now_us: float) -> float:
        """Schedule ``cost_us`` of daemon work at ``now_us``; returns it."""
        self.at_us = max(self.at_us, now_us) + cost_us
        return cost_us

    def wait_for(self, now_us: float) -> float:
        """Fence wait if the foreground synchronizes at ``now_us``."""
        w = self.at_us - now_us
        return w if w > 0.0 else 0.0


class AsyncOrchestrator:
    """Background daemon + epoch/fence protocol for one ``TieredPageStore``.

    Attach via ``OrchestrationConfig(async_mode=True)``; the store routes
    ``access_batch`` / ``background_tick`` / ``drain`` through here.
    """

    # RDMA one-sided writes pipeline on the wire (QP depth): the Remote
    # Sender Thread's per-page occupancy is the issue+completion share, not
    # the full serial latency.  This keeps the simulated daemon's throughput
    # in the regime the paper measures (the sender keeps up with the app).
    FLUSH_PIPELINE_DEPTH = 8

    def __init__(self, store, *, epoch_len: int = 64,
                 daemon_budget: int = 256, real_thread: bool = False):
        if epoch_len < 1:
            raise ValueError("epoch_len must be >= 1")
        if daemon_budget < 1:
            raise ValueError("daemon_budget must be >= 1")
        self.store = store
        self.epoch_len = int(epoch_len)
        self.daemon_budget = int(daemon_budget)
        self.real_thread = bool(real_thread)
        self.epoch = 0
        self._ops_in_epoch = 0
        # simulated time at which the daemon becomes idle (us, on the same
        # axis as stats.time_us); work scheduled at a boundary at time T
        # advances it by the charged cost from max(daemon_clock, T)
        self.daemon_clock = 0.0
        # counters (engine-level; Stats carries fences/fence_wait/daemon_us)
        self.n_boundaries = 0
        self.n_daemon_flush_pages = 0
        self.n_daemon_held_slots = 0
        # real-thread mode plumbing
        self._lock: Optional[threading.RLock] = None
        self._cv: Optional[threading.Condition] = None
        self._work: deque = deque()
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        if self.real_thread:
            # ONE RLock shared with the condition: a fence waiting for the
            # daemon parks on ``_cv.wait()``, which releases the lock (all
            # recursion levels) so the daemon can take it, run its slice,
            # and notify — a separate condition lock would deadlock here
            self._lock = threading.RLock()
            self._cv = threading.Condition(self._lock)
            self._thread = threading.Thread(target=self._daemon_loop,
                                            daemon=True,
                                            name="valet-async-daemon")
            self._thread.start()

    # -- foreground: the async critical path ---------------------------------

    def run_batch(self, pages: np.ndarray, iw: np.ndarray,
                  out_lats: np.ndarray) -> None:
        """Process a batch op-by-op, pinning epochs by construction: the
        boundary only ever runs *between* ops, so no op observes a daemon
        commit mid-flight."""
        pages_l = pages.tolist()
        iw_l = np.asarray(iw, bool).tolist()
        lock = self._lock
        for i, (pg, w) in enumerate(zip(pages_l, iw_l)):
            if lock is not None:
                with lock:
                    out_lats[i] = self._write(pg) if w else self._read(pg)
            else:
                out_lats[i] = self._write(pg) if w else self._read(pg)
            self._ops_in_epoch += 1
            if self._ops_in_epoch >= self.epoch_len:
                self._ops_in_epoch = 0
                self.epoch_boundary()

    def _read(self, pg: int) -> float:
        # the scalar read never stalls (a failed cache-fill alloc simply
        # skips the fill), so it is reused verbatim
        return self.store.read(pg)

    def _write(self, pg: int) -> float:
        """The scalar ``write`` schedule with the synchronous flush stall
        replaced by a fence on the daemon."""
        store = self.store
        st = store.stats
        store.step += 1
        st.writes += 1
        lat = 0.0
        ppb = max(1, store.pages_per_block)
        ws = store.pipeline.write((pg,), store.step)
        if ws is None:
            # pool exhausted: reclaim from reclaimable queue (pointer move)
            store._reclaim(ppb)
            ws = store.pipeline.write((pg,), store.step)
        if ws is None:
            # genuinely exhausted: fence — wait out the daemon's in-flight
            # work and commit its holds instead of flushing inline
            lat += self._fence_locked()
            ws = store.pipeline.write((pg,), store.step)
        if ws is None:
            # daemon had nothing in flight either: emergency synchronous
            # flush, charged to this op exactly like the sync stall (rare)
            lat += store._flush(ppb, in_critical_path=True)
            store._reclaim(ppb)
            ws = store.pipeline.write((pg,), store.step)
        if ws is not None:
            store.gpt.map_local(pg, ws.slots[0])
            if store.data_plane is not None:
                store.data_plane.local_write(pg, ws.slots[0])
            lat += store.costs.local_write
        else:
            lat += store.costs.cold_write      # total pressure: spill cold
            store._host_add(pg)
        st.time_us += lat
        st.ops += 1
        return lat

    # -- fence ---------------------------------------------------------------

    def fence(self) -> float:
        """Public fence: drain the daemon and commit all holds NOW.  Returns
        the simulated wait charged (0 when the daemon was already idle)."""
        if self._lock is not None:
            with self._lock:
                return self._fence_locked()
        return self._fence_locked()

    def _fence_locked(self) -> float:
        store = self.store
        st = store.stats
        st.fences += 1
        if self.real_thread:
            self._wait_daemon_idle()
        wait = self.daemon_clock - st.time_us
        wait = wait if wait > 0.0 else 0.0
        st.fence_wait_us += wait
        st.fence_lat.record(wait)
        store.pool.commit_holds()
        if store.pool.free_count() == 0:
            store._reclaim(max(1, store.pages_per_block))
        return wait

    # -- epoch boundary / daemon work ----------------------------------------

    def epoch_boundary(self, budget: Optional[int] = None) -> None:
        """Commit matured holds, then schedule this epoch's daemon work."""
        budget = self.daemon_budget if budget is None else int(budget)
        self.epoch += 1
        self.n_boundaries += 1
        if self.real_thread:
            with self._cv:
                self.store.pool.commit_holds(
                    now_us=self.store.stats.time_us)
                self._work.append(budget)
                self._cv.notify_all()
            return
        now = self.store.stats.time_us
        self.store.pool.commit_holds(now_us=now)
        self._daemon_work(budget, now)

    def _daemon_work(self, budget: int, now: float) -> None:
        """One daemon slice: flush staged sets, size the pool, restock the
        free list into an epoch-tagged hold.  State mutates now (visible at
        schedule time — the deliberate relaxation vs the scalar reference);
        the simulated cost lands on ``daemon_clock``, not the critical path."""
        store = self.store
        st = store.stats
        # 1. lazy send, off the critical path (the Remote Sender Thread)
        staged = len(store.pipeline.staging)
        if store.policy.lazy_send and staged:
            n = min(budget, staged)
            cost = store._flush(n)
            charged = cost / self.FLUSH_PIPELINE_DEPTH
            self.daemon_clock = max(self.daemon_clock, now) + charged
            st.daemon_us += charged
            self.n_daemon_flush_pages += min(n, staged)
        # 1b. re-replication repair: drain the degraded-block queue at the
        # daemon's pipelined rate — repairs overlap foreground ops exactly
        # like flushes (the repair copies are sender-driven block writes)
        if store.repairq:
            before = st.repair_us
            pages = store._drain_repairs(
                min(budget, store.config.repair_rate))
            if pages:
                charged = (st.repair_us - before) / self.FLUSH_PIPELINE_DEPTH
                self.daemon_clock = max(self.daemon_clock, now) + charged
                st.daemon_us += charged
        # keep the coordinator's degraded-admission signal in sync (note
        # while the backlog persists, clear_degraded once it drains)
        store._report_repair_backlog()
        # 2. pool sizing (same cadence as the sync background_tick)
        if store.policy.dynamic_pool:
            store.pool.shrink_for_pressure()
            if not store.repairq:
                store.pool.maybe_grow()
        # 3. restock ahead of demand: drain the reclaimable queue into a
        # hold that commits once the daemon's clock catches up (at the
        # earliest, the next epoch boundary).  The target is capped at half
        # the pool — restocking two epochs of allocations is pointless (and
        # guts local residency) when the pool itself is barely bigger
        pool = store.pool
        target = min(2 * self.epoch_len, pool.size // 2)
        want = target - pool.free_count() - pool.held_count()
        if want > 0 and len(store.pipeline.reclaimable):
            k = store._reclaim_held(min(want, budget), self.epoch,
                                    self.daemon_clock)
            self.n_daemon_held_slots += k

    def tick(self, budget: int) -> None:
        """``background_tick`` in async mode: an extra epoch boundary with
        an explicitly raised daemon budget."""
        self.epoch_boundary(budget=max(int(budget), self.daemon_budget))

    # -- migration accounting -------------------------------------------------

    def note_block_copied(self, n_pages: int) -> None:
        """Charge one migrated block's copy (read from source + write to
        destination per page, pipelined) to the daemon clock — migration
        runs concurrently with the critical path (§3.5 sender-driven
        protocol; receivers are passive)."""
        store = self.store
        cost = n_pages * (store.costs.remote_read
                          + store.costs.remote_write) \
            / self.FLUSH_PIPELINE_DEPTH
        now = store.stats.time_us
        self.daemon_clock = max(self.daemon_clock, now) + cost
        store.stats.daemon_us += cost

    # -- quiesce / teardown ---------------------------------------------------

    def quiesce(self) -> None:
        """Barrier for ``drain()``: finish all daemon work and commit every
        hold, WITHOUT charging the foreground (a drain is a checkpoint
        barrier, not a critical-path op)."""
        if self.real_thread:
            with self._cv:
                self._wait_daemon_idle()
                self.store.pool.commit_holds()
            return
        self.store.pool.commit_holds()

    def close(self) -> None:
        """Stop the real daemon thread (no-op in simulated-clock mode)."""
        if self._thread is None:
            return
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=5.0)
        self._thread = None

    # -- real-thread mode ------------------------------------------------------

    def _wait_daemon_idle(self) -> None:
        # caller holds the shared lock; wait() releases it (every recursion
        # level) so the daemon can drain, then re-acquires before returning
        while self._work:
            self._cv.wait(timeout=0.05)

    def _daemon_loop(self) -> None:
        while True:
            with self._cv:
                while not self._work and not self._stop:
                    self._cv.wait(timeout=0.1)
                if self._stop and not self._work:
                    return
                budget = self._work[0]
                self._daemon_work(budget, self.store.stats.time_us)
                self._work.popleft()
                self._cv.notify_all()
