"""ClusterCoordinator — federated host coordinators over a cluster pool.

ROADMAP item 3: Valet's §3.4 host-coordinated pool assumes one host slab
and a flat, static remote peer set.  At cluster scale (the regime Pond's
pool-level admission targets — see PAPERS.md; DOLMA is the
placement-granularity contrast) three things change:

* **Two-level pooling.**  A ``ClusterCoordinator`` owns the cluster-wide
  page pool and admits per-host ``HostMemoryCoordinator``s the same way a
  host coordinator admits containers: registration reserves the host's
  ``min_slab`` floor, and a host whose containers outgrow its slab leases
  *more slab* from the cluster (``lease_slab``) instead of hitting a fixed
  ceiling.  Slab is grow-only while a host lives; the whole slab returns
  on ``deregister_host``/``fail_host`` — which keeps cluster conservation
  a one-line sum.

* **Heterogeneous peers and failure domains.**  Remote peers carry
  ``PeerProfile``s (extra latency, capacity override, failure-domain id)
  drawn from seeded distributions (``draw_peer_profiles``).  Replica
  placement (``replication.ReplicaPlacer``) and migration destination
  choice (``migration.MigrationEngine``) become strictly cross-domain so
  one rack failure never takes out every copy of a block.

* **Recovery-storm admission.**  When a host or rack fails, survivors
  re-lease en masse.  ``fail_host``/``rejoin_host`` open a *storm window*
  (counted in lease calls — the coordinators are clockless) during which
  slab grants are shed to floor deficits and every gated call is charged
  the same staggered exponential ladder the SUSPECT retry path uses
  (``backoff_base_us * (2^attempts - 1)``, ``core/faults.py``): repeated
  denials back a host off, a grant resets its ladder.  Degraded hosts
  (``note_host_degraded`` fan-in from the per-host coordinators) stay
  shed to floor even outside a storm — no growth on top of an unrepaired
  replica backlog.

Convergence is provable, not hoped for: ``check_invariants`` asserts
cluster slab conservation, every DOWN host's slab reclaimed, and each
live host's coordinator internally consistent; ``ClusterInvariantChecker``
composes that with every surviving store's ``InvariantChecker`` plus the
cross-domain replica law and the ``check_replication_restored`` barrier.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.coordinator import HostMemoryCoordinator


# -- heterogeneous peer profiles ------------------------------------------


@dataclass(frozen=True)
class PeerProfile:
    """One remote peer's identity in a heterogeneous cluster.

    ``latency_us`` is *extra* one-way latency added to every remote read
    hit on this peer (0 for a near peer — the homogeneous cost model is
    the base, so an all-zero profile set prices identically to no
    profiles).  ``capacity_blocks`` overrides the store-wide
    ``peer_capacity_blocks`` (None keeps it).  ``domain`` is the failure
    domain (rack): peers sharing a domain fail together under a
    correlated rack crash, so replicas are placed strictly cross-domain.
    """
    latency_us: float = 0.0
    capacity_blocks: Optional[int] = None
    domain: int = 0


def draw_peer_profiles(n_peers: int, n_domains: int = 2, *, seed: int = 0,
                       base_capacity_blocks: int = 1024,
                       latency_scale_us: float = 0.0
                       ) -> Tuple[PeerProfile, ...]:
    """Draw a seeded heterogeneous peer set.

    Capacities are uniform over ``[base/2, 3*base/2]`` (far-memory boxes
    differ in DIMM population), extra latencies lognormal(0, 0.5) scaled
    by ``latency_scale_us`` (0 keeps the homogeneous cost model), and
    domains are contiguous rack stripes (``peer i -> i*n_domains//n``) so
    a rack maps onto a contiguous peer-id range.  Identical seeds yield
    identical tuples.
    """
    assert n_peers > 0 and n_domains > 0
    rng = np.random.default_rng(seed)
    caps = rng.integers(base_capacity_blocks // 2,
                        base_capacity_blocks * 3 // 2 + 1, size=n_peers)
    lats = latency_scale_us * rng.lognormal(0.0, 0.5, size=n_peers)
    return tuple(
        PeerProfile(latency_us=float(lats[i]) if latency_scale_us else 0.0,
                    capacity_blocks=int(caps[i]),
                    domain=(i * n_domains) // n_peers)
        for i in range(n_peers))


def profile_domains(profiles) -> Optional[List[int]]:
    """Peer -> failure-domain list from a profile tuple (None when the
    profiles carry a single domain — a flat peer set needs no exclusion)."""
    if not profiles:
        return None
    doms = [p.domain for p in profiles]
    return doms if len(set(doms)) > 1 else None


# -- host records ----------------------------------------------------------


class HostState(Enum):
    UP = "up"
    DOWN = "down"


@dataclass
class HostRecord:
    """Cluster-side state for one registered host."""
    hid: int
    name: str
    min_slab: int                  # guaranteed slab floor
    max_slab: int                  # slab lease cap
    slab: int = 0                  # pages currently held from the pool
    state: HostState = HostState.UP
    coordinator: Optional[HostMemoryCoordinator] = None
    demand_decay: Optional[float] = None
    degraded_blocks: int = 0       # aggregated per-host repair backlog
    storm_attempts: int = 0        # consecutive gated denials (ladder rung)
    storm_wait_us: float = 0.0     # simulated backoff charged to this host
    n_slab_leases: int = 0
    pages_slab_leased: int = 0


@dataclass
class ClusterStats:
    n_hosts_registered: int = 0
    n_host_deregistrations: int = 0
    n_host_failures: int = 0
    n_host_rejoins: int = 0
    n_slab_lease_calls: int = 0
    pages_slab_leased: int = 0
    n_storms: int = 0              # storm windows opened (fail/rejoin)
    n_storm_denials: int = 0       # gated lease calls shed to zero
    storm_wait_us: float = 0.0     # total staggered-backoff simulated wait
    n_degraded_reports: int = 0    # per-host backlog fan-ins (non-zero)
    n_degraded_clears: int = 0     # per-host backlog drained to zero


# -- the cluster coordinator ----------------------------------------------


class ClusterCoordinator:
    """Arbitrates one cluster page pool across N host coordinators."""

    STORM_WINDOW = 256             # gated lease calls after a fail/rejoin
    MAX_BACKOFF_EXP = 6            # ladder cap: base * (2^6 - 1)

    def __init__(self, total_pages: int, *, backoff_base_us: float = 8.0,
                 storm_window: Optional[int] = None):
        assert total_pages > 0
        self.total_pages = total_pages
        self.backoff_base_us = float(backoff_base_us)
        self.storm_window = self.STORM_WINDOW if storm_window is None \
            else int(storm_window)
        self._free = total_pages
        self._hosts: Dict[int, HostRecord] = {}
        self._next_hid = 0
        self._storm_calls_left = 0
        self.stats = ClusterStats()

    # -- host lifecycle ----------------------------------------------------

    def register_host(self, *, min_slab: int, max_slab: Optional[int] = None,
                      name: Optional[str] = None,
                      demand_decay: Optional[float] = None
                      ) -> HostMemoryCoordinator:
        """Admit a host: reserve its ``min_slab`` floor and hand back a
        freshly built ``HostMemoryCoordinator`` wired into the cluster
        (its lease shortfalls escalate to ``lease_slab``).  Raises when
        the floor does not fit the free pool — the same admission-control
        contract containers get from a host coordinator."""
        max_slab = min_slab if max_slab is None else max_slab
        assert 0 < min_slab <= max_slab
        if min_slab > self._free:
            raise ValueError(
                f"cannot admit host ({min_slab} floor pages): only "
                f"{self._free} of {self.total_pages} pool pages free")
        hid = self._next_hid
        self._next_hid += 1
        rec = HostRecord(hid=hid, name=name or f"host{hid}",
                         min_slab=min_slab, max_slab=max_slab,
                         slab=min_slab, demand_decay=demand_decay)
        self._free -= min_slab
        rec.coordinator = self._attach_coordinator(rec)
        self._hosts[hid] = rec
        self.stats.n_hosts_registered += 1
        return rec.coordinator

    def _attach_coordinator(self, rec: HostRecord) -> HostMemoryCoordinator:
        coord = HostMemoryCoordinator(rec.slab,
                                      demand_decay=rec.demand_decay)
        coord.cluster = self
        coord.host_id = rec.hid
        return coord

    def deregister_host(self, hid: int) -> int:
        """A host leaves cleanly: its whole slab returns to the pool."""
        rec = self._hosts.pop(hid)
        returned = rec.slab
        self._free += returned
        if rec.coordinator is not None:
            rec.coordinator.cluster = None
        self.stats.n_host_deregistrations += 1
        return returned

    def fail_host(self, hid: int) -> int:
        """A host crashes: reclaim its entire slab (every lease its
        containers held dies with the host), drop its coordinator, and
        open a recovery-storm window — the survivors are about to
        re-lease en masse.  Returns the pages reclaimed."""
        rec = self._hosts[hid]
        assert rec.state is HostState.UP, f"host{hid} already down"
        reclaimed = rec.slab
        self._free += reclaimed
        rec.slab = 0
        rec.state = HostState.DOWN
        if rec.coordinator is not None:
            rec.coordinator.cluster = None
            rec.coordinator = None
        rec.degraded_blocks = 0
        self.stats.n_host_failures += 1
        self._enter_storm()
        return reclaimed

    def rejoin_host(self, hid: int) -> HostMemoryCoordinator:
        """A DOWN host comes back empty: re-reserve its floor, hand it a
        *fresh* coordinator (its old containers died with it), and open a
        storm window — a rejoin re-leases just like a failure does."""
        rec = self._hosts[hid]
        assert rec.state is HostState.DOWN, f"host{hid} is not down"
        if rec.min_slab > self._free:
            raise ValueError(
                f"cannot rejoin host{hid} ({rec.min_slab} floor pages): "
                f"only {self._free} pool pages free")
        self._free -= rec.min_slab
        rec.slab = rec.min_slab
        rec.state = HostState.UP
        rec.storm_attempts = 0
        rec.coordinator = self._attach_coordinator(rec)
        self.stats.n_host_rejoins += 1
        self._enter_storm()
        return rec.coordinator

    def _enter_storm(self) -> None:
        self._storm_calls_left = self.storm_window
        self.stats.n_storms += 1

    def storm_active(self) -> bool:
        return self._storm_calls_left > 0

    # -- slab leasing ------------------------------------------------------

    def lease_slab(self, hid: int, want: int) -> int:
        """Grant up to ``want`` more slab pages to a live host.

        Mid-storm (and for a degraded host any time) grants are shed to
        the host's floor deficit, and every gated call pays the staggered
        exponential ladder — ``backoff_base_us * (2^attempts - 1)`` of
        simulated wait, attempts escalating per denial and resetting on a
        grant — so a thundering herd of re-leasing survivors serializes
        instead of oscillating."""
        rec = self._hosts[hid]
        self.stats.n_slab_lease_calls += 1
        if rec.state is not HostState.UP:
            return 0
        want = min(want, rec.max_slab - rec.slab)
        storm = self._storm_calls_left > 0
        if storm:
            self._storm_calls_left -= 1
            wait = self.backoff_base_us * (
                (1 << min(rec.storm_attempts, self.MAX_BACKOFF_EXP)) - 1)
            rec.storm_wait_us += wait
            self.stats.storm_wait_us += wait
        if storm or rec.degraded_blocks > 0:
            # degraded-mode admission: floor deficits only
            want = min(want, max(rec.min_slab - rec.slab, 0))
        granted = min(want, self._free) if want > 0 else 0
        if granted > 0:
            self._free -= granted
            rec.slab += granted
            rec.n_slab_leases += 1
            rec.pages_slab_leased += granted
            self.stats.pages_slab_leased += granted
            rec.storm_attempts = 0
        elif storm:
            rec.storm_attempts += 1
            self.stats.n_storm_denials += 1
        return granted

    def headroom_for(self, hid: int) -> int:
        """Slab pages this host could still lease right now — the cap
        input its coordinator folds into ``available_for``.  Shed to the
        floor deficit mid-storm / while degraded, like ``lease_slab``."""
        rec = self._hosts[hid]
        if rec.state is not HostState.UP:
            return 0
        room = rec.max_slab - rec.slab
        if self._storm_calls_left > 0 or rec.degraded_blocks > 0:
            room = min(room, max(rec.min_slab - rec.slab, 0))
        return max(min(room, self._free), 0)

    # -- degradation fan-in ------------------------------------------------

    def note_host_degraded(self, hid: int, n_blocks: int) -> None:
        """A host coordinator reports its aggregated container repair
        backlog (``HostMemoryCoordinator._forward_degraded``).  Non-zero
        sheds the host's slab admission to floor; zero releases it."""
        rec = self._hosts.get(hid)
        if rec is None:
            return
        was = rec.degraded_blocks
        rec.degraded_blocks = int(n_blocks)
        if n_blocks > 0:
            self.stats.n_degraded_reports += 1
        elif was > 0:
            self.stats.n_degraded_clears += 1

    # -- accounting / invariants ------------------------------------------

    def free(self) -> int:
        return self._free

    def hosts(self) -> List[HostRecord]:
        return list(self._hosts.values())

    def check_invariants(self) -> None:
        held = sum(r.slab for r in self._hosts.values())
        assert held + self._free == self.total_pages, \
            f"cluster pool not conserved: {held} held + {self._free} " \
            f"free != {self.total_pages}"
        assert self._free >= 0
        for rec in self._hosts.values():
            if rec.state is HostState.DOWN:
                assert rec.slab == 0, \
                    f"{rec.name}: DOWN but still holds {rec.slab} pages"
                assert rec.coordinator is None, \
                    f"{rec.name}: DOWN but coordinator attached"
            else:
                assert rec.min_slab <= rec.slab <= rec.max_slab, \
                    f"{rec.name}: slab {rec.slab} outside " \
                    f"[{rec.min_slab}, {rec.max_slab}]"
                coord = rec.coordinator
                assert coord is not None, f"{rec.name}: UP w/o coordinator"
                assert coord.total_pages == rec.slab, \
                    f"{rec.name}: coordinator slab {coord.total_pages} " \
                    f"!= cluster record {rec.slab}"
                coord.check_invariants()


class ClusterInvariantChecker:
    """Cluster-wide safety: the coordinator's conservation laws plus every
    surviving store's full ``InvariantChecker`` (which includes the
    cross-domain replica law when the store carries failure domains)."""

    def __init__(self, cluster: ClusterCoordinator,
                 stores_by_host: Dict[int, List]):
        self.cluster = cluster
        self.stores_by_host = stores_by_host

    def _live_stores(self):
        live = {r.hid for r in self.cluster.hosts()
                if r.state is HostState.UP}
        for hid, stores in sorted(self.stores_by_host.items()):
            if hid in live:
                for store in stores:
                    yield store

    def check(self) -> None:
        from repro.core.invariants import InvariantChecker
        self.cluster.check_invariants()
        for store in self._live_stores():
            InvariantChecker(store).check()

    def check_recovery_converged(self, factor: Optional[int] = None) -> None:
        """The post-storm barrier: every surviving host's store drained
        its repair queue and every referenced primary is back at full
        replication — cluster recovery must end complete, not quiet."""
        from repro.core.invariants import InvariantChecker
        self.check()
        for store in self._live_stores():
            InvariantChecker(store).check_replication_restored(factor)
