"""Unified orchestration configuration (the stable public API surface).

Five PRs of vectorization work accreted knobs onto ``TieredPageStore`` and
``ValetServeEngine`` one keyword at a time.  ``OrchestrationConfig`` is the
consolidation: one frozen dataclass holding every orchestration decision —
policy, cost profile, pool geometry, pipeline depths, coordinator/QoS
settings, and the async-engine knobs introduced alongside it — constructed
once and handed to ``TieredPageStore.from_config()`` /
``ValetServeEngine.from_config()``.

The legacy constructor keywords keep working as *deprecated aliases*: passing
them emits a ``DeprecationWarning`` naming the replacement field, and they
are folded into an ``OrchestrationConfig`` internally, so both construction
paths produce bitwise-identical stores (``test_config.py`` pins this).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

from repro.core.policies import (CostModel, Policy, PAPER_COSTS, VALET)


@dataclass(frozen=True)
class OrchestrationConfig:
    """Every orchestration knob in one immutable, replace()-able object.

    Pool geometry is in *pages*; depths are entry counts; ``activity_decay``
    is the coordinator's per-round demand decay (§3.4).  The async fields
    only take effect with ``async_mode=True`` (see ``AsyncOrchestrator``).
    """

    # -- policy & cost profile -------------------------------------------
    policy: Policy = VALET
    costs: CostModel = PAPER_COSTS

    # -- local pool geometry (§4.1) --------------------------------------
    pool_capacity: int = 1024
    min_pool: int = 64
    max_pool: Optional[int] = None        # None -> pool_capacity
    grow_step: Optional[int] = None       # None -> capacity // 8

    # -- remote / host tiers ---------------------------------------------
    n_peers: int = 4
    peer_capacity_blocks: int = 1024
    pages_per_block: int = 16
    host_capacity: int = 1 << 30

    # -- pipeline depths & cadence ---------------------------------------
    batch_reclaim: bool = True            # dense SoA reclaim/flush engine
    staging_depth: int = 1 << 16          # WritePipeline row-queue length
    flush_batch: int = 64                 # default background_tick drain
    pressure_batch: int = 256             # blocks freed per pressure round

    # -- host memory coordinator (§3.4) / QoS ----------------------------
    coordinator: Optional[Any] = None     # HostMemoryCoordinator
    container_name: Optional[str] = None
    weight: float = 1.0                   # weighted-fair share (QoS)
    activity_decay: float = 0.5           # coordinator demand decay / round

    # -- async orchestration engine --------------------------------------
    async_mode: bool = False              # overlap reclaim/flush/migration
    epoch_len: int = 64                   # ops per epoch (commit cadence)
    daemon_budget: int = 256              # pages of daemon work per epoch
    real_thread: bool = False             # real daemon thread (not determ.)

    # -- fault handling (core/faults.py) ---------------------------------
    # retry/backoff against a SUSPECT peer: each access pays
    # ``backoff_base_us * (2^retry_limit - 1)`` of simulated wait (the
    # full exponential ladder — deterministic, so the parity suites hold
    # whenever no fault is injected)
    retry_limit: int = 3
    backoff_base_us: float = 8.0
    # simulated us a peer may stay SUSPECT before the health poll
    # escalates it to DOWN (fail_peer)
    suspect_timeout_us: float = 50_000.0
    # re-replication repair drain rate: pages copied per background tick
    # (sync) or per daemon slice (async)
    repair_rate: int = 256

    # -- cluster-scale knobs (core/cluster.py) ---------------------------
    # heterogeneous remote peers: a tuple of ``PeerProfile``s (one per
    # peer: extra latency, capacity override, failure domain — see
    # ``draw_peer_profiles``).  None keeps the flat homogeneous peer set —
    # bitwise identical to every pre-cluster run.
    peer_profiles: Optional[Tuple[Any, ...]] = None
    # REJOINING warm-up: a rejoined peer's advertised free capacity ramps
    # linearly over its first ``rejoin_ramp_grants`` block grants instead
    # of re-entering placement at full weight.  Only activates after a
    # rejoin event, so fault-free runs are unaffected.  0 disables.
    rejoin_ramp_grants: int = 16

    # -- device tier / zero-restore (PR 8) -------------------------------
    # trace store: remember reclaimed pages' slots and repoint on re-access
    # while the slot is untouched (off by default: it improves hit ratios,
    # so the bitwise scalar/batch parity suites run without it)
    device_tier: bool = False
    # serve engine: preemption demotes KV pages in place (no copy); restore
    # repoints block-table entries and streams only reused slots.  False =
    # legacy bulk gather/scatter spill/restore (the comparison baseline).
    zero_restore: bool = True

    # -- serving knobs (ValetServeEngine.from_config) --------------------
    page: int = 16                        # tokens per KV page
    max_batch: int = 8                    # concurrent decode slots
    max_seq: int = 512                    # max tokens per sequence
    pool_slots: Optional[int] = None      # KV pool slots; None -> pool_capacity
    step_cost_us: float = 0.0             # simulated cost per decode step

    # -- simulation plumbing ---------------------------------------------
    seed: int = 0
    free_memory_fn: Optional[Callable[[], int]] = field(
        default=None, compare=False)
    data_plane: Optional[Any] = field(default=None, compare=False)

    def replace(self, **changes) -> "OrchestrationConfig":
        return dataclasses.replace(self, **changes)


# legacy TieredPageStore keyword -> OrchestrationConfig field
LEGACY_STORE_KWARGS = {
    "pool_capacity": "pool_capacity",
    "min_pool": "min_pool",
    "max_pool": "max_pool",
    "n_peers": "n_peers",
    "peer_capacity_blocks": "peer_capacity_blocks",
    "pages_per_block": "pages_per_block",
    "host_capacity": "host_capacity",
    "free_memory_fn": "free_memory_fn",
    "seed": "seed",
    "data_plane": "data_plane",
    "batch_reclaim": "batch_reclaim",
    "grow_step": "grow_step",
    "coordinator": "coordinator",
    "container_name": "container_name",
    "container_weight": "weight",
    "weight": "weight",
}


# legacy ValetServeEngine.from_config keyword -> OrchestrationConfig field
# (PR 8 moved the serving knobs onto the config; the loose kwargs stay as
# deprecated aliases behind the same CI gate as the store's)
LEGACY_SERVE_KWARGS = {
    "max_batch": "max_batch",
    "max_seq": "max_seq",
    "page": "page",
    "pool_slots": "pool_slots",
    "step_cost_us": "step_cost_us",
}


def config_from_legacy_kwargs(base: OrchestrationConfig,
                              kwargs: dict,
                              *, owner: str,
                              stacklevel: int = 3,
                              alias_map: Optional[dict] = None
                              ) -> OrchestrationConfig:
    """Fold deprecated constructor keywords into a config, warning per key.

    Unknown keys raise ``TypeError`` exactly as the old signature would.
    ``alias_map`` defaults to the store's map; the serve engine passes
    ``LEGACY_SERVE_KWARGS``.
    """
    aliases = LEGACY_STORE_KWARGS if alias_map is None else alias_map
    mapped = {}
    for key, val in kwargs.items():
        tgt = aliases.get(key)
        if tgt is None:
            raise TypeError(
                f"{owner}() got an unexpected keyword argument {key!r}")
        warnings.warn(
            f"{owner}({key}=...) is deprecated; build an "
            f"OrchestrationConfig({tgt}=...) and use "
            f"{owner}.from_config() instead",
            DeprecationWarning, stacklevel=stacklevel)
        mapped[tgt] = val
    return dataclasses.replace(base, **mapped) if mapped else base
