"""HostMemoryCoordinator — cross-container host memory arbitration (§3.4).

The paper's second contribution: "Valet utilizes unused local memory across
containers by managing local memory via a host-coordinated memory pool,
which allows containers to dynamically expand and shrink their memory
allocations according to the workload demands."

One coordinator owns a fixed physical slab of host pages and arbitrates it
across N co-located containers (``TieredPageStore`` / ``ValetServeEngine``
instances).  Each container's ``ValetMempool`` *leases* pages from the
coordinator when it grows and *returns* them when it shrinks, replacing the
bare ``free_memory_fn`` probe with real accounting:

* **Registration** reserves every container's ``min_pages`` floor up front
  (the sum of floors must fit the slab), so no container can ever be starved
  below its guaranteed minimum.
* **Lease** grants are batched (one call covers a whole grow step, the way
  ``alloc_batch`` covers a whole allocation burst) and capped by the
  container's ``max_pages``.
* **Weighted-fair reclamation**: when a lease cannot be served from free
  pages, the coordinator reclaims from the *other* containers — idle ones
  first (lowest recent demand), shedding them toward their weighted fair
  share, then, if still short, toward their ``min_pages`` floor.  A donor
  frees pages through its registered callback (flush + LRU reclaim + shrink
  on a ``TieredPageStore``), so one container's idle memory becomes
  another's cache instead of forcing remote paging.

Single-container parity: ``available_for(cid)`` reports ``free + leased``
— the total the container could hold — so with N=1 it is the constant slab
size and every sizing decision (80% growth trigger, 50%-of-host-free cap,
pressure shrink) is bitwise identical to a plain pool whose
``free_memory_fn`` returns the slab size (``tests/test_coordinator.py``
pins this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class ContainerRecord:
    """Coordinator-side state for one registered container."""
    cid: int
    name: str
    min_pages: int                 # guaranteed floor, reserved at register
    max_pages: int                 # lease cap
    weight: float                  # weighted-fair share of the surplus
    leased: int = 0                # pages currently held (== its pool size)
    demand: float = 0.0            # decayed recent-activity signal
    donate_cb: Optional[Callable[[int], int]] = None
    size_fn: Optional[Callable[[], int]] = None    # invariant probe
    # remote-pressure routing (§3.4 follow-up): how many victim-candidate
    # MR blocks this container holds on a given peer, and its handler that
    # frees blocks there (migrate or evict, per its policy)
    peer_footprint_fn: Optional[Callable[[int], int]] = None
    peer_pressure_cb: Optional[Callable[[int, int], int]] = None
    # per-container counters
    n_leases: int = 0
    pages_leased_total: int = 0
    pages_donated_total: int = 0
    peer_blocks_freed_total: int = 0
    degraded_blocks: int = 0       # latest repair-backlog report (0 = healthy)


@dataclass
class CoordinatorStats:
    n_lease_calls: int = 0
    n_release_calls: int = 0
    n_partial_grants: int = 0      # lease served below the asked amount
    n_reclaim_events: int = 0      # arbitration rounds (free pool was short)
    pages_reclaimed: int = 0       # pages pulled back from donors
    n_peer_pressure_events: int = 0   # coordinated remote-pressure fan-outs
    peer_blocks_freed: int = 0        # MR blocks freed across containers
    n_degraded_reports: int = 0       # repair-backlog reports (fault path)
    n_degraded_clears: int = 0        # backlog-drained un-throttle events
    n_degraded_denials: int = 0       # lease asks shed to floor while degraded
    n_deregistrations: int = 0        # containers that left mid-run (churn)


class LeaseClient:
    """A container's handle into the coordinator (what ``ValetMempool``
    sees): the lease/return API plus the host-free probe, scoped to one
    container id so the pool never needs to know its own cid."""

    __slots__ = ("coordinator", "cid")

    def __init__(self, coordinator: "HostMemoryCoordinator", cid: int):
        self.coordinator = coordinator
        self.cid = cid

    def available(self) -> int:
        return self.coordinator.available_for(self.cid)

    def lease(self, want: int) -> int:
        return self.coordinator.lease(self.cid, want)

    def release(self, n: int) -> None:
        self.coordinator.release(self.cid, n)


class HostMemoryCoordinator:
    """Arbitrates one fixed host slab across N container mempools."""

    DEMAND_DECAY = 0.5             # aging applied at each arbitration round
    FUTILE_COOLDOWN = 32           # lease calls skipped after a 0-yield round

    def __init__(self, total_pages: int,
                 demand_decay: Optional[float] = None):
        assert total_pages > 0
        self.total_pages = total_pages
        # aging factor for the idle-first donor ordering; instance knob so
        # deployments can tune how fast historic bursts fade (the class
        # attribute stays as the default for existing call sites)
        self.demand_decay = self.DEMAND_DECAY if demand_decay is None \
            else float(demand_decay)
        self._free = total_pages
        self._containers: Dict[int, ContainerRecord] = {}
        self._next_cid = 0
        # arbitration damping: after a reclamation round that freed nothing
        # (every donor at its floor or pinned by live data), skip the next
        # FUTILE_COOLDOWN short-on-free lease calls instead of re-scanning
        # all donors per allocation burst.  Keyed by requesting cid —
        # futility is per-requester (the donor set excludes the caller), so
        # one container's dry round must not block another's reclamation.
        # Any release resets all cooldowns (donor state visibly changed);
        # otherwise they expire by call count, which keeps the retry
        # schedule deterministic.
        self._cooldown: Dict[int, int] = {}
        self.stats = CoordinatorStats()
        # cluster federation (core.cluster): set by
        # ClusterCoordinator.register_host.  With no cluster attached every
        # path below is bitwise identical to the standalone coordinator.
        self.cluster = None
        self.host_id: Optional[int] = None

    # -- registration --------------------------------------------------------

    def register(self, *, min_pages: int, max_pages: int,
                 weight: float = 1.0, name: Optional[str] = None
                 ) -> LeaseClient:
        """Admit a container: reserve its ``min_pages`` floor immediately.

        Raises if the floor does not fit the remaining slab — admission
        control is what makes the no-starvation guarantee possible.  With
        tenant churn a joiner may find the slab fully grown, so a short
        floor first arbitrates against the existing donors (idle-first,
        the same two-pass weighted-fair reclamation lease shortfalls use)
        before admission is refused."""
        assert 0 < min_pages <= max_pages
        assert weight > 0
        if min_pages > self._free:
            self._reclaim_for(self._next_cid, min_pages - self._free)
        if min_pages > self._free:
            raise ValueError(
                f"cannot admit container ({min_pages} floor pages): only "
                f"{self._free} of {self.total_pages} slab pages free")
        cid = self._next_cid
        self._next_cid += 1
        rec = ContainerRecord(cid=cid, name=name or f"container{cid}",
                              min_pages=min_pages, max_pages=max_pages,
                              weight=weight, leased=min_pages)
        self._free -= min_pages
        self._containers[cid] = rec
        return LeaseClient(self, cid)

    def deregister(self, cid: int) -> int:
        """A container leaves (tenant churn): its whole lease — floor
        included — returns to the slab, and every cooldown resets (the
        donor landscape visibly changed).  Returns the pages reclaimed."""
        rec = self._containers.pop(cid)
        returned = rec.leased
        self._free += returned
        self._cooldown.clear()
        self.stats.n_deregistrations += 1
        if rec.degraded_blocks > 0:
            self._forward_degraded()
        return returned

    def set_donor(self, cid: int, donate_cb: Callable[[int], int],
                  size_fn: Optional[Callable[[], int]] = None) -> None:
        """Attach the container's pressure callback (and an optional pool
        size probe used only by ``check_invariants``).  ``donate_cb(n)``
        must free up to ``n`` leased pages (returning them through
        ``release``) and return how many it actually freed."""
        rec = self._containers[cid]
        rec.donate_cb = donate_cb
        rec.size_fn = size_fn

    def register_peer_footprint(self, cid: int,
                                footprint_fn: Callable[[int], int],
                                pressure_cb: Callable[[int, int], int]
                                ) -> None:
        """Attach the container's remote-memory footprint probe and its
        peer-pressure handler.  ``footprint_fn(peer)`` reports how many
        victim-candidate MR blocks the container holds on ``peer`` (a
        ``TieredPageStore`` answers with one masked count over its dense
        per-peer block membership columns); ``pressure_cb(peer, n)`` frees
        up to ``n`` blocks there and returns how many it actually freed."""
        rec = self._containers[cid]
        rec.peer_footprint_fn = footprint_fn
        rec.peer_pressure_cb = pressure_cb

    # -- demand signal -------------------------------------------------------

    def note_activity(self, cid: int, n_ops: int) -> None:
        """Record container activity (ops served); decayed at arbitration
        time so stale bursts fade and idle containers donate first."""
        self._containers[cid].demand += n_ops

    def note_degraded(self, cid: int, n_blocks: int) -> None:
        """A container reports its re-replication backlog (blocks still
        below their replication factor after a drain round).  The report
        is a live admission throttle: while ``degraded_blocks > 0`` the
        container's lease grants are shed to its ``min_pages`` floor (no
        growth on top of an unrepaired backlog), and operators can watch
        ``stats.n_degraded_reports`` / ``ContainerRecord.degraded_blocks``
        for stuck repairs.  ``clear_degraded`` releases the throttle when
        the repair queue drains."""
        self._containers[cid].degraded_blocks = int(n_blocks)
        self.stats.n_degraded_reports += 1
        self._forward_degraded()

    def clear_degraded(self, cid: int) -> None:
        """The container's repair backlog drained (its ``RepairQueue``
        emptied): drop the admission throttle so growth resumes.  Without
        this release path a container that ever reported degraded would be
        pinned at its floor forever."""
        rec = self._containers[cid]
        if rec.degraded_blocks == 0:
            return
        rec.degraded_blocks = 0
        self.stats.n_degraded_clears += 1
        self._forward_degraded()

    def _forward_degraded(self) -> None:
        """Aggregate the per-container backlog and fan it in to the cluster
        coordinator (storm admission watches per-host degradation)."""
        if self.cluster is None:
            return
        total = sum(r.degraded_blocks for r in self._containers.values())
        self.cluster.note_host_degraded(self.host_id, total)

    # -- accounting ----------------------------------------------------------

    def free(self) -> int:
        return self._free

    def available_for(self, cid: int) -> int:
        """Host pages this container could hold in total: the free slab,
        what it already leases, plus the co-tenants' *reclaimable excess*
        (their lease above the ``min_pages`` floor — what weighted-fair
        reclamation could pull back for this container).  Advertising the
        excess is what lets a grower's lease request exceed the bare free
        count and trigger reclamation of idle containers' memory; it is a
        cap input, not a promise — grants are cut to what donors actually
        free.  With one container this is the constant slab size — the
        plain ``free_memory_fn`` parity contract."""
        own = self._containers[cid].leased
        donatable = sum(r.leased - r.min_pages
                        for r in self._containers.values()
                        if r.cid != cid and r.donate_cb is not None
                        and r.leased > r.min_pages)
        headroom = 0 if self.cluster is None \
            else self.cluster.headroom_for(self.host_id)
        return self._free + own + donatable + headroom

    def grantable_for(self, cid: int) -> int:
        """Lower bound on what ``lease(cid, ...)`` would grant right now
        without reclamation: the free slab capped at the container's lease
        room — shed to its floor deficit while it reports a repair backlog
        (the degraded admission throttle).  The batch planner's capacity
        prediction uses this instead of the bare free count so it never
        promises growth the throttle will refuse."""
        rec = self._containers[cid]
        room = rec.max_pages - rec.leased
        if rec.degraded_blocks > 0:
            room = min(room, max(rec.min_pages - rec.leased, 0))
        return max(0, min(room, self._free))

    def fair_share(self, cid: int) -> int:
        """Weighted fair allocation: the floor plus this container's weight
        share of the slab surplus beyond all floors."""
        rec = self._containers[cid]
        floors = sum(r.min_pages for r in self._containers.values())
        weights = sum(r.weight for r in self._containers.values())
        surplus = max(self.total_pages - floors, 0)
        return rec.min_pages + int(surplus * rec.weight / weights)

    # -- lease / return ------------------------------------------------------

    def lease(self, cid: int, want: int) -> int:
        """Grant up to ``want`` pages (one batched call per grow step).

        Shortfalls trigger weighted-fair reclamation from other containers
        before the grant is cut; the grant may still be partial when donors
        cannot free enough."""
        rec = self._containers[cid]
        self.stats.n_lease_calls += 1
        want = min(want, rec.max_pages - rec.leased)
        if rec.degraded_blocks > 0:
            # degraded-mode shedding: a live repair backlog caps grants at
            # the min_pages floor (already reserved at register), so a
            # container cannot grow on top of unreplicated blocks.
            # clear_degraded lifts the cap when the backlog drains.
            capped = min(want, max(rec.min_pages - rec.leased, 0))
            if capped < want:
                self.stats.n_degraded_denials += 1
            want = capped
        if want <= 0:
            return 0
        if want > self._free:
            cd = self._cooldown.get(cid, 0)
            if cd > 0:
                self._cooldown[cid] = cd - 1
            elif self._reclaim_for(cid, want - self._free) == 0:
                self._cooldown[cid] = self.FUTILE_COOLDOWN
            if want > self._free and self.cluster is not None:
                # still short after local arbitration: ask the cluster pool
                # for more slab (storm admission may stagger or deny this).
                got = self.cluster.lease_slab(self.host_id,
                                              want - self._free)
                if got > 0:
                    self.total_pages += got
                    self._free += got
        granted = min(want, self._free)
        if granted < want:
            self.stats.n_partial_grants += 1
        if granted > 0:
            self._free -= granted
            rec.leased += granted
            rec.n_leases += 1
            rec.pages_leased_total += granted
        return granted

    def release(self, cid: int, n: int) -> None:
        """Return ``n`` leased pages to the slab (pool shrink / donation)."""
        if n <= 0:
            return
        rec = self._containers[cid]
        assert rec.leased - n >= 0, (rec.leased, n)
        rec.leased -= n
        self._free += n
        self._cooldown.clear()
        self.stats.n_release_calls += 1

    # -- weighted-fair reclamation ------------------------------------------

    def _reclaim_for(self, cid: int, need: int) -> int:
        """Pull ~``need`` pages back from other containers.

        Donor order is idle-first (lowest decayed demand, cid tie-break for
        determinism).  Pass 1 sheds donors above their weighted fair share
        down to it; pass 2, only if still short, sheds any donor down to its
        ``min_pages`` floor.  Donors free pages via their callback (which
        calls ``release`` internally), so progress is measured on the free
        counter, not on promises.  Returns the pages actually freed."""
        self.stats.n_reclaim_events += 1
        total_got = 0
        donors = sorted(
            (r for r in self._containers.values()
             if r.cid != cid and r.donate_cb is not None),
            key=lambda r: (r.demand, r.cid))
        for floor_of in (lambda r: max(r.min_pages, self.fair_share(r.cid)),
                         lambda r: r.min_pages):
            for rec in donors:
                if need <= 0:
                    break
                excess = rec.leased - floor_of(rec)
                if excess <= 0:
                    continue
                free_before = self._free
                rec.donate_cb(min(excess, need))
                got = self._free - free_before
                rec.pages_donated_total += got
                self.stats.pages_reclaimed += got
                need -= got
                total_got += got
        # age the demand signal so one historic burst does not shield a
        # now-idle container from donating forever
        for rec in self._containers.values():
            rec.demand *= self.demand_decay
        return total_got

    # -- coordinated remote pressure (§3.4 + §3.5) ---------------------------

    def peer_pressure(self, peer: int, blocks_to_free: int) -> int:
        """Fan a remote peer's memory pressure out across containers.

        Without coordination each container only sees its own MR blocks, so
        a pressured peer must signal every sender separately and idle
        containers' blocks survive while busy ones churn.  Here the
        coordinator routes the demand: containers that actually occupy the
        peer (non-zero ``footprint_fn``) free blocks idle-first (lowest
        decayed demand, cid tie-break — the same donor order as host-memory
        reclamation), each asked for at most its own footprint.  Returns
        the blocks actually freed (migrated or evicted per each
        container's policy); may fall short when footprints do."""
        if blocks_to_free <= 0:
            return 0
        self.stats.n_peer_pressure_events += 1
        holders = sorted(
            (r for r in self._containers.values()
             if r.peer_pressure_cb is not None
             and r.peer_footprint_fn is not None),
            key=lambda r: (r.demand, r.cid))
        freed = 0
        for rec in holders:
            if freed >= blocks_to_free:
                break
            fp = rec.peer_footprint_fn(peer)
            if fp <= 0:
                continue
            ask = min(fp, blocks_to_free - freed)
            got = rec.peer_pressure_cb(peer, ask)
            rec.peer_blocks_freed_total += got
            self.stats.peer_blocks_freed += got
            freed += got
        for rec in self._containers.values():
            rec.demand *= self.demand_decay
        return freed

    # -- invariants (property tests) ----------------------------------------

    def containers(self) -> List[ContainerRecord]:
        return list(self._containers.values())

    def check_invariants(self) -> None:
        leased = sum(r.leased for r in self._containers.values())
        assert leased + self._free == self.total_pages, \
            f"slab not conserved: {leased} leased + {self._free} free " \
            f"!= {self.total_pages}"
        assert self._free >= 0
        for rec in self._containers.values():
            assert rec.min_pages <= rec.leased <= rec.max_pages, \
                f"{rec.name}: leased {rec.leased} outside " \
                f"[{rec.min_pages}, {rec.max_pages}]"
            if rec.size_fn is not None:
                size = rec.size_fn()
                assert size == rec.leased, \
                    f"{rec.name}: pool size {size} != leased {rec.leased}"
