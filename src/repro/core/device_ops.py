"""Device-side data plane for the Valet page pools (pure jnp, jit-able).

The pool is a fixed array of page slots per layer:
  K/V pool: (n_slots, page_size, n_kv_heads, head_dim)

All ops are functional (return new arrays) and static-shaped so they compose
with jit/pjit; the control plane (pool.py/tiering.py) decides *which* slots,
the data plane only moves bytes.  On TPU the gather/append paths are the
Pallas kernels (``repro.kernels.paged_attention``); these jnp versions are
the oracle + CPU path.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KVPool(NamedTuple):
    """One layer's paged KV storage."""
    k: jax.Array        # (n_slots, page, n_kv, hd)
    v: jax.Array


def make_kv_pool(n_slots, page, n_kv, hd, dtype=jnp.bfloat16) -> KVPool:
    shape = (n_slots, page, n_kv, hd)
    return KVPool(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def append_token(pool: KVPool, k, v, slot, offset) -> KVPool:
    """Write one token's K/V into (slot, offset) per batch element.

    k, v: (B, n_kv, hd); slot, offset: (B,) int32.  The write completes into
    the *local pool* — the paper's critical-path contract: callers never wait
    for any remote traffic.
    """
    return KVPool(
        pool.k.at[slot, offset].set(k),
        pool.v.at[slot, offset].set(v),
    )


def append_token_masked(pool: KVPool, k, v, slot, offset, own_mask) -> KVPool:
    """Masked append for sharded pools: only the owning rank writes."""
    slot = jnp.where(own_mask, slot, pool.k.shape[0])      # OOB -> dropped
    return KVPool(
        pool.k.at[slot, offset].set(k, mode="drop"),
        pool.v.at[slot, offset].set(v, mode="drop"),
    )


def gather_pages(pool: KVPool, slots):
    """slots: (B, P) int32 (-1 = pad).  Returns k,v (B, P, page, n_kv, hd)
    and a page-valid mask (B, P)."""
    valid = slots >= 0
    safe = jnp.maximum(slots, 0)
    return pool.k[safe], pool.v[safe], valid


def write_prefill_pages(pool: KVPool, k_pages, v_pages, slots) -> KVPool:
    """Bulk-insert prefill KV.  k_pages: (B, P, page, n_kv, hd);
    slots: (B, P) int32 (-1 = skip)."""
    flat_slots = slots.reshape(-1)
    kf = k_pages.reshape((-1,) + k_pages.shape[2:])
    vf = v_pages.reshape((-1,) + v_pages.shape[2:])
    safe = jnp.where(flat_slots >= 0, flat_slots, pool.k.shape[0])
    return KVPool(
        pool.k.at[safe].set(kf, mode="drop"),
        pool.v.at[safe].set(vf, mode="drop"),
    )


def local_write_batch(pool: KVPool, k_pages, v_pages, slots) -> KVPool:
    """Bulk local-pool write: scatter ``n`` whole pages into their slots.

    k_pages/v_pages: (n, page, n_kv, hd); slots: (n,) int32.  This is the
    device-side primitive behind a ``TieredPageStore`` data plane's
    ``local_write_batch(pages, slots)`` hook: the adapter resolves its
    logical page ids to page data, then lands the whole alloc run with one
    ``.at[slots].set`` scatter instead of one device update per page (the
    critical-path contract is unchanged: the write completes into the
    local pool, no remote traffic).  ``slots`` must be distinct — an alloc
    run pops each pool slot at most once, and XLA scatter-set does not
    define an update order for duplicate indices."""
    return KVPool(
        pool.k.at[slots].set(k_pages),
        pool.v.at[slots].set(v_pages),
    )


@partial(jax.jit, donate_argnums=(0, 1))
def _stream_page_jit(pk, pv, k, v, slot):
    return pk.at[slot].set(k), pv.at[slot].set(v)


def stream_page(pool: KVPool, k, v, slot) -> KVPool:
    """On-demand single-page stream-in (the zero-restore miss path).

    k/v: one page ``(page, n_kv, hd)``; ``slot`` a scalar index.  Restore in
    the zero-restore engine is block-table repointing for every page whose
    slot survived preemption untouched; only pages whose slot was *reused*
    come back through here, one host read each, instead of the legacy bulk
    per-layer ``local_write_batch`` scatter over the whole sequence.  The
    pool buffers are donated (in-place scatter, no pool-sized copy) and the
    slot is a traced argument, so every streamed page of a layer shares one
    compiled program."""
    return KVPool(*_stream_page_jit(
        pool.k, pool.v,
        jnp.asarray(k, pool.k.dtype), jnp.asarray(v, pool.v.dtype),
        jnp.asarray(slot, jnp.int32)))


def copy_block(pool: KVPool, src_slot: jax.Array, dst_slot: jax.Array) -> KVPool:
    """Migration data plane: copy one slot's page (same pool or after a
    cross-device transfer).  Functional; a few HBM reads+writes."""
    return KVPool(
        pool.k.at[dst_slot].set(pool.k[src_slot]),
        pool.v.at[dst_slot].set(pool.v[src_slot]),
    )


def extract_blocks(pool: KVPool, slots):
    """Read slots out of the pool (spill to host tier).  (n, page, kv, hd)."""
    return pool.k[slots], pool.v[slots]


def insert_blocks(pool: KVPool, ks, vs, slots) -> KVPool:
    """Insert blocks fetched from a slower tier back into the pool."""
    return KVPool(pool.k.at[slots].set(ks), pool.v.at[slots].set(vs))


# -- host tier ----------------------------------------------------------------

def to_host_tier(x):
    """Spill an array to the host memory tier.

    On TPU this uses the jax memories API (``memory_kind="pinned_host"``) —
    an async DMA that leaves the data device-addressable; on backends
    without host memory kinds it falls back to a host numpy copy.  Either
    way the Valet contract holds: the spill is off the critical path and
    round-trips exactly.
    """
    import numpy as np
    try:
        s = x.sharding.with_memory_kind("pinned_host")
        return jax.device_put(x, s)
    except Exception:
        return np.asarray(x)


def from_host_tier(x, like=None):
    """Fetch a spilled array back toward HBM (inverse of ``to_host_tier``)."""
    try:
        if like is not None and hasattr(like, "sharding"):
            return jax.device_put(x, like.sharding)
        return jnp.asarray(x)
    except Exception:
        return jnp.asarray(x)


# -- ring buffer for sliding-window layers -----------------------------------

class RingKV(NamedTuple):
    k: jax.Array        # (B, W, n_kv, hd)
    v: jax.Array


def make_ring(batch, window, n_kv, hd, dtype=jnp.bfloat16) -> RingKV:
    return RingKV(jnp.zeros((batch, window, n_kv, hd), dtype),
                  jnp.zeros((batch, window, n_kv, hd), dtype))


def ring_append(ring: RingKV, k, v, pos) -> RingKV:
    """k, v: (B, n_kv, hd); pos: scalar int (global step)."""
    w = ring.k.shape[1]
    idx = pos % w
    return RingKV(ring.k.at[:, idx].set(k), ring.v.at[:, idx].set(v))


def ring_valid(ring: RingKV, pos):
    """(B, W) validity mask after ``pos + 1`` tokens written."""
    w = ring.k.shape[1]
    b = ring.k.shape[0]
    filled = jnp.minimum(pos + 1, w)
    m = jnp.arange(w)[None, :] < filled
    return jnp.broadcast_to(m, (b, w))
