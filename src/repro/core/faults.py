"""Fault-injection subsystem: peer health states, repair queue, injector.

Valet's fault-tolerance story (paper §5.1/§5.3, Table 3) needs more than the
one-shot ``fail_peer`` sweep to be believable: production remote-memory
peers *blip* (transient network faults), crash in correlated groups (rack
power), and come back — and each of those must degrade latency before it
degrades durability.  This module holds the pieces that are independent of
the store proper:

* ``HealthState`` / ``PeerHealth`` — the per-peer state machine

      UP --suspect--> SUSPECT --recover--> UP
      UP/SUSPECT/REJOINING --down--> DOWN --rejoin--> REJOINING --activate--> UP

  SUSPECT carries a deadline (``suspect_timeout_us`` of simulated time): if
  no ``recover`` arrives first, the store's health poll escalates to DOWN.
  Illegal transitions are rejected (return ``False``), never raised — the
  injector replays seeded schedules that may race a timeout escalation.

* ``RepairQueue`` — degraded primary blocks awaiting re-replication.  FIFO
  with membership dedup; drained off the critical path by
  ``TieredPageStore._drain_repairs`` (sync ticks) or the async daemon.

* ``FaultInjector`` — a deterministic, op-indexed failure schedule driven
  against a live store: ``advance(n_ops)`` after each driven chunk fires
  every due event.  Schedule builders for the canonical scenarios (transient
  blip, permanent crash, correlated multi-peer failure, rejoin-driven
  recovery storm) plus a seeded random generator for fuzz traces.

Everything here is simulation-deterministic: no wall clock, no RNG except
the explicitly seeded ``random_schedule``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from collections import deque
from typing import Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np


class HealthState(enum.IntEnum):
    UP = 0
    SUSPECT = 1
    DOWN = 2
    REJOINING = 3


# legal (from, to) edges of the per-peer state machine
_LEGAL = {
    (HealthState.UP, HealthState.SUSPECT),         # transient fault observed
    (HealthState.SUSPECT, HealthState.UP),         # blip healed in time
    (HealthState.SUSPECT, HealthState.DOWN),       # timeout / crash
    (HealthState.UP, HealthState.DOWN),            # hard crash
    (HealthState.REJOINING, HealthState.DOWN),     # crashed while rejoining
    (HealthState.DOWN, HealthState.REJOINING),     # operator brought it back
    (HealthState.REJOINING, HealthState.UP),       # first healthy poll
}


class PeerHealth:
    """Per-peer health state machine with a transition log.

    All times are simulated microseconds on the ``stats.time_us`` axis.
    Transition methods return True when the edge was legal and taken.
    """

    def __init__(self, n_peers: int, *, suspect_timeout_us: float = 50_000.0):
        self.n_peers = int(n_peers)
        self.suspect_timeout_us = float(suspect_timeout_us)
        n = max(self.n_peers, 1)
        self.state = np.zeros(n, np.int8)            # HealthState values
        self.since_us = np.zeros(n, np.float64)      # last transition time
        self._deadline = np.full(n, np.inf)          # SUSPECT escalation
        self.transitions: List[Tuple[int, str, str, float]] = []

    def _move(self, peer: int, to: HealthState, now: float) -> bool:
        cur = HealthState(int(self.state[peer]))
        if (cur, to) not in _LEGAL:
            return False
        self.state[peer] = int(to)
        self.since_us[peer] = now
        self.transitions.append((peer, cur.name, to.name, now))
        if to is not HealthState.SUSPECT:
            self._deadline[peer] = np.inf
        return True

    # -- transitions ---------------------------------------------------------

    def suspect(self, peer: int, now: float) -> bool:
        if self._move(peer, HealthState.SUSPECT, now):
            self._deadline[peer] = now + self.suspect_timeout_us
            return True
        return False

    def recover(self, peer: int, now: float) -> bool:
        return self._move(peer, HealthState.UP, now) \
            if self.state[peer] == int(HealthState.SUSPECT) else False

    def down(self, peer: int, now: float) -> bool:
        return self._move(peer, HealthState.DOWN, now)

    def rejoin(self, peer: int, now: float) -> bool:
        return self._move(peer, HealthState.REJOINING, now)

    def activate(self, peer: int, now: float) -> bool:
        return self._move(peer, HealthState.UP, now) \
            if self.state[peer] == int(HealthState.REJOINING) else False

    # -- queries -------------------------------------------------------------

    def state_of(self, peer: int) -> HealthState:
        return HealthState(int(self.state[peer]))

    def expired_suspects(self, now: float) -> List[int]:
        """SUSPECT peers whose escalation deadline has passed."""
        hit = (self.state == int(HealthState.SUSPECT)) \
            & (self._deadline <= now)
        return np.flatnonzero(hit).tolist()

    def rejoining_peers(self) -> List[int]:
        return np.flatnonzero(
            self.state == int(HealthState.REJOINING)).tolist()

    def any_transient(self) -> bool:
        """True while any peer sits in a transitional state (SUSPECT or
        REJOINING) — the store's lazy poll condition."""
        return bool(np.any((self.state == int(HealthState.SUSPECT))
                           | (self.state == int(HealthState.REJOINING))))

    def counts(self) -> dict:
        return {s.name: int(np.count_nonzero(self.state == int(s)))
                for s in HealthState}


class RepairQueue:
    """Degraded primary blocks awaiting re-replication (FIFO, deduped).

    Keys are MR block ids ``(peer, slot)``.  Pushed by ``fail_peer`` (a
    crash stripped copies) and by block placement when the replica
    allocation came up short; drained by ``_drain_repairs`` off the
    critical path.  A block that cannot be repaired yet (no live peer has
    room) is re-queued — the queue length is the store's degradation
    signal (coordinator admission throttling keys off it)."""

    def __init__(self):
        self._q: deque = deque()
        self._set: Set[Tuple[int, int]] = set()
        self.n_enqueued = 0
        self.n_repaired = 0
        self.n_requeued = 0

    def push(self, key: Tuple[int, int]) -> bool:
        if key in self._set:
            return False
        self._set.add(key)
        self._q.append(key)
        self.n_enqueued += 1
        return True

    def requeue(self, key: Tuple[int, int]) -> None:
        if key not in self._set:
            self._set.add(key)
            self._q.append(key)
            self.n_requeued += 1

    def pop(self) -> Tuple[int, int]:
        key = self._q.popleft()
        self._set.discard(key)
        return key

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __contains__(self, key) -> bool:
        return key in self._set


# -- deterministic fault schedules --------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault action, keyed by absolute trace op index."""
    at_op: int
    kind: str                      # suspect | recover | crash | rejoin
    peers: Tuple[int, ...]

    _KINDS = ("suspect", "recover", "crash", "rejoin")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {self._KINDS})")


@dataclass
class FaultInjector:
    """Drive a deterministic fault schedule against a live store.

    The driver calls ``advance(n_ops)`` after each executed trace chunk;
    every event whose ``at_op`` has been reached fires in schedule order
    (stable-sorted by ``at_op``).  Events map onto the store's fault API —
    ``mark_suspect`` / ``clear_suspect`` / ``fail_peer`` / ``rejoin_peer``
    — and the per-event outcome is recorded in ``log`` (kind, peer, the
    store method's return), so a replayed seed yields an identical log.

    Works unchanged against sync and async stores: the API is the store's
    in both modes, and all events land *between* driven chunks, never
    mid-op (mid-epoch for async stores — chunks need not align with epoch
    boundaries)."""

    store: object
    events: Sequence[FaultEvent]
    ops: int = 0
    log: List[Tuple[int, str, int, object]] = field(default_factory=list)

    def __post_init__(self):
        self._sched = sorted(self.events, key=lambda e: e.at_op)
        self._i = 0

    @property
    def done(self) -> bool:
        return self._i >= len(self._sched)

    def advance(self, n_ops: int) -> int:
        """Account ``n_ops`` executed ops; fire every due event.  Returns
        the number of events fired."""
        self.ops += int(n_ops)
        fired = 0
        while self._i < len(self._sched) \
                and self._sched[self._i].at_op <= self.ops:
            ev = self._sched[self._i]
            self._i += 1
            for peer in ev.peers:
                self.log.append((self.ops, ev.kind, peer,
                                 self._fire(ev.kind, peer)))
            fired += 1
        return fired

    def _fire(self, kind: str, peer: int):
        s = self.store
        if kind == "suspect":
            return s.mark_suspect(peer)
        if kind == "recover":
            return s.clear_suspect(peer)
        if kind == "crash":
            return s.fail_peer(peer)
        return s.rejoin_peer(peer)


def transient_blip(peer: int, at_op: int, duration_ops: int
                   ) -> List[FaultEvent]:
    """SUSPECT for ``duration_ops`` ops, then heal (UP)."""
    return [FaultEvent(at_op, "suspect", (peer,)),
            FaultEvent(at_op + duration_ops, "recover", (peer,))]


def crash(peer: int, at_op: int) -> List[FaultEvent]:
    """Permanent failure: UP/SUSPECT -> DOWN, recovery sweep + repair."""
    return [FaultEvent(at_op, "crash", (peer,))]


def correlated_crash(peers: Iterable[int], at_op: int) -> List[FaultEvent]:
    """Multi-peer (rack-scale) failure: every peer drops at one op."""
    return [FaultEvent(at_op, "crash", tuple(peers))]


def recovery_storm(peers: Iterable[int], at_op: int) -> List[FaultEvent]:
    """All crashed peers rejoin at once — the repair-drain stress case."""
    return [FaultEvent(at_op, "rejoin", tuple(peers))]


def standard_schedule(n_ops: int, *, blip_peer: int = 0,
                      crash_peer: int = 1,
                      correlated_peers: Tuple[int, int] = (2, 3)
                      ) -> List[FaultEvent]:
    """The canonical four-phase schedule used by the ``fault_recovery``
    benchmark and the recovery tests, scaled to an ``n_ops`` trace:

      phase 1 (~10-25%): transient blip on ``blip_peer`` (retry/backoff)
      phase 2 (~40%):    permanent crash of ``crash_peer`` (repair kicks in)
      phase 3 (~60%):    correlated two-peer crash (rack failure)
      phase 4 (~75%):    recovery storm — all three dead peers rejoin
    """
    evs = transient_blip(blip_peer, n_ops // 10, max(1, 3 * n_ops // 20))
    evs += crash(crash_peer, 2 * n_ops // 5)
    evs += correlated_crash(correlated_peers, 3 * n_ops // 5)
    evs += recovery_storm((crash_peer,) + tuple(correlated_peers),
                          3 * n_ops // 4)
    return evs


def peers_in_domain(domains: Sequence[int], domain: int) -> Tuple[int, ...]:
    """Every peer id in one failure domain (rack) — the unit a correlated
    failure takes out.  ``domains`` maps peer -> domain id (see
    ``cluster.PeerProfile`` / ``draw_peer_profiles``)."""
    return tuple(p for p, d in enumerate(domains) if d == domain)


def domain_correlated_crash(domains: Sequence[int], domain: int,
                            at_op: int) -> List[FaultEvent]:
    """Rack-scale correlated crash: every peer in ``domain`` drops at one
    op.  With strictly cross-domain replica placement this must never lose
    a replicated page — the cluster benchmark gates exactly that."""
    peers = peers_in_domain(domains, domain)
    assert peers, f"failure domain {domain} holds no peers"
    return correlated_crash(peers, at_op)


def domain_recovery_storm(domains: Sequence[int], domain: int,
                          at_op: int) -> List[FaultEvent]:
    """The whole rack rejoins at once — the cross-host repair-drain and
    storm-admission stress case."""
    peers = peers_in_domain(domains, domain)
    assert peers, f"failure domain {domain} holds no peers"
    return recovery_storm(peers, at_op)


def cluster_schedule(n_ops: int, domains: Sequence[int], *,
                     crash_domain: Optional[int] = None
                     ) -> List[FaultEvent]:
    """The canonical cluster churn schedule (``cluster_tenant`` benchmark
    and the cross-host convergence tests), scaled to an ``n_ops`` trace:

      phase 1 (~40%): correlated crash of one whole failure domain
      phase 2 (~70%): rack-wide recovery storm — every dead peer rejoins

    ``crash_domain`` defaults to the highest domain id (by convention the
    far rack).  Identical inputs yield an identical schedule."""
    if crash_domain is None:
        crash_domain = max(domains)
    evs = domain_correlated_crash(domains, crash_domain, 2 * n_ops // 5)
    evs += domain_recovery_storm(domains, crash_domain, 7 * n_ops // 10)
    return evs


def random_schedule(n_ops: int, n_peers: int, *, seed: int = 0,
                    n_events: int = 8) -> List[FaultEvent]:
    """Seeded random fault schedule for fuzz traces.

    Events may be redundant (crashing a DOWN peer, recovering an UP one) —
    the injector fires them anyway and the store's fault API treats illegal
    transitions as no-ops, which is itself part of what the fuzz tests pin.
    Identical ``(n_ops, n_peers, seed)`` yield an identical schedule."""
    rng = np.random.default_rng(seed)
    kinds = FaultEvent._KINDS
    evs = []
    for _ in range(n_events):
        at = int(rng.integers(1, max(2, n_ops)))
        kind = kinds[int(rng.integers(0, len(kinds)))]
        peer = int(rng.integers(0, max(1, n_peers)))
        evs.append(FaultEvent(at, kind, (peer,)))
    return evs
