"""Whole-store invariant checking + statistical stats equivalence.

The synchronous ``TieredPageStore`` is verified by *bitwise parity* suites
(scalar reference vs vectorized paths reach identical state).  The async
orchestration engine deliberately breaks bitwise parity — flush cadence,
victim order and placement draws all shift once daemon work overlaps the
critical path — so its verification tier is this module:

* ``InvariantChecker``: every safety property the paper's protocol promises,
  checked against the live store state.  Runs after every epoch in the async
  tests; passes trivially (and is also exercised) on the synchronous store.
* ``stats_close``: statistical-equivalence bounds between a sync and an
  async run of the same trace — the workload-visible counters (hits per
  tier, evictions, migrations) must agree within tolerance even though
  their exact interleavings differ.

The checks, mapped to the paper:

1. **No lost writes** (§3.1 reliability, §5.2): every IN_USE pool slot is
   reachable — it is staged for remote send or parked in the §5.2 deferred
   map.  An IN_USE slot outside both would hold the only copy of a write
   with nothing scheduled to ever send it.
2. **§5.2 write-set safety**: a page's latest pending slot is IN_USE (never
   RECLAIMABLE/FREE/held — reclaiming it would lose the newest data), and
   the page table maps the page to exactly that slot.
3. **Slab/page conservation**: pool FREE accounting (free stack + epoch
   holds) is exact; per-peer MR block counts match the dense membership
   columns and the block dict.
4. **Replica-index consistency** (§3.3): ``_replica_of`` and
   ``block_replicas`` are mutual inverses and agree with the dense
   ``_blk_replica`` flags.
5. **Mapping coherence**: local page-table entries point at slots owned by
   that page; PEER-mapped pages appear in their block's page list; the
   host-tier dict and its dense mirror agree.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.pool import SlotState

_IN_USE = int(SlotState.IN_USE)
_RECLAIMABLE = int(SlotState.RECLAIMABLE)


class InvariantError(AssertionError):
    """An invariant violation, with enough context to debug the trace."""


def _fail(msg: str):
    raise InvariantError(msg)


class InvariantChecker:
    """Checks every protocol invariant of one ``TieredPageStore``.

    Usage::

        chk = InvariantChecker(store)
        chk.check()          # raises InvariantError on the first violation

    Cheap enough to run after every epoch in tests (vectorized gathers over
    the SoA columns; the dict walks touch only live blocks/replicas).
    """

    def __init__(self, store):
        self.store = store
        self.n_checks = 0

    def check(self):
        self.n_checks += 1
        s = self.store
        # the queue/pool layer asserts its own conservation laws (free stack
        # + holds exactness, staged slots IN_USE, §5.2 flag canonicality)
        s.pipeline.check_invariants()
        self._check_no_lost_writes()
        self._check_write_set_safety()
        self._check_local_mappings()
        self._check_block_conservation()
        self._check_replica_index()
        self._check_gpt_block_containment()
        self._check_host_tier()
        self._check_peer_health()
        self._check_domain_disjointness()

    # -- 1. no lost writes ----------------------------------------------------

    def _check_no_lost_writes(self):
        s = self.store
        pool = s.pool
        in_use = set(np.flatnonzero(pool.state == _IN_USE).tolist())
        staged = {int(sl) for ws in s.pipeline.staging.entries()
                  for sl in ws.slots}
        defer = s.pipeline._defer
        deferred = set(defer[defer >= 0].tolist())
        orphans = in_use - staged - deferred
        if orphans:
            _fail(f"lost writes: IN_USE slots {sorted(orphans)[:8]} are "
                  "neither staged nor §5.2-deferred — nothing will ever "
                  "send or reclaim them")
        if staged - in_use:
            _fail("staged slot not IN_USE")
        if deferred - in_use:
            _fail("§5.2 deferred slot not IN_USE")

    # -- 2. §5.2 write-set safety ---------------------------------------------

    def _check_write_set_safety(self):
        s = self.store
        pend = s.pipeline._pend
        pgs = np.flatnonzero(pend >= 0)
        if not pgs.size:
            return
        slots = pend[pgs]
        st = s.pool.state[slots]
        if np.any(st != _IN_USE):
            bad = int(pgs[np.argmax(st != _IN_USE)])
            _fail(f"page {bad}: pending slot {int(pend[bad])} is "
                  f"{SlotState(int(s.pool.state[pend[bad]])).name}, "
                  "not IN_USE — the newest write could be reclaimed")
        # the page table must expose exactly the newest write
        lsl = s.gpt._l_slot
        known = pgs[pgs < lsl.shape[0]]
        if known.size < pgs.size:
            _fail("pending page beyond the page table")
        mism = known[lsl[known] != pend[known]]
        if mism.size:
            pg = int(mism[0])
            _fail(f"page {pg}: page table maps slot {int(lsl[pg])} but the "
                  f"pending (newest) slot is {int(pend[pg])}")

    # -- 5a. local mapping coherence ------------------------------------------

    def _check_local_mappings(self):
        s = self.store
        lsl = s.gpt._l_slot
        pgs = np.flatnonzero(lsl >= 0)
        if not pgs.size:
            return
        slots = lsl[pgs]
        owners = s.pool.owner[slots]
        if np.any(owners != pgs):
            i = int(np.argmax(owners != pgs))
            _fail(f"page {int(pgs[i])} maps local slot {int(slots[i])} "
                  f"owned by page {int(owners[i])}")
        st = s.pool.state[slots]
        bad = (st != _IN_USE) & (st != _RECLAIMABLE)
        if np.any(bad):
            i = int(np.argmax(bad))
            _fail(f"page {int(pgs[i])} maps local slot {int(slots[i])} in "
                  f"state {SlotState(int(st[i])).name}")

    # -- 3b. MR block conservation --------------------------------------------

    def _check_block_conservation(self):
        s = self.store
        by_peer: List[set] = [set() for _ in s.peers]
        for (p, slot) in s.blocks:
            by_peer[p].add(slot)
        for p, peer in enumerate(s.peers):
            hi = s._next_block_slot[p]
            if np.any(s._blk_live[p][hi:]):
                _fail(f"peer {p}: live flag beyond next_block_slot {hi}")
            live = set(np.flatnonzero(s._blk_live[p][:hi]).tolist())
            if live != by_peer[p]:
                _fail(f"peer {p}: dense live column {sorted(live)[:8]} != "
                      f"block dict {sorted(by_peer[p])[:8]}")
            if peer.used != len(live):
                _fail(f"peer {p}: used={peer.used} but {len(live)} live "
                      "blocks")
            if peer.used > peer.capacity:
                _fail(f"peer {p}: used {peer.used} over capacity")

    # -- 4. replica index bidirectionality ------------------------------------

    def _check_replica_index(self):
        s = self.store
        n_flagged = sum(int(np.count_nonzero(col)) for col in s._blk_replica)
        if n_flagged != len(s._replica_of):
            _fail(f"{n_flagged} replica flags set but {len(s._replica_of)} "
                  "reverse-index entries")
        for rep, prim in s._replica_of.items():
            rp, rs = rep
            if not s._blk_replica[rp][rs]:
                _fail(f"replica block {rep} missing its dense flag")
            if rep not in s.blocks:
                _fail(f"replica block {rep} not allocated")
            if rep not in tuple(s.block_replicas.get(prim, ())):
                _fail(f"replica {rep} not in primary {prim}'s replica list")
        for prim, reps in s.block_replicas.items():
            if prim not in s.blocks:
                _fail(f"primary {prim} has replicas but is not allocated")
            for rep in reps:
                if s._replica_of.get(tuple(rep)) != prim:
                    _fail(f"replica list of {prim} names {tuple(rep)} whose "
                          "reverse index disagrees")

    # -- 5b. GPT -> block containment -----------------------------------------

    def _check_gpt_block_containment(self):
        s = self.store
        gpt = s.gpt
        from repro.core.page_table import Tier
        peer_t = int(Tier.PEER)
        pgs = np.flatnonzero(gpt._r_tier == peer_t)
        for pg in pgs.tolist():
            loc = gpt.remote_location(pg)
            if loc is None:
                continue
            key = (loc.peer, loc.slot)
            members = s.blocks.get(key)
            if members is None:
                _fail(f"page {pg} maps PEER block {key} which is freed")
            elif pg not in members:
                _fail(f"page {pg} maps PEER block {key} but is not in its "
                      "page list")

    # -- 5c. host tier dict / dense mirror ------------------------------------

    def _check_host_tier(self):
        s = self.store
        dense = set(np.flatnonzero(s._host_mask).tolist())
        keys = set(s.host_pages.keys())
        if dense != keys:
            _fail("host_pages dict and dense mask diverge: "
                  f"{sorted(dense ^ keys)[:8]}")

    # -- 6. peer health / fault handling (§5.1, Table 3) ----------------------

    def _check_peer_health(self):
        """A DOWN peer holds nothing: no mapped page, no live MR block,
        no replica tuple on a survivor still naming it — and the dense
        failure cache agrees with the per-peer flags."""
        s = self.store
        gpt = s.gpt
        from repro.core.page_table import Tier
        peer_t = int(Tier.PEER)
        for p, peer in enumerate(s.peers):
            if bool(s._peer_failed[p]) != peer.failed:
                _fail(f"peer {p}: _peer_failed cache "
                      f"{bool(s._peer_failed[p])} != PeerState.failed "
                      f"{peer.failed}")
            if not peer.failed:
                continue
            mapped = (gpt._r_tier == peer_t) & (gpt._r_peer == p) \
                & gpt._r_mapped
            if np.any(mapped):
                pg = int(np.argmax(mapped))
                _fail(f"page {pg} still mapped on DOWN peer {p}")
            hi = s._next_block_slot[p]
            if np.any(s._blk_live[p][:hi]):
                sl = int(np.argmax(s._blk_live[p][:hi]))
                _fail(f"DOWN peer {p} still holds live block slot {sl}")
            if peer.used != 0:
                _fail(f"DOWN peer {p} reports used={peer.used}")
        failed = {p for p, peer in enumerate(s.peers) if peer.failed}
        if failed:
            for pg, reps in gpt._replicas.items():
                for r in reps:
                    if r[0] in failed:
                        _fail(f"page {pg} keeps stale replica {tuple(r)} "
                              f"on DOWN peer {r[0]}")
            for rep in s._replica_of:
                if rep[0] in failed:
                    _fail(f"replica block {rep} lives on DOWN peer "
                          f"{rep[0]}")

    # -- 6b. failure-domain disjointness (cluster-scale placement) ------------

    def _check_domain_disjointness(self):
        """With failure domains configured (``peer_profiles``), every
        replica of every block lives in a domain distinct from its
        primary's and from every sibling replica's — the law that makes a
        correlated rack failure survivable.  Unconditional because the
        placer has no same-domain fallback (a short replica set goes to
        the repair queue instead).  No-op on flat peer sets."""
        s = self.store
        doms = getattr(s, "_peer_domain", None)
        if doms is None:
            return
        for prim, reps in s.block_replicas.items():
            seen = {doms[prim[0]]}
            for r in reps:
                d = doms[r[0]]
                if d in seen:
                    _fail(f"block {prim} (domain {doms[prim[0]]}) has "
                          f"replica {tuple(r)} in an already-occupied "
                          f"failure domain {d}")
                seen.add(d)

    # -- 7. repair quiesced => replication restored (opt-in barrier) ----------

    def check_replication_restored(self, factor: int = None):
        """After ``repair_quiesce`` the store must be back at full
        durability: an empty repair queue and every live primary block
        that still backs mapped pages carrying >= ``factor`` replicas
        (default ``policy.replication``).  Not part of ``check()`` — mid-
        trace a degraded block is legal; this is the recovery benchmark's
        end-of-phase assertion."""
        self.n_checks += 1
        s = self.store
        R = s.policy.replication if factor is None else int(factor)
        if R <= 0:
            return
        if len(s.repairq):
            _fail(f"repair queue still holds {len(s.repairq)} degraded "
                  "blocks after quiesce")
        gpt = s.gpt
        from repro.core.page_table import Tier
        peer_t = int(Tier.PEER)
        referenced = set()
        for pg in np.flatnonzero((gpt._r_tier == peer_t)
                                 & gpt._r_mapped).tolist():
            loc = gpt.remote_location(pg)
            if loc is not None:
                referenced.add((loc.peer, loc.slot))
        for key in referenced:
            if key in s._replica_of:
                continue               # replicas are counted via the primary
            have = len(tuple(s.block_replicas.get(key, ())))
            if have < R:
                _fail(f"block {key} still degraded after quiesce: "
                      f"{have}/{R} replicas")


# -- statistical equivalence ---------------------------------------------------

def stats_close(sync_stats, async_stats, *, rtol: float = 0.15,
                atol: int = 64) -> bool:
    """Do two runs of the same trace tell the same workload story?

    Bitwise time/stall comparisons are meaningless across orchestration
    modes; what must agree are the workload-visible counters.  Each counter
    pair must satisfy ``|a - b| <= atol + rtol * max(a, b)`` — ``atol``
    absorbs small-count jitter (a handful of extra evictions), ``rtol``
    bounds the drift on large counters (hit counts in the millions).
    """
    fields = ("ops", "writes", "local_hits", "remote_hits", "host_hits",
              "cold_hits", "evictions", "migrations")
    for f in fields:
        a = getattr(sync_stats, f)
        b = getattr(async_stats, f)
        if abs(a - b) > atol + rtol * max(a, b):
            return False
    return True


def stats_delta(sync_stats, async_stats) -> dict:
    """The per-counter deltas behind a ``stats_close`` verdict (debugging)."""
    fields = ("ops", "writes", "local_hits", "remote_hits", "host_hits",
              "cold_hits", "evictions", "migrations")
    return {f: (getattr(sync_stats, f), getattr(async_stats, f))
            for f in fields}
