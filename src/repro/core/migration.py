"""Sender-driven migration protocol (paper §3.5, Figure 14).

When a remote peer is memory-pressured it must reclaim MR blocks.  Valet
*migrates* the victim block to a less-pressured peer instead of deleting it:

  1. peer's activity monitor reports pressure to the sender
  2. sender selects the victim (least-active block, ``activity.py``) and the
     destination (power-of-two-choices over peer free memory)
  3. sender parks new writes to the migrating block in its local mempool
     staging queue (reads continue against the source block)
  4. source copies the block to the destination (data plane)
  5. sender cuts the page table over, unparks writes, frees the source block

The sender owns the whole control flow (receivers are passive), so messages
are naturally serialized and no extra ordering protocol is needed.  The
explicit message log makes the protocol unit-testable and mirrors Figure 14.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.activity import ActivityTracker, power_of_two_choices, \
    select_victims_nad
from repro.core.page_table import GlobalPageTable, Location, Tier


class Phase(enum.Enum):
    IDLE = 0
    PREPARE = 1       # destination chosen, writes being parked
    COPYING = 2       # data plane copy in flight; reads served from source
    CUTOVER = 3       # page table repoint + unpark writes
    DONE = 4
    ABORTED = 5


@dataclass
class Message:
    """One protocol message (for the log / tests)."""
    src: str
    dst: str
    kind: str
    payload: dict = field(default_factory=dict)


@dataclass
class Migration:
    block: int                    # victim block id (pool slot on source peer)
    pages: List[int]              # logical pages in the block
    src_peer: int
    dst_peer: int
    dst_slot: int = -1
    phase: Phase = Phase.IDLE
    log: List[Message] = field(default_factory=list)


class MigrationEngine:
    """Drives migrations; the caller supplies data/metadata callbacks.

    copy_fn(src_peer, src_slot, dst_peer, dst_slot): data-plane block copy
    alloc_fn(peer) -> slot | None: allocate an MR slot on a peer
    free_fn(peer, slot): release an MR slot
    park_fn(pages, hold: bool): park/unpark writes (staging queue hold)
    """

    def __init__(self, gpt: GlobalPageTable, tracker: ActivityTracker,
                 free_counts_fn: Callable[[], Sequence[int]],
                 copy_fn, alloc_fn, free_fn, park_fn,
                 rng: Optional[np.random.Generator] = None):
        self.gpt = gpt
        self.tracker = tracker
        self.free_counts_fn = free_counts_fn
        self.copy_fn = copy_fn
        self.alloc_fn = alloc_fn
        self.free_fn = free_fn
        self.park_fn = park_fn
        self.rng = rng or np.random.default_rng(0)
        self.completed: List[Migration] = []
        self.aborted: List[Migration] = []
        # counters
        self.n_migrated_blocks = 0
        self.n_migrated_pages = 0

    # -- entry point: a peer signals memory pressure --------------------------

    def handle_pressure(self, src_peer: int, blocks_to_free: int,
                        block_pages: Callable[[int], List[int]],
                        candidate_blocks: Sequence[int], step: int
                        ) -> List[Migration]:
        """Select least-active victims on ``src_peer`` and migrate them."""
        victims = select_victims_nad(self.tracker, candidate_blocks,
                                     blocks_to_free, step)
        out = []
        for blk in victims:
            mig = self.migrate_block(src_peer, blk, block_pages(blk))
            out.append(mig)
        return out

    # -- one block migration ---------------------------------------------------

    def migrate_block(self, src_peer: int, block: int,
                      pages: List[int]) -> Migration:
        mig = Migration(block=block, pages=list(pages), src_peer=src_peer,
                        dst_peer=-1)

        # 2. destination: power-of-two-choices over free counts, != source
        free = list(self.free_counts_fn())
        dst = power_of_two_choices(free, self.rng, exclude=[src_peer])
        if dst is None or free[dst] <= 0:
            mig.phase = Phase.ABORTED
            mig.log.append(Message("sender", "sender", "NO_DESTINATION"))
            self.aborted.append(mig)
            return mig
        mig.dst_peer = dst
        mig.log.append(Message("sender", f"peer{dst}", "ALLOC_REQ",
                               {"block": block}))
        slot = self.alloc_fn(dst)
        if slot is None:
            mig.phase = Phase.ABORTED
            mig.log.append(Message(f"peer{dst}", "sender", "ALLOC_FAIL"))
            self.aborted.append(mig)
            return mig
        mig.dst_slot = slot
        mig.log.append(Message(f"peer{dst}", "sender", "ALLOC_OK",
                               {"slot": slot}))

        # 3. park writes; reads keep hitting the source block (Figure 12)
        mig.phase = Phase.PREPARE
        self.park_fn(mig.pages, True)
        mig.log.append(Message("sender", "sender", "PARK_WRITES",
                               {"pages": len(mig.pages)}))

        # 4. data-plane copy (source -> destination, sender-coordinated)
        mig.phase = Phase.COPYING
        mig.log.append(Message("sender", f"peer{src_peer}", "COPY_REQ",
                               {"dst": dst, "dst_slot": slot}))
        self.copy_fn(src_peer, block, dst, slot)
        mig.log.append(Message(f"peer{src_peer}", "sender", "COPY_DONE"))

        # 5. cutover: repoint pages, unpark writes, free source block
        mig.phase = Phase.CUTOVER
        for pg in mig.pages:
            loc = self.gpt.remote_location(pg)
            reps = loc.replicas if loc else ()
            self.gpt.map_remote(pg, Location(Tier.PEER, peer=dst, slot=slot,
                                             replicas=reps))
        self.park_fn(mig.pages, False)
        self.free_fn(src_peer, block)
        mig.log.append(Message("sender", f"peer{src_peer}", "FREE_BLOCK",
                               {"block": block}))

        mig.phase = Phase.DONE
        self.completed.append(mig)
        self.n_migrated_blocks += 1
        self.n_migrated_pages += len(mig.pages)
        return mig
