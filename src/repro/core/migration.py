"""Sender-driven migration protocol (paper §3.5, Figure 14).

When a remote peer is memory-pressured it must reclaim MR blocks.  Valet
*migrates* the victim block to a less-pressured peer instead of deleting it:

  1. peer's activity monitor reports pressure to the sender
  2. sender selects the victim (least-active block, ``activity.py``) and the
     destination (power-of-two-choices over peer free memory)
  3. sender parks new writes to the migrating block in its local mempool
     staging queue (reads continue against the source block)
  4. source copies the block to the destination (data plane)
  5. sender cuts the page table over, unparks writes, frees the source block

The sender owns the whole control flow (receivers are passive), so messages
are naturally serialized and no extra ordering protocol is needed.  The
explicit message log makes the protocol unit-testable and mirrors Figure 14.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.activity import ActivityTracker, power_of_two_choices, \
    select_victims_nad, select_victims_topk
from repro.core.page_table import GlobalPageTable, Location, Tier


class Phase(enum.Enum):
    IDLE = 0
    PREPARE = 1       # destination chosen, writes being parked
    COPYING = 2       # data plane copy in flight; reads served from source
    CUTOVER = 3       # page table repoint + unpark writes
    DONE = 4
    ABORTED = 5


@dataclass
class Message:
    """One protocol message (for the log / tests)."""
    src: str
    dst: str
    kind: str
    payload: dict = field(default_factory=dict)


@dataclass
class Migration:
    block: int                    # victim block id (pool slot on source peer)
    pages: List[int]              # logical pages in the block
    src_peer: int
    dst_peer: int
    dst_slot: int = -1
    phase: Phase = Phase.IDLE
    log: List[Message] = field(default_factory=list)


class MigrationEngine:
    """Drives migrations; the caller supplies data/metadata callbacks.

    copy_fn(src_peer, src_slot, dst_peer, dst_slot): data-plane block copy
    alloc_fn(peer) -> slot | None: allocate an MR slot on a peer
    free_fn(peer, slot): release an MR slot
    park_fn(pages, hold: bool): park/unpark writes (staging queue hold)
    """

    def __init__(self, gpt: GlobalPageTable, tracker: ActivityTracker,
                 free_counts_fn: Callable[[], Sequence[int]],
                 copy_fn, alloc_fn, free_fn, park_fn,
                 rng: Optional[np.random.Generator] = None):
        self.gpt = gpt
        self.tracker = tracker
        self.free_counts_fn = free_counts_fn
        self.copy_fn = copy_fn
        self.alloc_fn = alloc_fn
        self.free_fn = free_fn
        self.park_fn = park_fn
        self.rng = rng or np.random.default_rng(0)
        self.completed: List[Migration] = []
        self.aborted: List[Migration] = []
        # failure-domain awareness (core/cluster.py): ``domains`` maps peer
        # -> failure-domain id and ``replica_peers_fn(block) -> peers``
        # reports where a block's replicas live.  When both are set, a
        # migration never lands a primary in a domain already holding one
        # of its replicas (the correlated-rack-failure guarantee survives
        # migration).  Left at None, destination choice is untouched —
        # bitwise identical draws.
        self.domains: Optional[Sequence[int]] = None
        self.replica_peers_fn: Optional[Callable[[int], Sequence[int]]] = None
        # counters
        self.n_migrated_blocks = 0
        self.n_migrated_pages = 0
        # optional accounting hook, called once per completed block copy
        # with the page count — the async orchestrator charges the copy to
        # its daemon clock (block transfers overlap the critical path)
        self.on_block_copied: Optional[Callable[[int], None]] = None

    # -- entry point: a peer signals memory pressure --------------------------

    def handle_pressure(self, src_peer: int, blocks_to_free: int,
                        block_pages: Callable[[int], List[int]],
                        candidate_blocks: Sequence[int], step: int,
                        batched: bool = False) -> List[Migration]:
        """Select least-active victims on ``src_peer`` and migrate them.

        ``batched=True`` takes the vectorized path: one dense top-k over the
        ``ActivityTracker`` arrays picks all victims in one shot and
        ``migrate_batch`` repoints every affected page with a single
        ``GlobalPageTable`` scatter.  The result (page table, peer state,
        counters, victim order) is identical to the scalar loop."""
        if batched:
            victims = select_victims_topk(self.tracker, candidate_blocks,
                                          blocks_to_free, step)
            return self.migrate_batch(src_peer, victims, block_pages)
        victims = select_victims_nad(self.tracker, candidate_blocks,
                                     blocks_to_free, step)
        out = []
        for blk in victims:
            mig = self.migrate_block(src_peer, blk, block_pages(blk))
            out.append(mig)
        return out

    # -- destination selection --------------------------------------------------

    def _choose_destination(self, src_peer: int, free: Sequence[int],
                            avoid_domains: Sequence[int] = ()
                            ) -> Optional[int]:
        """p2c over free counts; if both sampled peers are pressured, fall
        back to a full scan (freest peer wins, lowest id breaks ties) before
        giving up — repeated pressure no longer aborts into eviction while a
        free peer exists.  ``avoid_domains`` (failure-domain ids) strikes
        whole racks from both the p2c draw and the fallback scan."""
        exclude = [src_peer]
        if avoid_domains and self.domains is not None:
            bad = set(avoid_domains)
            exclude += [p for p, d in enumerate(self.domains)
                        if d in bad and p != src_peer]
        dst = power_of_two_choices(free, self.rng, exclude=exclude)
        if dst is not None and free[dst] > 0:
            return dst
        barred = set(exclude)
        best, best_free = None, 0
        for i, f in enumerate(free):
            if i not in barred and f > best_free:
                best, best_free = i, f
        return best

    def _avoid_domains_for(self, block: int, pages: Sequence[int]
                           ) -> Sequence[int]:
        """Failure domains holding a replica of this block's pages — the
        migrated primary must not join them.  Empty when domain awareness
        is off."""
        if self.domains is None or self.replica_peers_fn is None:
            return ()
        return sorted({self.domains[p]
                       for p in self.replica_peers_fn(block)
                       if 0 <= p < len(self.domains)})

    # -- one block migration ---------------------------------------------------

    def migrate_block(self, src_peer: int, block: int,
                      pages: List[int]) -> Migration:
        mig = Migration(block=block, pages=list(pages), src_peer=src_peer,
                        dst_peer=-1)

        # 2. destination: power-of-two-choices over free counts, != source
        # (and, with domain awareness, != any rack holding a replica)
        free = list(self.free_counts_fn())
        dst = self._choose_destination(src_peer, free,
                                       self._avoid_domains_for(block, pages))
        if dst is None:
            mig.phase = Phase.ABORTED
            mig.log.append(Message("sender", "sender", "NO_DESTINATION"))
            self.aborted.append(mig)
            return mig
        mig.dst_peer = dst
        mig.log.append(Message("sender", f"peer{dst}", "ALLOC_REQ",
                               {"block": block}))
        slot = self.alloc_fn(dst)
        if slot is None:
            mig.phase = Phase.ABORTED
            mig.log.append(Message(f"peer{dst}", "sender", "ALLOC_FAIL"))
            self.aborted.append(mig)
            return mig
        mig.dst_slot = slot
        mig.log.append(Message(f"peer{dst}", "sender", "ALLOC_OK",
                               {"slot": slot}))

        # 3. park writes; reads keep hitting the source block (Figure 12)
        mig.phase = Phase.PREPARE
        self.park_fn(mig.pages, True)
        mig.log.append(Message("sender", "sender", "PARK_WRITES",
                               {"pages": len(mig.pages)}))

        # 4. data-plane copy (source -> destination, sender-coordinated)
        mig.phase = Phase.COPYING
        mig.log.append(Message("sender", f"peer{src_peer}", "COPY_REQ",
                               {"dst": dst, "dst_slot": slot}))
        self.copy_fn(src_peer, block, dst, slot)
        mig.log.append(Message(f"peer{src_peer}", "sender", "COPY_DONE"))

        # 5. cutover: repoint pages, unpark writes, free source block
        mig.phase = Phase.CUTOVER
        for pg in mig.pages:
            loc = self.gpt.remote_location(pg)
            reps = loc.replicas if loc else ()
            self.gpt.map_remote(pg, Location(Tier.PEER, peer=dst, slot=slot,
                                             replicas=reps))
        self.park_fn(mig.pages, False)
        self.free_fn(src_peer, block)
        mig.log.append(Message("sender", f"peer{src_peer}", "FREE_BLOCK",
                               {"block": block}))

        mig.phase = Phase.DONE
        self.completed.append(mig)
        self.n_migrated_blocks += 1
        self.n_migrated_pages += len(mig.pages)
        if self.on_block_copied is not None:
            self.on_block_copied(len(mig.pages))
        return mig

    # -- batched migration (vectorized reclaim pipeline) ------------------------

    def migrate_batch(self, src_peer: int, blocks: Sequence[int],
                      block_pages: Callable[[int], List[int]]
                      ) -> List[Migration]:
        """Migrate several victim blocks with ONE page-table scatter.

        Per victim, the control decisions stay sequential and identical to
        ``migrate_block`` — destination choice consumes the same rng stream
        against the same free counts (each victim's alloc/free lands before
        the next victim's p2c draw) — but the per-page work is hoisted out:
        writes are parked/unparked with two staging-queue scans instead of
        two per block, and every affected page is repointed by a single
        ``map_remote_batch`` scatter (victim order preserved, so duplicate
        pages keep last-writer-wins parity with the scalar loop).  The
        Figure-14 protocol message log is elided on this path (the scalar
        reference keeps it); abort reasons are still logged."""
        infos = [(blk, list(block_pages(blk))) for blk in blocks]
        all_pages = [pg for _, pgs in infos for pg in pgs]
        # 3. park once for the whole batch; reads keep hitting the sources
        self.park_fn(all_pages, True)

        migs: List[Migration] = []
        done: List[Migration] = []
        # free counts tracked incrementally: each dst alloc is -1, each src
        # free is +1 — exactly the transitions ``free_counts_fn`` would
        # report between victims (the src entry may drift for a failed src,
        # but the source is never a destination candidate)
        free = list(self.free_counts_fn())
        for blk, pages in infos:
            mig = Migration(block=blk, pages=pages, src_peer=src_peer,
                            dst_peer=-1)
            migs.append(mig)
            dst = self._choose_destination(src_peer, free,
                                           self._avoid_domains_for(blk,
                                                                   pages))
            if dst is None:
                mig.phase = Phase.ABORTED
                mig.log.append(Message("sender", "sender", "NO_DESTINATION"))
                self.aborted.append(mig)
                continue
            mig.dst_peer = dst
            slot = self.alloc_fn(dst)
            if slot is None:
                mig.phase = Phase.ABORTED
                mig.log.append(Message(f"peer{dst}", "sender", "ALLOC_FAIL"))
                self.aborted.append(mig)
                continue
            mig.dst_slot = slot
            free[dst] -= 1
            # 4. data-plane copy; source freed before the next victim's p2c
            # so destination choices see the same free counts as the scalar
            # loop (which completes each migration before starting the next)
            mig.phase = Phase.COPYING
            self.copy_fn(src_peer, blk, dst, slot)
            self.free_fn(src_peer, blk)
            free[src_peer] += 1
            done.append(mig)

        # 5. cutover: ONE scatter repoints every migrated page (replicas are
        # preserved, fetched in bulk), then unpark with one scan
        if done:
            mv_pages = [pg for mig in done for pg in mig.pages]
            mv_peers = [mig.dst_peer for mig in done for _ in mig.pages]
            mv_slots = [mig.dst_slot for mig in done for _ in mig.pages]
            reps = self.gpt.replicas_batch(mv_pages)
            self.gpt.map_remote_batch(
                mv_pages, [int(Tier.PEER)] * len(mv_pages), mv_peers,
                mv_slots, reps)
        self.park_fn(all_pages, False)
        for mig in done:
            mig.phase = Phase.DONE
            self.completed.append(mig)
            self.n_migrated_blocks += 1
            self.n_migrated_pages += len(mig.pages)
            if self.on_block_copied is not None:
                self.on_block_copied(len(mig.pages))
        return migs
