"""Global Page Table (paper §4.1) — logical page -> physical location.

The paper uses a radix tree (pointer-chasing, host-friendly).  On an
accelerator control plane we keep the same contract with flat dense tables:
O(1) lookup, grow-on-demand, and the paper's simple existence rule — *if a
local mapping exists the page is local; otherwise it is remote* — which
avoids lock contention on updates (here: avoids read-modify-write races
between the scheduler thread and the flush thread).

Tiers mirror DESIGN.md §2: LOCAL HBM pool -> PEER device HBM -> HOST DRAM ->
COLD (recompute / disk analogue).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np


class Tier(enum.IntEnum):
    NONE = 0
    LOCAL = 1      # local HBM pool slot
    PEER = 2       # another device's spill pool (RDMA MR analogue)
    HOST = 3       # host DRAM (pinned) tier
    COLD = 4       # disk / recompute analogue


@dataclass(frozen=True)
class Location:
    tier: Tier
    peer: int = -1          # peer id for Tier.PEER / host id for Tier.HOST
    slot: int = -1          # slot within that tier's pool
    replicas: Tuple[Tuple[int, int], ...] = ()   # [(peer, slot)] extra copies


class GlobalPageTable:
    """logical page id -> Location (+ optional local pool slot)."""

    def __init__(self):
        self._local: Dict[int, int] = {}          # page -> local pool slot
        self._remote: Dict[int, Location] = {}    # page -> remote location

    # -- local mapping (the paper's "page reference exists -> local") --------

    def map_local(self, page: int, slot: int):
        self._local[page] = slot

    def unmap_local(self, page: int) -> Optional[int]:
        return self._local.pop(page, None)

    def local_slot(self, page: int) -> Optional[int]:
        return self._local.get(page)

    # -- remote mapping -------------------------------------------------------

    def map_remote(self, page: int, loc: Location):
        self._remote[page] = loc

    def remote_location(self, page: int) -> Optional[Location]:
        return self._remote.get(page)

    def drop_remote(self, page: int):
        self._remote.pop(page, None)

    def lookup(self, page: int) -> Location:
        """Resolution order: local pool, then remote, then NONE."""
        slot = self._local.get(page)
        if slot is not None:
            return Location(Tier.LOCAL, slot=slot)
        return self._remote.get(page, Location(Tier.NONE))

    def pages_on_peer(self, peer: int) -> List[int]:
        return [pg for pg, loc in self._remote.items()
                if loc.tier == Tier.PEER and loc.peer == peer]

    def repoint_replica(self, page: int) -> bool:
        """Peer failure: promote the first replica to primary (Table 3)."""
        loc = self._remote.get(page)
        if loc is None or not loc.replicas:
            return False
        (peer, slot), rest = loc.replicas[0], loc.replicas[1:]
        self._remote[page] = Location(loc.tier, peer=peer, slot=slot,
                                      replicas=rest)
        return True

    def __len__(self):
        return len(self._local) + len(
            set(self._remote) - set(self._local))

    # -- dense device-facing view ---------------------------------------------

    def block_table(self, pages: List[int], n_peers: int,
                    pages_per_peer: int) -> np.ndarray:
        """Dense per-peer gather lists for the data plane.

        Returns int32 [n_peers, pages_per_peer] of tier-slot ids (-1 pad) —
        the device-side view the paged-attention kernel consumes.  Pages in
        the LOCAL tier are listed under peer 0's pool by convention of the
        caller (serving engine passes separate local lists).
        """
        out = np.full((n_peers, pages_per_peer), -1, np.int32)
        fill = [0] * n_peers
        for pg in pages:
            loc = self.lookup(pg)
            if loc.tier == Tier.PEER and fill[loc.peer] < pages_per_peer:
                out[loc.peer, fill[loc.peer]] = loc.slot
                fill[loc.peer] += 1
        return out
