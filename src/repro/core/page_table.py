"""Global Page Table (paper §4.1) — logical page -> physical location.

The paper uses a radix tree (pointer-chasing, host-friendly).  On an
accelerator control plane we keep the same contract with flat dense tables:
O(1) lookup, grow-on-demand, and the paper's simple existence rule — *if a
local mapping exists the page is local; otherwise it is remote* — which
avoids lock contention on updates (here: avoids read-modify-write races
between the scheduler thread and the flush thread).

The backing store is a set of dense numpy arrays indexed by logical page id
(page ids are small sequential ints in both the simulator and the serving
engine), so a whole batch of lookups is a single vectorized gather
(``lookup_batch`` / ``local_slots_batch``) instead of a Python loop of dict
probes — the enabling piece of ``TieredPageStore.access_batch``.  Replica
lists are sparse (only replicated pages carry them) and stay dict-backed.

Tiers mirror DESIGN.md §2: LOCAL HBM pool -> PEER device HBM -> HOST DRAM ->
COLD (recompute / disk analogue).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


class Tier(enum.IntEnum):
    NONE = 0
    LOCAL = 1      # local HBM pool slot
    PEER = 2       # another device's spill pool (RDMA MR analogue)
    HOST = 3       # host DRAM (pinned) tier
    COLD = 4       # disk / recompute analogue
    # demoted-but-resident: the page's pool slot was released (preemption /
    # reclaim) but its bytes are still untouched in device memory, so a
    # later access can *repoint* to the old slot instead of reading a copy
    # back (zero-restore serving; see core/tiers.DeviceTier).  Stored in the
    # remote columns with ``slot`` = the shadow pool slot.
    DEVICE = 5


@dataclass(frozen=True)
class Location:
    tier: Tier
    peer: int = -1          # peer id for Tier.PEER / host id for Tier.HOST
    slot: int = -1          # slot within that tier's pool
    replicas: Tuple[Tuple[int, int], ...] = ()   # [(peer, slot)] extra copies


class GlobalPageTable:
    """logical page id -> Location (+ optional local pool slot).

    Scalar API (``map_local`` / ``lookup`` / ...) is unchanged from the
    dict-backed version; the ``*_batch`` methods operate on int arrays and
    are the fast path for batched orchestration.
    """

    def __init__(self, initial_pages: int = 1024):
        n = max(int(initial_pages), 1)
        self._l_slot = np.full(n, -1, np.int64)    # page -> local pool slot
        self._r_tier = np.zeros(n, np.int8)        # page -> remote tier
        self._r_peer = np.full(n, -1, np.int32)
        self._r_slot = np.full(n, -1, np.int64)
        self._r_mapped = np.zeros(n, bool)         # remote entry exists
        self._replicas: Dict[int, Tuple[Tuple[int, int], ...]] = {}

    # -- capacity ------------------------------------------------------------

    def _ensure(self, page: int):
        """Grow the dense tables to cover ``page`` (geometric growth)."""
        n = self._l_slot.shape[0]
        if page < n:
            return
        new = max(n * 2, page + 1)

        def grow(arr, fill):
            out = np.full(new, fill, arr.dtype)
            out[:n] = arr
            return out

        self._l_slot = grow(self._l_slot, -1)
        self._r_tier = grow(self._r_tier, 0)
        self._r_peer = grow(self._r_peer, -1)
        self._r_slot = grow(self._r_slot, -1)
        self._r_mapped = grow(self._r_mapped, False)

    # -- local mapping (the paper's "page reference exists -> local") --------

    def map_local(self, page: int, slot: int):
        self._ensure(page)
        self._l_slot[page] = slot

    def unmap_local(self, page: int) -> Optional[int]:
        if page >= self._l_slot.shape[0]:
            return None
        slot = self._l_slot[page]
        if slot < 0:
            return None
        self._l_slot[page] = -1
        return int(slot)

    def local_slot(self, page: int) -> Optional[int]:
        if page >= self._l_slot.shape[0]:
            return None
        slot = self._l_slot[page]
        return None if slot < 0 else int(slot)

    # -- remote mapping -------------------------------------------------------

    def map_remote(self, page: int, loc: Location):
        page = int(page)
        self._ensure(page)
        self._r_tier[page] = int(loc.tier)
        self._r_peer[page] = loc.peer
        self._r_slot[page] = loc.slot
        self._r_mapped[page] = True
        if loc.replicas:
            self._replicas[page] = tuple(loc.replicas)
        else:
            self._replicas.pop(page, None)

    def remote_location(self, page: int) -> Optional[Location]:
        page = int(page)
        if page >= self._r_mapped.shape[0] or not self._r_mapped[page]:
            return None
        return Location(Tier(int(self._r_tier[page])),
                        peer=int(self._r_peer[page]),
                        slot=int(self._r_slot[page]),
                        replicas=self._replicas.get(page, ()))

    def drop_remote(self, page: int):
        page = int(page)
        if page >= self._r_mapped.shape[0]:
            return
        self._r_mapped[page] = False
        self._r_tier[page] = 0
        self._r_peer[page] = -1
        self._r_slot[page] = -1
        self._replicas.pop(page, None)

    def lookup(self, page: int) -> Location:
        """Resolution order: local pool, then remote, then NONE."""
        slot = self.local_slot(page)
        if slot is not None:
            return Location(Tier.LOCAL, slot=slot)
        return self.remote_location(page) or Location(Tier.NONE)

    def pages_on_peer(self, peer: int) -> List[int]:
        mask = (self._r_tier == int(Tier.PEER)) & (self._r_peer == peer) \
            & self._r_mapped
        return [int(p) for p in np.flatnonzero(mask)]

    def repoint_replica(self, page: int, alive=None) -> bool:
        """Peer failure: promote the first replica to primary (Table 3).

        ``alive`` (optional ``peer -> bool``) filters the candidate set: a
        replica on a DOWN peer is never promoted and is dropped from the
        surviving tuple (correlated failures would otherwise promote a
        dead copy)."""
        page = int(page)
        reps = self._replicas.get(page)
        if page >= self._r_mapped.shape[0] or not self._r_mapped[page] \
                or not reps:
            return False
        if alive is not None:
            reps = tuple(r for r in reps if alive(r[0]))
            if not reps:
                return False
        (peer, slot), rest = reps[0], reps[1:]
        self.map_remote(page, Location(Tier(int(self._r_tier[page])),
                                       peer=peer, slot=slot, replicas=rest))
        return True

    def purge_replicas_on_peer(self, peer: int) -> int:
        """Strip every replica tuple entry living on ``peer`` (peer death):
        a surviving primary's page must never carry — let alone later
        promote — a replica on a DOWN peer.  Returns pages touched."""
        rd = self._replicas
        if not rd:
            return 0
        n = 0
        for pg in list(rd):
            reps = rd[pg]
            kept = tuple(r for r in reps if r[0] != peer)
            if len(kept) != len(reps):
                n += 1
                if kept:
                    rd[pg] = kept
                else:
                    del rd[pg]
        return n

    def add_replica_batch(self, pages, primary: Tuple[int, int],
                          rep: Tuple[int, int]) -> int:
        """Append replica ``rep`` to every page still mapped with
        ``primary`` as its remote block (the re-replication repair path:
        one mask over the block's page list instead of per-page lookups).
        Pages that moved on — overwritten, migrated, promoted — are
        skipped.  Returns pages updated."""
        parr = np.asarray(pages, np.int64)
        if not parr.size:
            return 0
        self._ensure(int(parr.max()))
        mask = (self._r_tier[parr] == int(Tier.PEER)) \
            & (self._r_peer[parr] == primary[0]) \
            & (self._r_slot[parr] == primary[1]) \
            & self._r_mapped[parr]
        hit = parr[mask]
        rd = self._replicas
        for pg in hit.tolist():
            cur = rd.get(pg, ())
            if rep not in cur:
                rd[pg] = cur + (rep,)
        return int(hit.size)

    def __len__(self):
        return int(np.count_nonzero((self._l_slot >= 0) | self._r_mapped))

    # -- vectorized batch operations (the access_batch fast path) -------------

    def lookup_batch(self, pages: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized ``lookup`` for a whole batch: one gather per table.

        Returns ``(tier, peer, slot)`` int arrays; local mappings override
        remote ones exactly as in the scalar resolution order.
        """
        pages = np.asarray(pages, np.int64)
        if pages.size:
            self._ensure(int(pages.max()))
        l_slot = self._l_slot[pages]
        is_local = l_slot >= 0
        tier = np.where(is_local, np.int8(Tier.LOCAL), self._r_tier[pages])
        peer = np.where(is_local, np.int32(-1), self._r_peer[pages])
        slot = np.where(is_local, l_slot, self._r_slot[pages])
        return tier, peer, slot

    def lookup_raw(self, pages: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Raw gathers for hot callers: ``(local_slot, remote_tier,
        remote_peer)`` with no local-override blending — callers derive
        their own masks (a local slot >= 0 wins, as in ``lookup``)."""
        pages = np.asarray(pages, np.int64)
        if pages.size:
            self._ensure(int(pages.max()))
        return self._l_slot[pages], self._r_tier[pages], self._r_peer[pages]

    def lookup_raw_known(self, pages: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``lookup_raw`` minus the growth check, for pages the caller has
        already resolved this batch (the tables cannot have shrunk since).
        This is the targeted re-gather used after a boundary reclaim: only
        the invalidated pages are re-classified, so the gather is a handful
        of rows instead of the whole remaining batch."""
        return self._l_slot[pages], self._r_tier[pages], self._r_peer[pages]

    def remote_raw_batch(self, pages: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
        """Vectorized ``remote_location`` essentials: ``(tier, peer, slot,
        mapped)`` arrays — ``mapped`` False where no remote entry exists."""
        pages = np.asarray(pages, np.int64)
        if pages.size:
            self._ensure(int(pages.max()))
        return (self._r_tier[pages], self._r_peer[pages],
                self._r_slot[pages], self._r_mapped[pages])

    def replicas_batch(self, pages) -> List[Tuple[Tuple[int, int], ...]]:
        """Replica tuples per page (``()`` where none) — bulk counterpart of
        reading ``remote_location(pg).replicas``."""
        rd = self._replicas
        if not rd:
            return [()] * len(pages)
        return [rd.get(int(pg), ()) for pg in pages]

    def has_replicas(self) -> bool:
        """True if any page currently carries replica copies."""
        return bool(self._replicas)

    def map_remote_batch(self, pages, tiers, peers, slots, replicas=None):
        """Bulk ``map_remote``: arrays of tier/peer/slot per page, plus an
        optional parallel sequence of replica tuples.  Duplicate pages keep
        last-writer-wins semantics, like sequential ``map_remote`` calls."""
        parr = np.asarray(pages, np.int64)
        if parr.size:
            self._ensure(int(parr.max()))
        self._r_tier[parr] = tiers
        self._r_peer[parr] = peers
        self._r_slot[parr] = slots
        self._r_mapped[parr] = True
        rd = self._replicas
        if replicas is None:
            if rd:
                for pg in parr.tolist():
                    rd.pop(pg, None)
        else:
            for pg, reps in zip(parr.tolist(), replicas):
                if reps:
                    rd[pg] = reps if type(reps) is tuple else tuple(reps)
                elif rd:
                    rd.pop(pg, None)

    def local_slots_batch(self, pages: np.ndarray) -> np.ndarray:
        """Vectorized ``local_slot``: int64 array, -1 where unmapped."""
        pages = np.asarray(pages, np.int64)
        if pages.size:
            self._ensure(int(pages.max()))
        return self._l_slot[pages]

    def local_slots_known(self, pages: np.ndarray) -> np.ndarray:
        """``local_slots_batch`` minus the growth check, for pages already
        covered by the tables (they were resolved or mapped before — the
        reclaim unmapper's case: every freed page was mapped once)."""
        return self._l_slot[pages]

    def map_local_known(self, pages: np.ndarray, slots: np.ndarray):
        """``map_local_batch`` minus the asarray/growth work, for int64
        page arrays the caller already resolved this batch (the segment
        engine: its snapshot gather grew the tables over the whole batch).
        Duplicate pages keep last-writer-wins, like sequential maps."""
        self._l_slot[pages] = slots

    def map_local_batch(self, pages: np.ndarray, slots: np.ndarray):
        pages = np.asarray(pages, np.int64)
        if pages.size:
            self._ensure(int(pages.max()))
        self._l_slot[pages] = slots

    def unmap_local_batch(self, pages: np.ndarray):
        pages = np.asarray(pages, np.int64)
        if pages.size:
            self._ensure(int(pages.max()))
        self._l_slot[pages] = -1

    def drop_remote_batch(self, pages):
        """Bulk ``drop_remote``: clear remote entries for a page array."""
        parr = np.asarray(pages, np.int64)
        if not parr.size:
            return
        self._ensure(int(parr.max()))
        self._r_mapped[parr] = False
        self._r_tier[parr] = 0
        self._r_peer[parr] = -1
        self._r_slot[parr] = -1
        if self._replicas:
            rd = self._replicas
            for pg in parr.tolist():
                rd.pop(pg, None)

    # -- dense device-facing view ---------------------------------------------

    def block_table(self, pages: List[int], n_peers: int,
                    pages_per_peer: int) -> np.ndarray:
        """Dense per-peer gather lists for the data plane.

        Returns int32 [n_peers, pages_per_peer] of tier-slot ids (-1 pad) —
        the device-side view the paged-attention kernel consumes.  Pages in
        the LOCAL tier are listed under peer 0's pool by convention of the
        caller (serving engine passes separate local lists).
        """
        out = np.full((n_peers, pages_per_peer), -1, np.int32)
        fill = [0] * n_peers
        for pg in pages:
            loc = self.lookup(pg)
            if loc.tier == Tier.PEER and fill[loc.peer] < pages_per_peer:
                out[loc.peer, fill[loc.peer]] = loc.slot
                fill[loc.peer] += 1
        return out
