"""Orchestration policies: Valet + the paper's comparison systems.

The policy object fixes every decision the paper varies between systems
(§6): local pool or not, lazy vs write-through sending, victim selection,
eviction action, replication, and the per-operation cost profile used by the
trace simulator (benchmarks reproduce Table 1 / Figures 19-23 with these).

Cost profiles:  ``PAPER_COSTS`` uses the measured microseconds from Table 1
(56Gbps IB + SATA disk).  ``TPU_COSTS`` re-derives each term for a v5e pod
(HBM 819 GB/s, ICI ~50 GB/s/link, PCIe-to-host ~16 GB/s, "cold" = recompute)
for a 64KiB page — the hardware-adaptation step documented in DESIGN.md §2.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CostModel:
    """Per-operation latency in microseconds (one 64KiB-page transaction)."""
    local_write: float        # store into local pool (copy + tree insert)
    local_read: float         # read hit in local pool
    remote_write: float       # one-sided write to a peer
    remote_read: float        # one-sided read from a peer
    host_write: float         # spill to host tier
    host_read: float
    cold_read: float          # disk / recompute analogue
    cold_write: float
    connect: float            # connection establishment (once per peer)
    map_block: float          # map a remote MR block (per block)
    receiver_cpu: float = 0.0 # two-sided receiver involvement (nbdX)


# Paper Table 1 (usec; disk numbers are per ~128KB burst in their setup).
PAPER_COSTS = CostModel(
    local_write=35.31,        # Valet write path total (radix+copy+enqueue)
    local_read=3.5,           # radix 1.39 + copy 2.11
    remote_write=51.35,       # RDMA WRITE
    remote_read=36.48,        # RDMA READ
    host_write=35.31,         # host tier ~ local pool in the paper's model
    host_read=3.5,
    cold_read=20_758.0,       # Disk RD
    cold_write=401_336.0,     # Disk WR
    connect=200_668.0,
    map_block=62_276.0,
    receiver_cpu=15.0,        # nbdX message-pool handling (approx)
)

# TPU v5e adaptation for a 64KiB KV page (see DESIGN.md §2):
#   HBM copy 64KiB @819GB/s ~0.08us + op overhead; ICI hop ~1us + 64KiB@50GB/s
#   ~1.3us; host DMA 64KiB @16GB/s ~4us + sync ~10us; cold = recompute a page
#   of KV from the prefix (~ms).  connect/map ~ collective setup + first-use
#   compilation of the transfer program.
TPU_COSTS = CostModel(
    local_write=2.0,
    local_read=1.0,
    remote_write=3.5,
    remote_read=2.5,
    host_write=14.0,
    host_read=12.0,
    cold_read=2_000.0,
    cold_write=2_000.0,
    connect=1_000.0,
    map_block=200.0,
    receiver_cpu=5.0,
)


@dataclass(frozen=True)
class Policy:
    """A complete orchestration policy (one per compared system)."""
    name: str
    use_local_pool: bool           # host-coordinated mempool in the path
    lazy_send: bool                # writes complete locally, sent async
    victim: str                    # nad | mass | random | none
    evict_action: str              # migrate | delete | none
    replication: int = 0          # extra copies on distinct peers
    cold_backup: bool = False
    write_through: bool = False    # no pool: remote send in critical path
    receiver_side_cpu: bool = False
    dynamic_pool: bool = True      # pool grows/shrinks with free memory
    use_remote: bool = True        # False = conventional OS swap (disk only)


VALET = Policy(
    name="valet", use_local_pool=True, lazy_send=True, victim="nad",
    evict_action="migrate", replication=1)

VALET_MASS = Policy(                     # beyond-paper victim selection
    name="valet-mass", use_local_pool=True, lazy_send=True, victim="mass",
    evict_action="migrate", replication=1)

INFINISWAP = Policy(
    name="infiniswap", use_local_pool=False, lazy_send=False, victim="random",
    evict_action="delete", cold_backup=True, write_through=True)

NBDX = Policy(
    name="nbdx", use_local_pool=False, lazy_send=False, victim="none",
    evict_action="delete", write_through=True, receiver_side_cpu=True)

OS_SWAP = Policy(
    name="os-swap", use_local_pool=False, lazy_send=False, victim="none",
    evict_action="none", write_through=True, cold_backup=True,
    use_remote=False)

POLICIES = {p.name: p for p in (VALET, VALET_MASS, INFINISWAP, NBDX, OS_SWAP)}
