"""ValetMempool — the host-coordinated local memory pool (paper §3.4, §4.1).

This is the control plane: deterministic metadata over a fixed array of page
slots whose *effective* size grows and shrinks dynamically.  The data plane
(actual K/V page arrays in HBM) lives in ``tiering.py`` / ``serve``; slots
here are indices into those arrays.

The metadata is **structure-of-arrays**: one dense numpy column per field
(``state``, ``owner``, ``last_step``, ``update_flag``, ``reclaim_flag``)
plus the free list as a stack over an int array (``_free_arr`` /
``_free_top`` — LIFO, preserving the exact pop/append order of the old
Python-list free list, which parity tests pin).  Whole reclaim bursts,
allocation runs and resize windows become masked gathers/scatters instead
of per-slot object churn; the scalar methods (``alloc``/``reclaim``/...)
keep their per-op semantics on the same arrays, and ``slots[i]`` returns a
lightweight view object for the reference paths and tests.

Paper-faithful rules (Table 2 + §4.1):

* **Use-pool-first**: allocation takes a pre-allocated free slot if one
  exists; only when the pool is exhausted does it try to *grow* (the inverse
  of Linux mempool's allocate-first).
* **Growth**: when usage reaches 80% of the current pool size, the pool
  grows on demand, capped at ``min(max_pool_pages, 50% of host free pages)``.
* **Shrink**: when host free memory drops, the pool shrinks (releasing FREE
  slots only), never below ``min_pool_pages``.
* Slot lifecycle (write path, §4.1 "Local Mempool Page Reclaim"):
  ``FREE -> IN_USE -> (staged for remote send) -> RECLAIMABLE -> FREE``.
  Reclaiming a page is a pointer move ("a few CPU cycles").
"""
from __future__ import annotations

import enum
from typing import Callable, List, Optional, Tuple

import numpy as np


class SlotState(enum.IntEnum):
    FREE = 0          # in the pool, ready to serve an allocation
    IN_USE = 1        # holds live data not yet replicated remotely
    RECLAIMABLE = 2   # remote replica exists; may be reclaimed for reuse
    UNBACKED = 3      # beyond the current effective pool size


_FREE = int(SlotState.FREE)
_IN_USE = int(SlotState.IN_USE)
_RECLAIMABLE = int(SlotState.RECLAIMABLE)
_UNBACKED = int(SlotState.UNBACKED)


class SlotView:
    """Scalar view of one slot's metadata row.

    The SoA columns are the single source of truth; this object is a
    zero-copy accessor kept for the scalar reference paths and the unit
    tests, which read and write slots as objects (``pool.slots[i].state``).
    """

    __slots__ = ("_p", "_i")

    def __init__(self, pool: "ValetMempool", i: int):
        self._p = pool
        self._i = i

    @property
    def state(self) -> SlotState:
        return SlotState(int(self._p.state[self._i]))

    @state.setter
    def state(self, v: SlotState):
        self._p.state[self._i] = int(v)

    @property
    def logical_page(self) -> int:
        return int(self._p.owner[self._i])

    @logical_page.setter
    def logical_page(self, v: int):
        self._p.owner[self._i] = v

    @property
    def last_activity(self) -> int:
        return int(self._p.last_step[self._i])

    @last_activity.setter
    def last_activity(self, v: int):
        self._p.last_step[self._i] = v

    @property
    def update_flag(self) -> bool:
        return bool(self._p.update_flag[self._i])

    @update_flag.setter
    def update_flag(self, v: bool):
        self._p.update_flag[self._i] = v

    @property
    def reclaim_flag(self) -> bool:
        return bool(self._p.reclaim_flag[self._i])

    @reclaim_flag.setter
    def reclaim_flag(self, v: bool):
        self._p.reclaim_flag[self._i] = v


class _SlotsView:
    """Sequence facade over the SoA columns (``pool.slots``)."""

    __slots__ = ("_p",)

    def __init__(self, pool: "ValetMempool"):
        self._p = pool

    def __len__(self):
        return self._p.capacity

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [SlotView(self._p, j)
                    for j in range(*i.indices(self._p.capacity))]
        return SlotView(self._p, i)

    def __iter__(self):
        return (SlotView(self._p, j) for j in range(self._p.capacity))


class ValetMempool:
    """Dynamic paged pool metadata.

    ``capacity`` is the physical slot-array size (the data-plane allocation);
    ``size`` is the current effective pool size (<= capacity), which grows
    and shrinks per the paper's rules.  ``free_memory_fn`` models host free
    pages (injected; in the serving engine it reports free HBM pages).
    """

    GROW_THRESHOLD = 0.8           # paper: grow at 80% usage
    HOST_FREE_FRACTION = 0.5       # paper: cap at 50% of host free memory

    def __init__(self, capacity: int, *, min_pages: int, max_pages: int,
                 free_memory_fn: Optional[Callable[[], int]] = None,
                 grow_step: Optional[int] = None,
                 lease=None):
        assert 0 < min_pages <= max_pages <= capacity
        self.capacity = capacity
        self.min_pages = min_pages
        self.max_pages = max_pages
        # coordinator-backed pools (``lease`` is a coordinator LeaseClient
        # whose registration already reserved ``min_pages``) probe the
        # coordinator's free slab instead of a synthetic host-free callable;
        # every grow must then be granted via ``lease.lease`` and every
        # shrink returns pages via ``lease.release``
        self.lease = lease
        if lease is not None:
            free_memory_fn = lease.available
        self.free_memory_fn = free_memory_fn or (lambda: capacity)
        self.grow_step = grow_step or max(min_pages // 2, 1)
        # structure-of-arrays slot metadata
        self.state = np.full(capacity, _UNBACKED, np.int8)
        self.owner = np.full(capacity, -1, np.int64)   # owning logical page
        self.last_step = np.zeros(capacity, np.int64)  # last write activity
        self.update_flag = np.zeros(capacity, bool)    # §5.2 newer set pends
        self.reclaim_flag = np.zeros(capacity, bool)   # §5.2 replica exists
        # per-slot allocation generation: bumped every FREE -> IN_USE
        # transition.  The device tier (core/tiers.py) validates its
        # demoted-but-resident entries against this lazily — a slot reused
        # since demotion has a newer generation — so no alloc hot path pays
        # a callback hook for zero-restore tracking.
        self.gen = np.zeros(capacity, np.int64)
        self._free_arr = np.empty(capacity, np.int64)  # free stack (LIFO)
        self._free_top = 0
        # epoch-tagged holds (async engine): slots the background daemon has
        # reclaimed but whose simulated completion has not been committed at
        # an epoch boundary yet.  Held slots are FREE-state but OFF the free
        # stack, so the foreground cannot allocate them early.  Each entry is
        # ``(epoch, finish_us, slots_array)``.
        self._held: List[Tuple[int, float, np.ndarray]] = []
        self.slots = _SlotsView(self)
        self.size = 0
        self._used = 0           # non-FREE/non-UNBACKED slots below size
        self._resize_to(min_pages)
        # counters for benchmarks / tests
        self.n_grow = 0
        self.n_shrink = 0
        self.n_alloc_from_pool = 0
        self.n_alloc_failed = 0
        self.n_reclaimed = 0
        self.n_claimed = 0       # zero-restore repoints (claim_batch)

    @property
    def _free(self) -> List[int]:
        """The free stack as a plain list, bottom to top (pop takes the last
        element) — the exact order the old list-backed free list held."""
        return self._free_arr[:self._free_top].tolist()

    # -- sizing ------------------------------------------------------------

    def _resize_to(self, new_size: int):
        new_size = max(self.min_pages, min(new_size, self.max_pages,
                                           self.capacity))
        if self._held and new_size < self.size:
            # a shrink rebuilds the free list from FREE-state slots and may
            # unback tail FREE slots — both would corrupt held slots (FREE
            # but deliberately off the list), so holds commit first
            self.commit_holds()
        state = self.state
        if new_size > self.size:
            # only back slots that are actually UNBACKED: a previous shrink
            # can strand non-FREE slots beyond the effective size (they keep
            # live data and simply return under the size here), and a
            # stranded slot released in the meantime is already on the free
            # list — blindly marking the range FREE would clobber both
            back = self.size + np.flatnonzero(
                state[self.size:new_size] == _UNBACKED)
            if back.size:
                state[back] = _FREE
                # re-backed memory is fresh pages, not the old bytes — bump
                # the generation so stale device-tier shadows never validate
                # against a slot that was unbacked in between
                self.gen[back] += 1
                top = self._free_top
                self._free_arr[top:top + back.size] = back
                self._free_top = top + back.size
        elif new_size < self.size:
            # release only FREE slots from the tail of the pool: the
            # reversed scan of the old loop releases the highest-index FREE
            # slots first, i.e. the tail suffix of the FREE set
            want = self.size - new_size
            tail_free = new_size + np.flatnonzero(
                state[new_size:self.size] == _FREE)
            rel = tail_free[max(tail_free.size - want, 0):]
            if rel.size:
                state[rel] = _UNBACKED
                fl = self._free_arr[:self._free_top]
                kept = fl[state[fl] == _FREE]       # order preserved
                self._free_arr[:kept.size] = kept
                self._free_top = int(kept.size)
            new_size = self.size - int(rel.size)
        self.size = new_size
        # resizes can strand non-FREE slots beyond the effective size, so
        # the O(1) usage counter is rebuilt here (resizes are rare events)
        s = state[:new_size]
        self._used = int(np.count_nonzero((s != _FREE) & (s != _UNBACKED)))

    def used(self) -> int:
        return self._used

    def usage_fraction(self) -> float:
        return self.used() / max(self.size, 1)

    def maybe_grow(self):
        """Paper: grow on demand at 80% usage, capped by max and host-free."""
        if self.size >= self.max_pages:
            # static pool (or already at max): growth is provably futile, so
            # skip the usage/host-free probes — the alloc path calls this on
            # every high-usage alloc (free_memory_fn is pure in this repo)
            return False
        if self.usage_fraction() < self.GROW_THRESHOLD:
            return False
        host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
        target = min(self.size + self.grow_step, self.max_pages,
                     max(host_cap, self.min_pages))
        if target <= self.size:
            return False
        if self.lease is not None:
            # coordinator-backed: the grow must be granted (one batched
            # lease per grow step); a partial grant grows partially
            granted = self.lease.lease(target - self.size)
            if granted <= 0:
                return False
            target = self.size + granted
        old = self.size
        self._resize_to(target)
        grew = self.size > old
        self.n_grow += int(grew)
        return grew

    def ensure_free(self, n: int) -> bool:
        """Grow (leasing if coordinator-backed) until ``n`` slots are FREE.

        Unlike ``maybe_grow`` this is demand-sized rather than step-sized:
        callers that need a known burst (engine admission/restore) reserve
        it up front instead of discovering mid-burst that growth stalled.
        Respects the same max/host-free caps; returns False when they bind
        first (static pools return False immediately, without side effects).
        """
        while self._free_top < n:
            host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
            want = max(self.grow_step, n - self._free_top)
            target = min(self.size + want, self.max_pages,
                         max(host_cap, self.min_pages))
            if target <= self.size:
                return False
            if self.lease is not None:
                granted = self.lease.lease(target - self.size)
                if granted <= 0:
                    return False
                target = self.size + granted
            old = self.size
            self._resize_to(target)
            self.n_grow += int(self.size > old)
            if self.size <= old:
                return False
        return True

    def shrink_for_pressure(self):
        """Shrink toward host free memory, never below min_pages."""
        host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
        target = max(self.min_pages, min(self.size, host_cap))
        if target < self.size:
            old = self.size
            self._resize_to(target)
            released = old - self.size
            if released and self.lease is not None:
                self.lease.release(released)
            self.n_shrink += int(self.size < old)
            return True
        return False

    def shrink_by(self, n: int) -> int:
        """Donate up to ``n`` pages back to the host (coordinator pressure
        path): releases FREE slots only, never below ``min_pages``, and
        returns the pages actually shed (already released to the lease)."""
        if n <= 0:
            return 0
        old = self.size
        self._resize_to(self.size - n)
        released = old - self.size
        if released:
            if self.lease is not None:
                self.lease.release(released)
            self.n_shrink += 1
        return released

    # -- overrun prediction (plan-once batch engine) -------------------------

    def alloc_prefix_capacity(self, n: int) -> int:
        """How many of ``n`` upcoming single-slot allocations would succeed
        back to back without a reclaim, counting the growth the alloc path
        itself would trigger (the pre-alloc grow at an empty free list and
        the 80%-usage opportunistic grow).

        This is the free-deficit predictor behind the plan-once
        ``access_batch`` engine: a batch segment is sized to exactly the
        allocations that fit, so the first op that would overrun the pool
        becomes an inline boundary event instead of a mid-bulk surprise.

        The prediction is a LOWER bound by construction — callers feed it to
        ``alloc_batch(..., allow_deficit=True)``, which asserts every alloc
        lands.  It is exact (simulating the same growth arithmetic against
        the same pure ``free_memory_fn``) for clean free-probe pools; pools
        with coordinator leases get a guaranteed lower bound from the
        coordinator's uncontended free slab (``_prefix_capacity_leased``);
        pools with stranded non-UNBACKED slots beyond the effective size (a
        prior shrink pinned live data in the tail) fall back to the current
        FREE count, which is always safe."""
        free = self._free_top
        if free >= n or n <= 0:
            return min(free, n) if n > 0 else 0
        size = self.size
        if size >= self.max_pages:
            return free
        if np.any(self.state[size:min(self.max_pages, self.capacity)]
                  != _UNBACKED):
            return free                # stranded tail: growth not predictable
        if self.lease is not None:
            return self._prefix_capacity_leased(n, free, size)
        grow_step = self.grow_step
        max_pages = self.max_pages
        min_pages = self.min_pages
        thresh = self.GROW_THRESHOLD
        host_frac = self.HOST_FREE_FRACTION
        free_fn = self.free_memory_fn
        used = self._used
        count = 0

        def sim_grow():
            # mirrors maybe_grow for a clean (no-lease, clean-tail) pool;
            # the usage precondition is checked by the callers below
            nonlocal size, free
            host_cap = int(free_fn() * host_frac)
            target = min(size + grow_step, max_pages,
                         max(host_cap, min_pages))
            if target <= size:
                return False
            free += target - size
            size = target
            return True

        while count < n:
            if free == 0:
                # scalar alloc's pre-grow: free list empty => usage is 1.0
                if not sim_grow():
                    break
            free -= 1
            used += 1
            count += 1
            if size < max_pages and used / max(size, 1) >= thresh:
                sim_grow()
        return count

    def _prefix_capacity_leased(self, n: int, free: int, size: int) -> int:
        """Lower-bound alloc capacity for coordinator-leased pools.

        Only the pre-alloc grow (empty free list) is modeled and every
        simulated grant is capped by the coordinator's CURRENT free slab —
        both choices keep the prediction a lower bound: the real path
        additionally takes 80%-usage opportunistic grows (extra capacity
        only) and ``lease()`` may reclaim co-tenants' excess on top of the
        free slab (larger grants only).  ``available_for`` — this pool's
        host-free probe — is invariant under its own leasing (a grant moves
        pages from the free slab into its own lease) and under weighted-fair
        reclamation (a donor's release moves its excess into the free slab),
        so the host cap is read once and holds for the whole simulation.
        Nothing here mutates the coordinator.

        The budget must be what ``lease()`` would actually grant, not the
        bare free count: a degraded container's grants are shed to its
        ``min_pages`` floor, so promising free-slab growth to it makes the
        alloc path's deficit mode overrun (``grantable_for`` folds the
        throttle in; for healthy containers it is the free slab capped at
        the lease room, which the ``cap_sz`` clamp below already implies —
        bitwise-identical predictions)."""
        coord = getattr(self.lease, "coordinator", None)
        if coord is None:
            return free                 # unknown lease backend: free is safe
        grantable = getattr(coord, "grantable_for", None)
        budget = coord.free() if grantable is None \
            else grantable(self.lease.cid)
        host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
        cap_sz = min(self.max_pages, max(host_cap, self.min_pages))
        # pre-grows repeat in grow_step chunks until the size cap or the
        # free-slab budget binds, so total guaranteed growth is their min
        growth = max(0, min(cap_sz - size, budget))
        return min(n, free + growth)

    # -- allocation ---------------------------------------------------------

    def alloc(self, logical_page: int, step: int) -> Optional[int]:
        """Use-pool-first allocation.  Returns a slot id or None."""
        if not self._free_top:
            self.maybe_grow()
        if not self._free_top:
            self.n_alloc_failed += 1
            return None
        top = self._free_top - 1
        self._free_top = top
        slot = int(self._free_arr[top])
        # FREE slots carry cleared §5.2 flags canonically (every transition
        # into FREE clears both; check_invariants pins it), so allocation
        # writes only the three live columns
        self.state[slot] = _IN_USE
        self.owner[slot] = logical_page
        self.last_step[slot] = step
        self.gen[slot] += 1
        if slot < self.size:
            self._used += 1
        self.n_alloc_from_pool += 1
        # opportunistic growth so the next alloc stays off the slow path
        if self.usage_fraction() >= self.GROW_THRESHOLD:
            self.maybe_grow()
        return slot

    def alloc_run(self, pages: np.ndarray, steps: np.ndarray) -> np.ndarray:
        """Vectorized bulk allocation for pools that cannot grow (static or
        already at ``max_pages``): one free-stack slice pop plus one scatter
        per metadata column.  Identical pop order, state transitions and
        counters as calling ``alloc`` per page (no growth trigger can fire).
        Requires ``free_count() >= len(pages)``; returns the slot array in
        allocation order."""
        n = len(pages)
        top = self._free_top - n
        sl = self._free_arr[top:self._free_top][::-1].copy()  # LIFO pop order
        self._free_top = top
        self.state[sl] = _IN_USE          # FREE ⇒ flags already clear
        self.owner[sl] = pages
        self.last_step[sl] = steps
        self.gen[sl] += 1
        if self.size == self.capacity:         # no stranded tail possible
            self._used += n
        else:
            self._used += int(np.count_nonzero(sl < self.size))
        self.n_alloc_from_pool += n
        return sl

    def alloc_batch(self, logical_pages, steps,
                    allow_deficit: bool = False) -> Optional[List[int]]:
        """Bulk use-pool-first allocation: one slot per page, in order.

        Semantically identical to calling ``alloc`` once per page (same free-
        stack pop order, same 80%-usage growth triggers, same counters).
        Pools pinned at ``max_pages`` take the fully vectorized ``alloc_run``
        (growth is provably futile there, which assumes ``free_memory_fn``
        is pure — it is everywhere in this repo); growable pools replay the
        scalar loop so every growth trigger lands at the exact op.

        Requires ``free_count() >= len(logical_pages)`` (the caller's batch
        guard); returns None without side effects otherwise.

        ``allow_deficit=True`` lifts the up-front guard for callers that
        pre-sized the batch with ``alloc_prefix_capacity``: the loop then
        also replicates the scalar alloc's pre-grow (grow when the free list
        is empty, before popping), and a pop that still cannot be served is
        an assertion failure — the predictor promised it would land.
        """
        pages = list(logical_pages)
        n = len(pages)
        if self._free_top < n and not allow_deficit:
            return None
        if self.size >= self.max_pages and self._free_top >= n:
            return self.alloc_run(np.asarray(pages, np.int64),
                                  np.asarray(list(steps), np.int64)).tolist()
        state = self.state
        owner = self.owner
        last = self.last_step
        free_arr = self._free_arr
        thresh = self.GROW_THRESHOLD
        size = self.size
        used = self._used
        can_grow = size < self.max_pages
        out: List[int] = []
        for pg, stp in zip(pages, steps):
            if not self._free_top:
                # scalar alloc's pre-grow: only reachable in deficit mode
                # (the guard above keeps the classic path pop-safe)
                self.maybe_grow()
                size = self.size
                used = self._used
                can_grow = size < self.max_pages
                assert self._free_top, \
                    "alloc_batch deficit: predictor overpromised"
            top = self._free_top - 1
            self._free_top = top
            slot = int(free_arr[top])
            state[slot] = _IN_USE         # FREE ⇒ flags already clear
            owner[slot] = pg
            last[slot] = stp
            self.gen[slot] += 1
            out.append(slot)
            if slot < size:
                used += 1
                self._used = used
            if can_grow and used / max(size, 1) >= thresh:
                if self.maybe_grow():
                    size = self.size
                    used = self._used
                    can_grow = size < self.max_pages
        self.n_alloc_from_pool += n
        return out

    def touch(self, slot: int, step: int):
        """Record write activity (paper: timestamp tag updated on write)."""
        self.last_step[slot] = step

    def mark_reclaimable(self, slot: int) -> bool:
        """Remote replica now exists (WC polled): slot may be reclaimed.

        Returns False when §5.2 defers the transition: a newer write-set for
        the same page is still pending, so the flag is cleared and the slot
        stays IN_USE until that newer set completes (the caller re-marks it
        then)."""
        if self.update_flag[slot]:
            self.update_flag[slot] = False
            return False
        self.state[slot] = _RECLAIMABLE
        self.reclaim_flag[slot] = True
        return True

    def reclaim(self, slot: int) -> int:
        """Return a RECLAIMABLE slot to the free list.  O(1) pointer move."""
        assert self.state[slot] == _RECLAIMABLE, SlotState(int(
            self.state[slot]))
        page = int(self.owner[slot])
        # RECLAIMABLE ⇒ update_flag already clear (mark_reclaimable defers
        # flagged slots; a pending slot is never RECLAIMABLE)
        self.state[slot] = _FREE
        self.owner[slot] = -1
        self.reclaim_flag[slot] = False
        if slot < self.size:
            self._used -= 1
        self._free_arr[self._free_top] = slot
        self._free_top += 1
        self.n_reclaimed += 1
        return page

    def reclaim_window(self, start: int, end: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Targeted out-of-FIFO reclaim of every RECLAIMABLE slot in
        ``[start, end)`` — the ``host_donate`` shrink window — as one masked
        gather/scatter.  Identical per-slot transitions, free-stack append
        order (ascending slot) and counters as calling ``reclaim`` on each.
        Returns the reclaimed ``(slots, pages)`` arrays."""
        w = start + np.flatnonzero(self.state[start:end] == _RECLAIMABLE)
        if not w.size:
            return w, w
        pages = self.owner[w].copy()
        self.state[w] = _FREE             # RECLAIMABLE ⇒ update_flag clear
        self.owner[w] = -1
        self.reclaim_flag[w] = False
        self._used -= int(np.count_nonzero(w < self.size))
        top = self._free_top
        self._free_arr[top:top + w.size] = w
        self._free_top = top + w.size
        self.n_reclaimed += int(w.size)
        return w, pages

    def release(self, slot: int):
        """Return an IN_USE slot directly to the free list (rollback path)."""
        assert self.state[slot] == _IN_USE, SlotState(int(self.state[slot]))
        self.state[slot] = _FREE
        self.owner[slot] = -1
        self.update_flag[slot] = False
        self.reclaim_flag[slot] = False
        if slot < self.size:
            self._used -= 1
        self._free_arr[self._free_top] = slot
        self._free_top += 1

    def release_batch(self, slots):
        """Bulk ``release``: the same per-slot transitions as one scatter
        per column (spill/free paths release whole page runs).  ``slots``
        must be distinct — they come from distinct pages' pool slots."""
        sl = np.asarray(slots, np.int64)
        if not sl.size:
            return
        assert (self.state[sl] == _IN_USE).all(), "release of non-IN_USE slot"
        self.state[sl] = _FREE
        self.owner[sl] = -1
        self.update_flag[sl] = False
        self.reclaim_flag[sl] = False
        self._used -= int(np.count_nonzero(sl < self.size))
        top = self._free_top
        self._free_arr[top:top + sl.size] = sl
        self._free_top = top + sl.size

    def free_count(self) -> int:
        return self._free_top

    # -- zero-restore repoint (device tier) ----------------------------------

    def free_gen(self, slot: int) -> Optional[int]:
        """Current generation of ``slot`` if it is claimable (FREE, inside
        the effective pool size — i.e. on the free list, not an epoch hold),
        else ``None``.  This is the validity probe behind the device tier's
        lazy demoted-entry validation."""
        s = int(slot)
        if s >= self.size or self.state[s] != _FREE:
            return None
        if self._held and any(s in h[2] for h in self._held):
            return None
        return int(self.gen[s])

    def claim_batch(self, slots, pages, step: int) -> None:
        """Re-claim *specific* FREE slots off the free list (zero-restore
        repoint): the same FREE -> IN_USE transition as ``alloc`` but
        targeting the exact slots whose data is still resident, so no bytes
        move.  Preserves the relative free-stack order of the remaining
        slots.  Callers validate claimability first (``free_gen``)."""
        sl = np.asarray(slots, np.int64)
        if not sl.size:
            return
        assert (self.state[sl] == _FREE).all(), "claim of non-FREE slot"
        fl = self._free_arr[:self._free_top]
        keep = fl[~np.isin(fl, sl)]
        assert keep.size == self._free_top - sl.size, \
            "claimed slot not on the free list (held or duplicated)"
        self._free_arr[:keep.size] = keep
        self._free_top = int(keep.size)
        self.state[sl] = _IN_USE          # FREE ⇒ flags already clear
        self.owner[sl] = np.asarray(pages, np.int64)
        self.last_step[sl] = step
        self.gen[sl] += 1
        self._used += int(np.count_nonzero(sl < self.size))
        self.n_claimed += int(sl.size)

    # -- epoch-tagged holds (async orchestration engine) ---------------------

    def hold_from_free(self, k: int, epoch: int, finish_us: float) -> int:
        """Move the top ``k`` free-stack slots into an epoch-tagged hold.

        The async daemon reclaims slots *now* (metadata-wise) but the
        simulated reclaim work completes at ``finish_us``; until an epoch
        boundary commits the hold, the foreground must not allocate those
        slots.  Popping the just-reclaimed slots straight back off the stack
        keeps ``reclaim_bulk`` untouched.  Returns the slots actually held.
        """
        k = min(int(k), self._free_top)
        if k <= 0:
            return 0
        top = self._free_top - k
        self._held.append((int(epoch), float(finish_us),
                           self._free_arr[top:self._free_top].copy()))
        self._free_top = top
        return k

    def commit_holds(self, *, up_to_epoch: Optional[int] = None,
                     now_us: Optional[float] = None) -> int:
        """Release held slots back to the free stack.

        A hold commits when every given bound admits it (``epoch <=
        up_to_epoch`` and ``finish_us <= now_us``); with no bounds, all
        holds commit (the fence / quiesce path).  Returns slots released.
        """
        if not self._held:
            return 0
        released = 0
        keep: List[Tuple[int, float, np.ndarray]] = []
        for ep, fin, slots in self._held:
            if ((up_to_epoch is not None and ep > up_to_epoch)
                    or (now_us is not None and fin > now_us)):
                keep.append((ep, fin, slots))
                continue
            top = self._free_top
            self._free_arr[top:top + slots.size] = slots
            self._free_top = top + slots.size
            released += int(slots.size)
        self._held = keep
        return released

    def held_count(self) -> int:
        return sum(int(s.size) for _, _, s in self._held)

    def reclaimable_slots(self) -> List[int]:
        return np.flatnonzero(
            self.state[:self.size] == _RECLAIMABLE).tolist()

    # -- invariants (property tests) ----------------------------------------

    def check_invariants(self):
        assert self.min_pages <= self.size <= min(self.max_pages,
                                                  self.capacity)
        s = self.state[:self.size]
        brute_used = int(np.count_nonzero((s != _FREE) & (s != _UNBACKED)))
        assert self._used == brute_used, (self._used, brute_used)
        fl = self._free_arr[:self._free_top]
        held = (np.concatenate([s for _, _, s in self._held])
                if self._held else np.empty(0, np.int64))
        both = np.concatenate([fl, held])
        assert np.unique(both).size == both.size, \
            "slot duplicated across free list / holds"
        assert (self.state[fl] == _FREE).all(), "non-FREE slot on free list"
        assert (self.state[held] == _FREE).all(), "non-FREE held slot"
        assert (self.owner[held] == -1).all() if held.size else True
        free_mask = self.state == _FREE
        assert int(np.count_nonzero(free_mask)) == both.size, \
            "FREE slot missing from free list + holds"
        assert (self.owner[free_mask] == -1).all()
        # canonical §5.2 flags (the allocation/reclaim fast paths rely on
        # these): FREE slots carry no flags, RECLAIMABLE no update_flag
        assert not self.update_flag[free_mask].any()
        assert not self.reclaim_flag[free_mask].any()
        assert not self.update_flag[self.state == _RECLAIMABLE].any()
