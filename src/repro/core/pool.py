"""ValetMempool — the host-coordinated local memory pool (paper §3.4, §4.1).

This is the control plane: deterministic Python metadata over a fixed array
of page slots whose *effective* size grows and shrinks dynamically.  The
data plane (actual K/V page arrays in HBM) lives in ``tiering.py`` /
``serve``; slots here are indices into those arrays.

Paper-faithful rules (Table 2 + §4.1):

* **Use-pool-first**: allocation takes a pre-allocated free slot if one
  exists; only when the pool is exhausted does it try to *grow* (the inverse
  of Linux mempool's allocate-first).
* **Growth**: when usage reaches 80% of the current pool size, the pool
  grows on demand, capped at ``min(max_pool_pages, 50% of host free pages)``.
* **Shrink**: when host free memory drops, the pool shrinks (releasing FREE
  slots only), never below ``min_pool_pages``.
* Slot lifecycle (write path, §4.1 "Local Mempool Page Reclaim"):
  ``FREE -> IN_USE -> (staged for remote send) -> RECLAIMABLE -> FREE``.
  Reclaiming a page is a pointer move ("a few CPU cycles").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, List, Optional, Set


class SlotState(enum.Enum):
    FREE = 0          # in the pool, ready to serve an allocation
    IN_USE = 1        # holds live data not yet replicated remotely
    RECLAIMABLE = 2   # remote replica exists; may be reclaimed for reuse
    UNBACKED = 3      # beyond the current effective pool size


@dataclass
class SlotMeta:
    state: SlotState = SlotState.UNBACKED
    logical_page: int = -1         # owning logical page (-1 = none)
    last_activity: int = 0         # step of last write (paper's timestamp tag)
    update_flag: bool = False      # §5.2: newer write-set exists for this page
    reclaim_flag: bool = False     # §5.2: replica exists; safe to reclaim


class ValetMempool:
    """Dynamic paged pool metadata.

    ``capacity`` is the physical slot-array size (the data-plane allocation);
    ``size`` is the current effective pool size (<= capacity), which grows
    and shrinks per the paper's rules.  ``free_memory_fn`` models host free
    pages (injected; in the serving engine it reports free HBM pages).
    """

    GROW_THRESHOLD = 0.8           # paper: grow at 80% usage
    HOST_FREE_FRACTION = 0.5       # paper: cap at 50% of host free memory

    def __init__(self, capacity: int, *, min_pages: int, max_pages: int,
                 free_memory_fn: Optional[Callable[[], int]] = None,
                 grow_step: Optional[int] = None,
                 lease=None):
        assert 0 < min_pages <= max_pages <= capacity
        self.capacity = capacity
        self.min_pages = min_pages
        self.max_pages = max_pages
        # coordinator-backed pools (``lease`` is a coordinator LeaseClient
        # whose registration already reserved ``min_pages``) probe the
        # coordinator's free slab instead of a synthetic host-free callable;
        # every grow must then be granted via ``lease.lease`` and every
        # shrink returns pages via ``lease.release``
        self.lease = lease
        if lease is not None:
            free_memory_fn = lease.available
        self.free_memory_fn = free_memory_fn or (lambda: capacity)
        self.grow_step = grow_step or max(min_pages // 2, 1)
        self.slots: List[SlotMeta] = [SlotMeta() for _ in range(capacity)]
        self.size = 0
        self._free: List[int] = []
        self._used = 0           # non-FREE/non-UNBACKED slots below size
        self._resize_to(min_pages)
        # counters for benchmarks / tests
        self.n_grow = 0
        self.n_shrink = 0
        self.n_alloc_from_pool = 0
        self.n_alloc_failed = 0
        self.n_reclaimed = 0

    # -- sizing ------------------------------------------------------------

    def _resize_to(self, new_size: int):
        new_size = max(self.min_pages, min(new_size, self.max_pages,
                                           self.capacity))
        if new_size > self.size:
            # only back slots that are actually UNBACKED: a previous shrink
            # can strand non-FREE slots beyond the effective size (they keep
            # live data and simply return under the size here), and a
            # stranded slot released in the meantime is already on the free
            # list — blindly marking the range FREE would clobber both
            for i in range(self.size, new_size):
                m = self.slots[i]
                if m.state == SlotState.UNBACKED:
                    m.state = SlotState.FREE
                    self._free.append(i)
        elif new_size < self.size:
            # release only FREE slots from the tail of the pool
            keep = []
            released = 0
            want = self.size - new_size
            for i in reversed(range(new_size, self.size)):
                if self.slots[i].state == SlotState.FREE and released < want:
                    self.slots[i].state = SlotState.UNBACKED
                    released += 1
                else:
                    keep.append(i)
            self._free = [i for i in self._free
                          if self.slots[i].state == SlotState.FREE]
            new_size = self.size - released
        self.size = new_size
        # resizes can strand non-FREE slots beyond the effective size, so
        # the O(1) usage counter is rebuilt here (resizes are rare events)
        self._used = sum(1 for i in range(self.size)
                         if self.slots[i].state != SlotState.FREE
                         and self.slots[i].state != SlotState.UNBACKED)

    def used(self) -> int:
        return self._used

    def usage_fraction(self) -> float:
        return self.used() / max(self.size, 1)

    def maybe_grow(self):
        """Paper: grow on demand at 80% usage, capped by max and host-free."""
        if self.size >= self.max_pages:
            # static pool (or already at max): growth is provably futile, so
            # skip the usage/host-free probes — the alloc path calls this on
            # every high-usage alloc (free_memory_fn is pure in this repo)
            return False
        if self.usage_fraction() < self.GROW_THRESHOLD:
            return False
        host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
        target = min(self.size + self.grow_step, self.max_pages,
                     max(host_cap, self.min_pages))
        if target <= self.size:
            return False
        if self.lease is not None:
            # coordinator-backed: the grow must be granted (one batched
            # lease per grow step); a partial grant grows partially
            granted = self.lease.lease(target - self.size)
            if granted <= 0:
                return False
            target = self.size + granted
        old = self.size
        self._resize_to(target)
        grew = self.size > old
        self.n_grow += int(grew)
        return grew

    def ensure_free(self, n: int) -> bool:
        """Grow (leasing if coordinator-backed) until ``n`` slots are FREE.

        Unlike ``maybe_grow`` this is demand-sized rather than step-sized:
        callers that need a known burst (engine admission/restore) reserve
        it up front instead of discovering mid-burst that growth stalled.
        Respects the same max/host-free caps; returns False when they bind
        first (static pools return False immediately, without side effects).
        """
        while len(self._free) < n:
            host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
            want = max(self.grow_step, n - len(self._free))
            target = min(self.size + want, self.max_pages,
                         max(host_cap, self.min_pages))
            if target <= self.size:
                return False
            if self.lease is not None:
                granted = self.lease.lease(target - self.size)
                if granted <= 0:
                    return False
                target = self.size + granted
            old = self.size
            self._resize_to(target)
            self.n_grow += int(self.size > old)
            if self.size <= old:
                return False
        return True

    def shrink_for_pressure(self):
        """Shrink toward host free memory, never below min_pages."""
        host_cap = int(self.free_memory_fn() * self.HOST_FREE_FRACTION)
        target = max(self.min_pages, min(self.size, host_cap))
        if target < self.size:
            old = self.size
            self._resize_to(target)
            released = old - self.size
            if released and self.lease is not None:
                self.lease.release(released)
            self.n_shrink += int(self.size < old)
            return True
        return False

    def shrink_by(self, n: int) -> int:
        """Donate up to ``n`` pages back to the host (coordinator pressure
        path): releases FREE slots only, never below ``min_pages``, and
        returns the pages actually shed (already released to the lease)."""
        if n <= 0:
            return 0
        old = self.size
        self._resize_to(self.size - n)
        released = old - self.size
        if released:
            if self.lease is not None:
                self.lease.release(released)
            self.n_shrink += 1
        return released

    # -- overrun prediction (plan-once batch engine) -------------------------

    def alloc_prefix_capacity(self, n: int) -> int:
        """How many of ``n`` upcoming single-slot allocations would succeed
        back to back without a reclaim, counting the growth the alloc path
        itself would trigger (the pre-alloc grow at an empty free list and
        the 80%-usage opportunistic grow).

        This is the free-deficit predictor behind the plan-once
        ``access_batch`` engine: a batch segment is sized to exactly the
        allocations that fit, so the first op that would overrun the pool
        becomes an inline boundary event instead of a mid-bulk surprise.

        The prediction is a LOWER bound by construction — callers feed it to
        ``alloc_batch(..., allow_deficit=True)``, which asserts every alloc
        lands.  It is exact (simulating the same growth arithmetic against
        the same pure ``free_memory_fn``) except in two conservative
        fallbacks where growth bookkeeping is state-dependent: pools with
        coordinator leases (a grant cannot be probed without mutating the
        coordinator) and pools with stranded non-UNBACKED slots beyond the
        effective size (a prior shrink pinned live data in the tail) — both
        fall back to the current FREE count, which is always safe."""
        free = len(self._free)
        if free >= n or n <= 0:
            return min(free, n) if n > 0 else 0
        size = self.size
        if size >= self.max_pages or self.lease is not None:
            return free
        slots = self.slots
        for i in range(size, min(self.max_pages, self.capacity)):
            if slots[i].state is not SlotState.UNBACKED:
                return free            # stranded tail: growth not predictable
        grow_step = self.grow_step
        max_pages = self.max_pages
        min_pages = self.min_pages
        thresh = self.GROW_THRESHOLD
        host_frac = self.HOST_FREE_FRACTION
        free_fn = self.free_memory_fn
        used = self._used
        count = 0

        def sim_grow():
            # mirrors maybe_grow for a clean (no-lease, clean-tail) pool;
            # the usage precondition is checked by the callers below
            nonlocal size, free
            host_cap = int(free_fn() * host_frac)
            target = min(size + grow_step, max_pages,
                         max(host_cap, min_pages))
            if target <= size:
                return False
            free += target - size
            size = target
            return True

        while count < n:
            if free == 0:
                # scalar alloc's pre-grow: free list empty => usage is 1.0
                if not sim_grow():
                    break
            free -= 1
            used += 1
            count += 1
            if size < max_pages and used / max(size, 1) >= thresh:
                sim_grow()
        return count

    # -- allocation ---------------------------------------------------------

    def alloc(self, logical_page: int, step: int) -> Optional[int]:
        """Use-pool-first allocation.  Returns a slot id or None."""
        if not self._free:
            self.maybe_grow()
        if not self._free:
            self.n_alloc_failed += 1
            return None
        slot = self._free.pop()
        m = self.slots[slot]
        m.state = SlotState.IN_USE
        m.logical_page = logical_page
        m.last_activity = step
        m.update_flag = False
        m.reclaim_flag = False
        if slot < self.size:
            self._used += 1
        self.n_alloc_from_pool += 1
        # opportunistic growth so the next alloc stays off the slow path
        if self.usage_fraction() >= self.GROW_THRESHOLD:
            self.maybe_grow()
        return slot

    def alloc_batch(self, logical_pages, steps,
                    allow_deficit: bool = False) -> Optional[List[int]]:
        """Bulk use-pool-first allocation: one slot per page, in order.

        Semantically identical to calling ``alloc`` once per page (same free-
        list pop order, same 80%-usage growth triggers, same counters), but
        with the per-page method-call overhead amortized away; ``maybe_grow``
        is invoked only when the scalar path would actually attempt growth.
        When the pool is already at ``max_pages`` the (provably futile) grow
        probe is skipped entirely, which assumes ``free_memory_fn`` is pure —
        it is everywhere in this repo.

        Requires ``free_count() >= len(logical_pages)`` (the caller's batch
        guard); returns None without side effects otherwise.

        ``allow_deficit=True`` lifts the up-front guard for callers that
        pre-sized the batch with ``alloc_prefix_capacity``: the loop then
        also replicates the scalar alloc's pre-grow (grow when the free list
        is empty, before popping), and a pop that still cannot be served is
        an assertion failure — the predictor promised it would land.
        """
        pages = list(logical_pages)
        n = len(pages)
        free = self._free
        if len(free) < n and not allow_deficit:
            return None
        slots_meta = self.slots
        thresh = self.GROW_THRESHOLD
        can_grow = self.size < self.max_pages
        size = self.size
        used = self._used
        out: List[int] = []
        in_use = SlotState.IN_USE
        if not can_grow:
            # static-size pool (or already at max): no growth trigger can
            # fire, so the per-alloc usage arithmetic drops out entirely
            for pg, stp in zip(pages, steps):
                slot = free.pop()
                m = slots_meta[slot]
                m.state = in_use
                m.logical_page = pg
                m.last_activity = stp
                m.update_flag = False
                m.reclaim_flag = False
                out.append(slot)
                if slot < size:
                    used += 1
            self._used = used
            self.n_alloc_from_pool += n
            return out
        for pg, stp in zip(pages, steps):
            if not free:
                # scalar alloc's pre-grow: only reachable in deficit mode
                # (the guard above keeps the classic path pop-safe)
                self.maybe_grow()
                size = self.size
                used = self._used
                can_grow = size < self.max_pages
                assert free, "alloc_batch deficit: predictor overpromised"
            slot = free.pop()
            m = slots_meta[slot]
            m.state = in_use
            m.logical_page = pg
            m.last_activity = stp
            m.update_flag = False
            m.reclaim_flag = False
            out.append(slot)
            if slot < size:
                used += 1
                self._used = used
            if can_grow and used / max(size, 1) >= thresh:
                if self.maybe_grow():
                    size = self.size
                    used = self._used
                    can_grow = size < self.max_pages
        self.n_alloc_from_pool += n
        return out

    def touch(self, slot: int, step: int):
        """Record write activity (paper: timestamp tag updated on write)."""
        self.slots[slot].last_activity = step

    def mark_reclaimable(self, slot: int) -> bool:
        """Remote replica now exists (WC polled): slot may be reclaimed.

        Returns False when §5.2 defers the transition: a newer write-set for
        the same page is still pending, so the flag is cleared and the slot
        stays IN_USE until that newer set completes (the caller re-marks it
        then)."""
        m = self.slots[slot]
        if m.update_flag:
            m.update_flag = False
            return False
        m.state = SlotState.RECLAIMABLE
        m.reclaim_flag = True
        return True

    def reclaim(self, slot: int) -> int:
        """Return a RECLAIMABLE slot to the free list.  O(1) pointer move."""
        m = self.slots[slot]
        assert m.state == SlotState.RECLAIMABLE, m.state
        page = m.logical_page
        m.state = SlotState.FREE
        m.logical_page = -1
        m.update_flag = False
        m.reclaim_flag = False
        if slot < self.size:
            self._used -= 1
        self._free.append(slot)
        self.n_reclaimed += 1
        return page

    def release(self, slot: int):
        """Return an IN_USE slot directly to the free list (rollback path)."""
        m = self.slots[slot]
        assert m.state == SlotState.IN_USE, m.state
        m.state = SlotState.FREE
        m.logical_page = -1
        m.update_flag = False
        m.reclaim_flag = False
        if slot < self.size:
            self._used -= 1
        self._free.append(slot)

    def release_batch(self, slots):
        """Bulk ``release``: same per-slot transitions with the attribute
        lookups hoisted (spill/free paths release whole page runs)."""
        meta = self.slots
        free = self._free
        size = self.size
        used = self._used
        for slot in slots:
            slot = int(slot)
            m = meta[slot]
            assert m.state == SlotState.IN_USE, m.state
            m.state = SlotState.FREE
            m.logical_page = -1
            m.update_flag = False
            m.reclaim_flag = False
            if slot < size:
                used -= 1
            free.append(slot)
        self._used = used

    def free_count(self) -> int:
        return len(self._free)

    def reclaimable_slots(self) -> List[int]:
        return [i for i in range(self.size)
                if self.slots[i].state == SlotState.RECLAIMABLE]

    # -- invariants (property tests) ----------------------------------------

    def check_invariants(self):
        assert self.min_pages <= self.size <= min(self.max_pages, self.capacity)
        brute_used = sum(1 for i in range(self.size)
                         if self.slots[i].state != SlotState.FREE
                         and self.slots[i].state != SlotState.UNBACKED)
        assert self._used == brute_used, (self._used, brute_used)
        free_set: Set[int] = set(self._free)
        assert len(free_set) == len(self._free), "duplicate free slots"
        for i, m in enumerate(self.slots):
            if i >= self.size:
                assert m.state == SlotState.UNBACKED or i in free_set or True
            if m.state == SlotState.FREE:
                assert i in free_set, f"FREE slot {i} missing from free list"
                assert m.logical_page == -1
            else:
                assert i not in free_set, f"non-FREE slot {i} on free list"
        for i in self._free:
            assert self.slots[i].state == SlotState.FREE
