"""Staging / Reclaimable queues with Update flags (paper §4.1, §5.2).

One ``WriteSet`` is the paper's 24-byte ``tree_entry``: the pages of a single
write transaction.  The pipeline is:

  write completes into local pool  ->  entry enqueued on StagingQueue
  remote send (async, coalesced)   ->  entry moves to ReclaimableQueue
  reclaim                           ->  slots returned to the pool

§5.2 consistency: when two write-sets update the same page, the older one's
slot must NOT be reclaimed before the newer one is sent (its pool slot holds
the only up-to-date copy).  The ``update_flag`` on the slot implements the
skip; both orderings (distance larger/smaller than queue size) are safe.

Both queues are **structure-of-arrays**: flattened parallel row columns
(page, slot, seq/hold, entry-start flag) in sliding buffers whose live
window ``[head, tail)`` is always contiguous, so a whole flush batch or
reclaim burst is one slice gather and the §5.2 bookkeeping becomes masked
scatters (``reclaim_bulk``, ``complete_flush_rows``, ``stage_rows``).
``WriteSet`` objects are materialized only on the scalar reference paths
and for tests; multi-page write-sets (the generic ``write()`` API — the
tiered store always stages single pages) flatten into consecutive rows and
keep the exact entry-atomic pop semantics via the entry-start flags.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.pool import SlotState, ValetMempool

_FREE = int(SlotState.FREE)
_IN_USE = int(SlotState.IN_USE)
_RECLAIMABLE = int(SlotState.RECLAIMABLE)

_EMPTY = np.empty(0, np.int64)


def _has_dup_values(arr: np.ndarray, n: int) -> bool:
    """True when ``arr`` (length ``n`` > 1) repeats a value — a Python set
    probe below 64 elements (cheaper than numpy dispatch there), one sort
    compare above.  Shared by every §5.2 path that must route duplicate
    pages through chain-aware handling."""
    if n <= 64:
        return len(set(arr.tolist())) != n
    srt = np.sort(arr)
    return bool(np.count_nonzero(srt[1:] == srt[:-1]))


@dataclass(slots=True)
class WriteSet:
    """One write transaction: logical pages + their pool slots."""
    seq: int
    pages: Tuple[int, ...]
    slots: Tuple[int, ...]
    migrating_hold: bool = False   # parked while its target block migrates


class _RowQueue:
    """Shared sliding-buffer machinery for the two SoA queues.

    ``_cols`` names the int64 row columns; bool columns are listed in
    ``_flags``.  The live rows sit in ``[head, tail)`` of every column;
    pops advance ``head``, pushes advance ``tail``, and when the tail hits
    the buffer end the window is compacted to the front (amortized O(1),
    and slices over the live window stay contiguous — the property the
    vectorized paths rely on)."""

    _cols: Tuple[str, ...] = ()
    _flags: Tuple[str, ...] = ()

    def _init_rows(self, cap: int = 1024):
        for name in self._cols:
            setattr(self, name, np.empty(cap, np.int64))
        for name in self._flags:
            setattr(self, name, np.zeros(cap, bool))
        self._head = 0
        self._tail = 0
        self._n_entries = 0
        self._n_multi = 0          # multi-page entries currently queued
        # lazy flag columns: while every row ever pushed was a single-page
        # entry (no multi rows yet), ``_first`` is not maintained — readers
        # only consult it when ``_n_multi > 0``.  The first multi-page push
        # normalizes the live window.  Subclasses track their own laziness
        # for extra flag columns (the staging hold column).
        self._first_lazy = True

    def __len__(self):
        return self._n_entries

    def _room_for(self, k: int):
        first = getattr(self, self._cols[0])
        cap = first.shape[0]
        if self._tail + k <= cap:
            return
        n = self._tail - self._head
        new_cap = cap
        while n + k > new_cap:
            new_cap *= 2
        for name in self._cols + self._flags:
            arr = getattr(self, name)
            if new_cap != cap:
                # flag columns grow ZEROED: the lazy-flag convention means
                # rows pushed later may never write their flag bit, and the
                # readers rely on unwritten positions being False
                out = np.zeros(new_cap, arr.dtype) if arr.dtype == bool \
                    else np.empty(new_cap, arr.dtype)
                out[:n] = arr[self._head:self._tail]
                setattr(self, name, out)
            else:
                arr[:n] = arr[self._head:self._tail].copy()
        self._head = 0
        self._tail = n

    def _entry_end(self, h: int) -> int:
        """Row index one past the entry starting at row ``h``."""
        if not self._n_multi:
            return h + 1
        first = self._first
        t = self._tail
        h2 = h + 1
        while h2 < t and not first[h2]:
            h2 += 1
        return h2


class StagingQueue(_RowQueue):
    """Writes accepted locally but not yet replicated to a remote peer.

    Writing (paging-out) is serialized (paper §3.1 Reliability): entries
    leave in FIFO order, via ``take_batch`` (message coalescing + batch send).
    """

    _cols = ("_seq", "_page", "_slot")
    _flags = ("_hold", "_first")

    def __init__(self, max_entries: int):
        self.max_entries = max_entries
        self._init_rows()
        self._n_held = 0               # entries currently parked (migration)
        # while True, every live/reusable row position holds False — pushes
        # skip the hold-column write (holds are rare migration events)
        self._hold_clean = True

    def full(self) -> bool:
        return self._n_entries >= self.max_entries

    def room(self) -> int:
        """Free staging entries — the batch engine's overrun bound."""
        return self.max_entries - self._n_entries

    def push(self, ws: WriteSet) -> bool:
        if self.full():
            return False
        k = len(ws.pages)
        if k > 1 and self._first_lazy:
            # first multi-page entry ever: backfill the live window (every
            # prior row is a single-page entry start)
            self._first[self._head:self._tail] = True
            self._first_lazy = False
        if ws.migrating_hold and self._hold_clean:
            self._hold_clean = False   # zeros until now — stays consistent
        self._room_for(k)
        t = self._tail
        if k == 1:
            self._seq[t] = ws.seq
            self._page[t] = ws.pages[0]
            self._slot[t] = ws.slots[0]
            if not self._hold_clean:
                self._hold[t] = ws.migrating_hold
            if not self._first_lazy:
                self._first[t] = True
        else:
            e = t + k
            self._seq[t:e] = ws.seq
            self._page[t:e] = ws.pages
            self._slot[t:e] = ws.slots
            if not self._hold_clean:
                self._hold[t:e] = ws.migrating_hold
            self._first[t:e] = False
            self._first[t] = True
            self._n_multi += 1
        self._tail = t + k
        self._n_entries += 1
        if ws.migrating_hold:
            self._n_held += 1
        return True

    def push_row(self, seq: int, page: int, slot: int):
        """Scalar single-page push (the fused tiny-segment replay — the
        caller's segment bound already guaranteed staging room, so the
        ``full()`` check is skipped like the pre-checked bulk pushes)."""
        self._room_for(1)
        t = self._tail
        self._seq[t] = seq
        self._page[t] = page
        self._slot[t] = slot
        if not self._hold_clean:
            self._hold[t] = False
        if not self._first_lazy:
            self._first[t] = True
        self._tail = t + 1
        self._n_entries += 1

    def push_rows(self, seqs, pages, slots):
        """Bulk push of single-page write-sets: one block write per column
        (the ``stage_rows`` fast path)."""
        k = len(pages)
        if not k:
            return
        self._room_for(k)
        t = self._tail
        e = t + k
        self._seq[t:e] = seqs
        self._page[t:e] = pages
        self._slot[t:e] = slots
        if not self._hold_clean:
            self._hold[t:e] = False
        if not self._first_lazy:
            self._first[t:e] = True
        self._tail = e
        self._n_entries += k

    def _rows_to_ws(self, h: int, e: int) -> List[WriteSet]:
        """Materialize rows ``[h, e)`` as WriteSet objects (entry-grouped)."""
        if e <= h:
            return []
        seqs = self._seq[h:e].tolist()
        pages = self._page[h:e].tolist()
        slots = self._slot[h:e].tolist()
        holds = self._hold[h:e].tolist()
        if not self._n_multi:
            return [WriteSet(s, (p,), (sl,), hd)
                    for s, p, sl, hd in zip(seqs, pages, slots, holds)]
        firsts = self._first[h:e].tolist()
        out: List[WriteSet] = []
        i = 0
        n = e - h
        while i < n:
            j = i + 1
            while j < n and not firsts[j]:
                j += 1
            out.append(WriteSet(seqs[i], tuple(pages[i:j]),
                                tuple(slots[i:j]), holds[i]))
            i = j
        return out

    def peek(self) -> Optional[WriteSet]:
        if not self._n_entries:
            return None
        return self._rows_to_ws(self._head,
                                self._entry_end(self._head))[0]

    def _rebuild(self, entries: List[WriteSet]):
        """Rewrite the whole buffer from an entry list (cold requeue paths:
        held-entry skips and entry-granular hold flips)."""
        self._init_rows(max(getattr(self, self._cols[0]).shape[0], 1024))
        self._n_held = 0
        self._hold_clean = True            # flag columns re-zeroed
        for ws in entries:                 # push re-counts every counter
            self.push(ws)

    def take_batch(self, n: int, skip_held: bool = True) -> List[WriteSet]:
        """Dequeue up to n sendable entries (held entries stay, FIFO kept).

        With no held entries (the common case — migrations are rare events)
        the whole batch pops as one slice."""
        if self._n_held and skip_held:
            ents = self._rows_to_ws(self._head, self._tail)
            out: List[WriteSet] = []
            keep: List[WriteSet] = []
            for i, ws in enumerate(ents):
                if len(out) >= n:
                    keep.extend(ents[i:])
                    break
                if ws.migrating_hold:
                    keep.append(ws)
                else:
                    out.append(ws)
            self._rebuild(keep)
            return out
        take = min(n, self._n_entries)
        if take == 0:
            return []
        h = self._head
        if not self._n_multi:
            e = h + take
        else:
            e = h
            for _ in range(take):
                e = self._entry_end(e)
        out = self._rows_to_ws(h, e)
        self._head = e
        self._n_entries -= take
        if self._n_held:               # skip_held=False popped held ones
            self._n_held -= sum(1 for ws in out if ws.migrating_hold)
        if self._n_multi:
            self._n_multi -= sum(1 for ws in out if len(ws.pages) > 1)
        return out

    def take_arrays(self, n: int
                    ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Pop up to ``n`` sendable entries as ``(seqs, pages, slots)``
        arrays — the batched flush's zero-object path.  Returns None when
        held or multi-page entries need the WriteSet walk."""
        if self._n_held or self._n_multi:
            return None
        take = min(n, self._n_entries)
        h = self._head
        e = h + take
        self._head = e
        self._n_entries -= take
        # copies: the buffer may compact under later pushes
        return (self._seq[h:e].copy(), self._page[h:e].copy(),
                self._slot[h:e].copy())

    def hold_pages(self, pages, hold: bool):
        """Park/unpark write-sets touching ``pages`` (migration §3.5)."""
        if self._n_multi:
            pset = set(pages)
            ents = self._rows_to_ws(self._head, self._tail)
            held = self._n_held
            for ws in ents:
                if ws.migrating_hold != hold and pset.intersection(ws.pages):
                    ws.migrating_hold = hold
                    held += 1 if hold else -1
            self._rebuild(ents)
            self._n_held = held
            return
        h, t = self._head, self._tail
        if h == t:
            return
        parr = np.asarray(list(pages) if not isinstance(pages, np.ndarray)
                          else pages, np.int64)
        win = self._hold[h:t]
        m = np.isin(self._page[h:t], parr) & (win != hold)
        cnt = int(np.count_nonzero(m))
        if cnt:
            win[m] = hold
            self._n_held += cnt if hold else -cnt
            self._hold_clean = False       # pushes must maintain the column

    def entries(self) -> List[WriteSet]:
        return self._rows_to_ws(self._head, self._tail)


class ReclaimableQueue(_RowQueue):
    """Write-sets whose remote replica exists; slots are reclaim candidates."""

    _cols = ("_page", "_slot")
    _flags = ("_first",)

    def __init__(self, max_entries: int):
        self.max_entries = max_entries
        self._init_rows()
        # > 0 while two live rows could share one (slot, page) pair — only
        # §5.2 deferred re-queues and out-of-queue-order reclaims
        # (``host_donate``'s shrink window) create such twins.  While 0,
        # ``reclaim_bulk`` skips its first-occurrence dedup pass; draining
        # the queue clears the risk.
        self._dup_risk = 0

    def push(self, ws: WriteSet):
        # arbitrary WriteSet pushes may duplicate a live row's (slot, page)
        # pair (re-queues, external callers): keep the bulk dedup armed
        self._dup_risk += 1
        k = len(ws.pages)
        if k > 1 and self._first_lazy:
            self._first[self._head:self._tail] = True
            self._first_lazy = False
        self._room_for(k)
        t = self._tail
        if k == 1:
            self._page[t] = ws.pages[0]
            self._slot[t] = ws.slots[0]
            if not self._first_lazy:
                self._first[t] = True
        else:
            e = t + k
            self._page[t:e] = ws.pages
            self._slot[t:e] = ws.slots
            self._first[t:e] = False
            self._first[t] = True
            self._n_multi += 1
        self._tail = t + k
        self._n_entries += 1

    def push_row(self, page: int, slot: int):
        """Scalar single-page push (the boundary fill hot path — no
        WriteSet object)."""
        self._room_for(1)
        t = self._tail
        self._page[t] = page
        self._slot[t] = slot
        if not self._first_lazy:
            self._first[t] = True
        self._tail = t + 1
        self._n_entries += 1

    def push_row_deferred(self, page: int, slot: int):
        """Re-queue a §5.2 deferred release: its original write-set row may
        still be live, so the (slot, page) pair can now appear twice."""
        self._dup_risk += 1
        self.push_row(page, slot)

    def push_rows(self, pages, slots):
        """Bulk push of single-page entries: one block write per column."""
        k = len(pages)
        if not k:
            return
        self._room_for(k)
        t = self._tail
        e = t + k
        self._page[t:e] = pages
        self._slot[t:e] = slots
        if not self._first_lazy:
            self._first[t:e] = True
        self._tail = e
        self._n_entries += k

    def reclaim_up_to(self, n_slots: int, pool: ValetMempool
                      ) -> List[Tuple[int, int]]:
        """Reclaim oldest entries' slots (LRU over write order) — the scalar
        reference: entries pop atomically while fewer than ``n_slots`` slots
        are freed, and slots whose page has a pending newer update
        (``update_flag``) were kept IN_USE by ``mark_reclaimable`` per §5.2,
        so the (slot, page) match guard skips their stale entries.
        Returns [(slot, logical_page)] actually freed."""
        freed: List[Tuple[int, int]] = []
        state = pool.state
        owner = pool.owner
        while self._n_entries and len(freed) < n_slots:
            h = self._head
            h2 = self._entry_end(h)
            for r in range(h, h2):
                slot = int(self._slot[r])
                pg = int(self._page[r])
                if state[slot] == _RECLAIMABLE and owner[slot] == pg:
                    pool.reclaim(slot)
                    freed.append((slot, pg))
            self._head = h2
            self._n_entries -= 1
            if h2 - h > 1:
                self._n_multi -= 1
        if not self._n_entries:
            self._dup_risk = 0         # no live rows, no possible twins
        return freed

    def reclaim_bulk(self, n_slots: int, pool: ValetMempool
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """``reclaim_up_to`` as masked gathers/scatters — identical state
        changes, pop/append order and counters, no per-entry Python.

        Chunks of queued rows are classified in one shot against the pool's
        SoA columns ((slot, page) match guard as a vector compare); the
        matched prefix that reaches ``n_slots`` frees with one scatter per
        metadata column and one block append to the free stack.  A slot
        queued twice in one chunk (a §5.2 deferred re-queue next to its
        original entry) frees only at its first occurrence — later rows see
        it FREE exactly as the sequential pop would.  Returns the freed
        ``(slots, pages)`` arrays in pop order."""
        if self._n_multi:
            freed = self.reclaim_up_to(n_slots, pool)
            k = len(freed)
            sl = np.fromiter((s for s, _ in freed), np.int64, k)
            pg = np.fromiter((p for _, p in freed), np.int64, k)
            return sl, pg
        state = pool.state
        owner = pool.owner
        out_s: List[np.ndarray] = []
        out_p: List[np.ndarray] = []
        need = n_slots
        while self._n_entries and need > 0:
            h = self._head
            # generous chunks: under pressure most queued rows are stale
            # (rewritten/refilled pages), and gathering 512 rows costs
            # barely more than 64 — one pass usually reaches the target
            chunk = min(self._n_entries, max(8 * need, 512))
            sl = self._slot[h:h + chunk]
            pg = self._page[h:h + chunk]
            match = (state[sl] == _RECLAIMABLE) & (owner[sl] == pg)
            mi = np.flatnonzero(match)
            if mi.size > 1 and self._dup_risk:
                msl = sl[mi]
                srt = np.sort(msl)
                if np.count_nonzero(srt[1:] == srt[:-1]):
                    # a §5.2 deferred re-queue alongside its original entry:
                    # the slot frees only at its first occurrence (later
                    # rows see it FREE, as the sequential pop would)
                    ao = np.argsort(msl, kind="stable")
                    ss = msl[ao]
                    later = np.zeros(msl.size, bool)
                    later[ao[1:][ss[1:] == ss[:-1]]] = True
                    mi = mi[~later]
                    msl = sl[mi]
            else:
                msl = sl[mi]
            if mi.size >= need:
                cut = int(mi[need - 1]) + 1
                mi = mi[:need]
                msl = msl[:need]
            else:
                cut = chunk
            if mi.size:
                mpg = pg[mi]
                state[msl] = _FREE        # RECLAIMABLE ⇒ update_flag clear
                owner[msl] = -1
                pool.reclaim_flag[msl] = False
                if pool.size == pool.capacity:
                    pool._used -= int(msl.size)
                else:
                    pool._used -= int(np.count_nonzero(msl < pool.size))
                top = pool._free_top
                pool._free_arr[top:top + msl.size] = msl
                pool._free_top = top + msl.size
                pool.n_reclaimed += int(msl.size)
                out_s.append(msl)
                out_p.append(mpg)
                need -= msl.size
            self._head = h + cut
            self._n_entries -= cut
        if not self._n_entries:
            self._dup_risk = 0         # no live rows, no possible twins
        if not out_s:
            return _EMPTY, _EMPTY
        if len(out_s) == 1:
            return out_s[0], out_p[0]
        return np.concatenate(out_s), np.concatenate(out_p)

    def entries(self) -> List[WriteSet]:
        out: List[WriteSet] = []
        h = self._head
        while h < self._tail:
            h2 = self._entry_end(h)
            out.append(WriteSet(-1, tuple(self._page[h:h2].tolist()),
                                tuple(self._slot[h:h2].tolist())))
            h = h2
        return out


class WritePipeline:
    """Pool + staging + reclaimable wired together (the write critical path).

    ``write()`` is the paper's Figure 7 left side: it completes as soon as
    pages are in the local pool.  ``flush()`` is the asynchronous Remote
    Sender Thread: it coalesces staged entries, "sends" them (caller-supplied
    callback = replication to a peer/host tier), then marks slots
    reclaimable.

    The §5.2 page maps are dense columns indexed by logical page id
    (grow-on-demand, like the GlobalPageTable): ``_pend`` holds each page's
    latest pending slot, ``_defer`` the older slot whose reclaim §5.2
    deferred until the newer write-set for the page is sent (FIFO flush ⇒
    at most one per page).  -1 = absent.
    """

    def __init__(self, pool: ValetMempool, queue_len: int = 4096):
        self.pool = pool
        self.staging = StagingQueue(queue_len)
        self.reclaimable = ReclaimableQueue(queue_len)
        self._seq = 0
        self._pend = np.full(1024, -1, np.int64)
        self._defer = np.full(1024, -1, np.int64)
        self._n_deferred = 0

    def _ensure_page(self, page: int):
        n = self._pend.shape[0]
        if page < n:
            return
        new = max(n * 2, page + 1)
        for name in ("_pend", "_defer"):
            arr = getattr(self, name)
            out = np.full(new, -1, np.int64)
            out[:n] = arr
            setattr(self, name, out)

    @property
    def _pending_slot(self) -> Dict[int, int]:
        """Dict view of the dense pending-slot column (tests/invariants)."""
        idx = np.flatnonzero(self._pend >= 0)
        return {int(p): int(self._pend[p]) for p in idx}

    def write(self, pages: Tuple[int, ...], step: int,
              alloc_fallback=None) -> Optional[WriteSet]:
        """Accept a write transaction into the pool.  Returns the WriteSet
        (write is complete for the caller) or None if allocation failed or
        the staging queue is full — either way with NO residual effects
        (slots released, pending-slot map and §5.2 flags restored), so the
        caller's reclaim/stall retry sequence never strands IN_USE slots."""
        slots: List[int] = []
        prevs: List[Optional[int]] = []
        pool = self.pool
        for pg in pages:
            slot = pool.alloc(pg, step)
            if slot is None and alloc_fallback is not None:
                slot = alloc_fallback(pg, step)
            if slot is None:
                self._rollback(pages, slots, prevs)
                return None
            self._ensure_page(pg)
            pend = self._pend
            prev = int(pend[pg])
            if prev >= 0:
                # §5.2 multiple updates: older slot must not be reclaimed
                # before this newer write-set is sent.
                pool.update_flag[prev] = True
                prevs.append(prev)
            else:
                prevs.append(None)
            pend[pg] = slot
            slots.append(slot)
        ws = WriteSet(self._seq, tuple(pages), tuple(slots))
        if not self.staging.push(ws):
            # staging overrun: the write did NOT happen — undo everything
            # (leaking here would pin the slots IN_USE forever: they are
            # neither staged nor reclaimable)
            self._rollback(pages, slots, prevs)
            return None
        self._seq += 1
        return ws

    def _rollback(self, pages, slots, prevs):
        """Undo a partially accepted write transaction: release the slots
        and restore each page's previous pending slot + its §5.2 flag (the
        latest pending slot is never update-flagged, so clearing is exact).
        """
        pend = self._pend
        pool = self.pool
        # newest-first so duplicate pages in one transaction unwind exactly
        # (zip truncates to the pages actually processed before the failure)
        for pg, slot, prev in reversed(list(zip(pages, slots, prevs))):
            if prev is not None:
                pool.update_flag[prev] = False
                pend[pg] = prev
            else:
                pend[pg] = -1
            pool.release(slot)

    def stage_rows(self, pages, slots) -> bool:
        """Vectorized ``stage_batch`` for single-page write-sets: one block
        row append plus masked scatters of the §5.2 update flags.

        Sequential semantics, exactly: every occurrence of a page flags its
        predecessor's slot — the previous occurrence in this batch, or the
        page's pre-existing pending slot for the first occurrence — and the
        page's pending slot ends on its last occurrence.  One stable
        argsort groups occurrences so within-batch predecessors are the
        sorted neighbors; flags only ever SET (idempotent), so scatter
        order is free.  Fresh alloc slots are disjoint from pending slots
        (those are IN_USE, staged), so no flag lands on a batch slot.

        Requires staging room for the whole batch; returns False without
        side effects otherwise."""
        n = len(pages)
        if self.staging.room() < n:
            return False
        parr = pages if isinstance(pages, np.ndarray) \
            else np.asarray(pages, np.int64)
        sarr = slots if isinstance(slots, np.ndarray) \
            else np.asarray(slots, np.int64)
        if not n:
            return True
        pend = self._pend
        try:
            prev = pend[parr]
        except IndexError:             # first sighting of a high page id
            self._ensure_page(int(parr.max()))
            pend = self._pend
            prev = pend[parr]
        uflag = self.pool.update_flag
        if n > 1 and _has_dup_values(parr, n):
            # duplicate pages: group occurrences with one stable argsort —
            # within-batch predecessors are the sorted neighbors
            order = np.argsort(parr, kind="stable")
            ps = parr[order]
            ss = sarr[order]
            same = ps[1:] == ps[:-1]       # row follows a same-page row
            uflag[ss[:-1][same]] = True
            first = np.empty(n, bool)
            first[0] = True
            np.logical_not(same, out=first[1:])
            fprev = pend[ps[first]]
            uflag[fprev[fprev >= 0]] = True
            last = np.empty(n, bool)
            last[n - 1] = True
            np.logical_not(same, out=last[:n - 1])
            pend[ps[last]] = ss[last]
        else:
            uflag[prev[prev >= 0]] = True
            pend[parr] = sarr
        seq = self._seq
        self.staging.push_rows(np.arange(seq, seq + n, dtype=np.int64),
                               parr, sarr)
        self._seq = seq + n
        return True

    def stage_batch(self, pages, slots) -> Optional[List[WriteSet]]:
        """Stage one single-page WriteSet per (page, slot) pair in bulk.

        Scalar-equivalent to ``write((pg,), ...)`` per page with the pool
        allocation done up front by ``ValetMempool.alloc_batch``: same seq
        numbers, same FIFO staging order, and the same §5.2 update-flag
        maintenance for duplicate pages (the older pending slot is flagged
        so it is not reclaimed before the newer write-set is sent).

        Requires staging room for the whole batch; returns None without
        side effects otherwise (callers pre-check and fall back to the
        scalar path).
        """
        n = len(pages)
        if self.staging.room() < n:
            return None
        pend = self._pend
        uflag = self.pool.update_flag
        seq = self._seq
        out: List[WriteSet] = []
        for pg, slot in zip(pages, slots):
            pg = int(pg)
            slot = int(slot)
            self._ensure_page(pg)
            pend = self._pend
            prev = int(pend[pg])
            if prev >= 0:
                uflag[prev] = True
            pend[pg] = slot
            ws = WriteSet(seq, (pg,), (slot,))
            seq += 1
            self.staging.push(ws)
            out.append(ws)
        self._seq = seq
        return out

    def staging_room(self) -> int:
        """Writes acceptable before the staging queue overruns — the batch
        engine bounds each bulk segment with this, so the op that would
        stall lands on the inline boundary path instead."""
        return self.staging.room()

    def complete_fill_batch(self, pages, slots):
        """Cache-fill bookkeeping in bulk: each filled slot is clean (a
        remote copy exists), so it is marked reclaimable and queued as its
        own single-page write-set — the exact per-slot transitions of the
        scalar ``_cache_fill`` tail (``mark_reclaimable`` + push) as one
        masked scatter.  Fill slots are fresh allocations (distinct, flags
        just cleared), so the §5.2 deferral branch is kept only for the
        general ``mark_reclaimable`` contract."""
        sarr = slots if isinstance(slots, np.ndarray) \
            else np.asarray(slots, np.int64)
        parr = pages if isinstance(pages, np.ndarray) \
            else np.asarray(pages, np.int64)
        if not sarr.size:
            return
        pool = self.pool
        uf = pool.update_flag[sarr]
        if uf.any():
            pool.update_flag[sarr[uf]] = False     # §5.2 deferral, as
            ok = sarr[~uf]                         # mark_reclaimable
            pool.state[ok] = _RECLAIMABLE
            pool.reclaim_flag[ok] = True
        else:
            pool.state[sarr] = _RECLAIMABLE
            pool.reclaim_flag[sarr] = True
        self.reclaimable.push_rows(parr, sarr)

    def fill_rows(self, pages: np.ndarray, slots: np.ndarray):
        """``complete_fill_batch`` for slots the caller JUST allocated (the
        segment engine's fills): a fresh slot's ``update_flag`` was cleared
        by the alloc, so the §5.2 deferral gather is skipped — two scatters
        and the row append."""
        pool = self.pool
        pool.state[slots] = _RECLAIMABLE
        pool.reclaim_flag[slots] = True
        self.reclaimable.push_rows(pages, slots)

    def flush(self, n: int, send_fn) -> List[WriteSet]:
        """Remote Sender Thread step: coalesce + send + mark reclaimable."""
        batch = self.staging.take_batch(n)
        pool = self.pool
        pend = self._pend
        defer = self._defer
        for ws in batch:
            send_fn(ws)
            for pg, slot in zip(ws.pages, ws.slots):
                if pend[pg] == slot:
                    pend[pg] = -1
                # §5.2 second half: this send supersedes any older slot for
                # the page whose reclaim was deferred — release it now (its
                # original queue entry may already have been popped, so a
                # fresh single-page entry re-queues it)
                if self._n_deferred:
                    d = int(defer[pg])
                    if d >= 0:
                        defer[pg] = -1
                        self._n_deferred -= 1
                        if pool.mark_reclaimable(d):
                            self.reclaimable.push_row_deferred(pg, d)
                if not pool.mark_reclaimable(slot):
                    defer[pg] = slot
                    self._n_deferred += 1
            self.reclaimable.push(ws)
        return batch

    def take_flush_batch(self, n: int) -> List[WriteSet]:
        """Dequeue up to ``n`` sendable write-sets (the batched flush's first
        half; ``complete_flush`` is the second)."""
        return self.staging.take_batch(n)

    def take_flush_rows(self, n: int
                        ) -> Optional[Tuple[np.ndarray, np.ndarray,
                                            np.ndarray]]:
        """Array form of ``take_flush_batch`` (no WriteSet objects) — None
        when held/multi-page entries need the WriteSet walk."""
        return self.staging.take_arrays(n)

    def complete_flush(self, batch: List[WriteSet]):
        """Post-send bookkeeping for a taken flush batch (WriteSet walk).

        Identical state transitions to the per-write-set tail of ``flush``
        (pending-slot retirement, §5.2 deferred-release handling, the
        reclaimable pushes).  The caller performs the "send" (placement)
        itself — placement touches peers/blocks/page-table only, this loop
        touches pool/queues only, so running them back to back instead of
        interleaved per write-set reaches the same state.  The batched
        store flushes through ``complete_flush_rows`` instead; this walk
        remains for multi-page write-sets and held-entry requeues."""
        pend = self._pend
        defer = self._defer
        pool = self.pool
        state = pool.state
        uflag = pool.update_flag
        rflag = pool.reclaim_flag
        push_row_deferred = self.reclaimable.push_row_deferred
        for ws in batch:
            for pg, slot in zip(ws.pages, ws.slots):
                if pend[pg] == slot:
                    pend[pg] = -1
                if self._n_deferred:
                    d = int(defer[pg])
                    if d >= 0:
                        defer[pg] = -1
                        self._n_deferred -= 1
                        if uflag[d]:
                            uflag[d] = False
                        else:
                            state[d] = _RECLAIMABLE
                            rflag[d] = True
                            push_row_deferred(pg, d)
                if uflag[slot]:
                    uflag[slot] = False
                    defer[pg] = slot
                    self._n_deferred += 1
                else:
                    state[slot] = _RECLAIMABLE
                    rflag[slot] = True
            self.reclaimable.push(ws)

    def complete_flush_rows(self, pages: np.ndarray, slots: np.ndarray):
        """``complete_flush`` over single-page rows as masked scatters.

        With distinct pages the per-entry walks are independent (each
        entry's own slot and its page's deferred slot are disjoint from
        every other entry's), so pending-slot retirement, both §5.2
        deferred-release halves and the reclaimable pushes (a released
        deferred slot's row precedes its entry's own row, in batch order)
        vectorize exactly.

        Duplicate pages couple through the per-page deferral chain, but
        the chain is fully determined: a page's non-last in-batch slot
        ALWAYS carries the update flag at flush time (its successor's
        stage set it, and nothing clears it before the flush), so it is
        deferred at its own step and released exactly when its successor
        flushes — i.e. every within-batch predecessor becomes a release
        row in front of its successor's own row, and only the page's LAST
        slot consults the live flag/deferral state.  One stable argsort
        recovers the chains (``_flush_rows_dup``)."""
        n = int(pages.size)
        if not n:
            return
        self._ensure_page(int(pages.max()))
        if n > 1 and _has_dup_values(pages, n):
            return self._flush_rows_dup(pages, slots)
        pool = self.pool
        pend = self._pend
        cur = pend[pages]
        ret = cur == slots
        if ret.any():
            pend[pages[ret]] = -1
        rel_idx = None                 # entries whose deferred slot releases
        d_rel = None
        if self._n_deferred:
            d = self._defer[pages]
            di = np.flatnonzero(d >= 0)
            if di.size:
                dslots = d[di]
                self._defer[pages[di]] = -1
                self._n_deferred -= int(di.size)
                uf = pool.update_flag[dslots]
                if uf.any():
                    pool.update_flag[dslots[uf]] = False
                rel = ~uf
                if rel.any():
                    d_rel = dslots[rel]
                    pool.state[d_rel] = _RECLAIMABLE
                    pool.reclaim_flag[d_rel] = True
                    rel_idx = di[rel]
        own_uf = pool.update_flag[slots]
        if own_uf.any():
            oi = np.flatnonzero(own_uf)
            pool.update_flag[slots[oi]] = False
            self._defer[pages[oi]] = slots[oi]
            self._n_deferred += int(oi.size)
            ok = slots[~own_uf]
            pool.state[ok] = _RECLAIMABLE
            pool.reclaim_flag[ok] = True
        else:
            pool.state[slots] = _RECLAIMABLE
            pool.reclaim_flag[slots] = True
        self._push_interleaved(pages, slots, rel_idx, d_rel)

    def _flush_rows_dup(self, pages: np.ndarray, slots: np.ndarray):
        """Post-send bookkeeping for a flush batch with duplicate pages —
        the §5.2 chain resolution of ``complete_flush_rows``'s docstring,
        bitwise identical to the sequential walk."""
        n = int(pages.size)
        pool = self.pool
        pend = self._pend
        uflag = pool.update_flag
        order = np.argsort(pages, kind="stable")
        ps = pages[order]
        ss = slots[order]
        samep = ps[1:] == ps[:-1]          # row follows a same-page row
        first = np.empty(n, bool)
        first[0] = True
        np.logical_not(samep, out=first[1:])
        last = np.empty(n, bool)
        last[n - 1] = True
        np.logical_not(samep, out=last[:n - 1])
        up = ps[last]                      # unique pages, sorted
        sl_last = ss[last]
        # pending-slot retirement: only a page's newest in-batch slot can
        # still be its pending slot (every older one was superseded)
        ret = pend[up] == sl_last
        if ret.any():
            pend[up[ret]] = -1
        has_rel_s = np.zeros(n, bool)      # sorted-row release markers
        rel_slot_s = np.empty(n, np.int64)
        if samep.any():
            # within-batch predecessors: deferred at their own step (flag
            # consumed), released when their successor flushes
            pred = ss[:-1][samep]
            uflag[pred] = False
            pool.state[pred] = _RECLAIMABLE
            pool.reclaim_flag[pred] = True
            has_rel_s[1:] = samep
            rel_slot_s[1:][samep] = pred
        if self._n_deferred:
            # a pre-batch deferred slot pops at its page's FIRST row
            d0 = self._defer[up]
            d0i = np.flatnonzero(d0 >= 0)
            if d0i.size:
                d0s = d0[d0i]
                self._defer[up[d0i]] = -1
                self._n_deferred -= int(d0i.size)
                uf0 = uflag[d0s]
                if uf0.any():
                    uflag[d0s[uf0]] = False
                relm = ~uf0
                if relm.any():
                    r0 = d0s[relm]
                    pool.state[r0] = _RECLAIMABLE
                    pool.reclaim_flag[r0] = True
                    fi = np.flatnonzero(first)[d0i[relm]]
                    has_rel_s[fi] = True
                    rel_slot_s[fi] = r0
        # the page's last slot consults the live flag state
        ufk = uflag[sl_last]
        if ufk.any():
            ki = np.flatnonzero(ufk)
            uflag[sl_last[ki]] = False
            self._defer[up[ki]] = sl_last[ki]
            self._n_deferred += int(ki.size)
            ok = sl_last[~ufk]
            pool.state[ok] = _RECLAIMABLE
            pool.reclaim_flag[ok] = True
        else:
            pool.state[sl_last] = _RECLAIMABLE
            pool.reclaim_flag[sl_last] = True
        # back to original row order for the FIFO pushes
        has_rel = np.empty(n, bool)
        rel_slot = np.empty(n, np.int64)
        has_rel[order] = has_rel_s
        rel_slot[order] = rel_slot_s
        rel_idx = np.flatnonzero(has_rel)
        if not rel_idx.size:
            self.reclaimable.push_rows(pages, slots)
            return
        self._push_interleaved(pages, slots, rel_idx, rel_slot[rel_idx])

    def _push_interleaved(self, pages, slots, rel_idx, rel_slots):
        """Push the flush batch's reclaimable rows: each released deferred
        slot's row lands immediately before its entry's own row."""
        if rel_idx is None:
            self.reclaimable.push_rows(pages, slots)
            return
        n = int(pages.size)
        extra = np.zeros(n, np.int64)
        extra[rel_idx] = 1
        own_pos = np.arange(n) + np.cumsum(extra)
        total = n + rel_idx.size
        op = np.empty(total, np.int64)
        osl = np.empty(total, np.int64)
        op[own_pos] = pages
        osl[own_pos] = slots
        op[own_pos[rel_idx] - 1] = pages[rel_idx]
        osl[own_pos[rel_idx] - 1] = rel_slots
        self.reclaimable._dup_risk += int(rel_idx.size)
        self.reclaimable.push_rows(op, osl)

    def reclaim_window(self, start: int, end: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Out-of-FIFO targeted reclaim of the pool window ``[start, end)``
        (the host-donate shrink path).  Arms the reclaimable queue's
        duplicate guard here, at the mechanism: the reclaimed slots' queue
        rows are NOT popped, so a slot later re-staged for the same page
        gives the queue two live rows for one (slot, page) pair."""
        slots, pages = self.pool.reclaim_window(start, end)
        if slots.size:
            self.reclaimable._dup_risk += int(slots.size)
        return slots, pages

    def reclaim(self, n_slots: int) -> List[Tuple[int, int]]:
        return self.reclaimable.reclaim_up_to(n_slots, self.pool)

    def reclaim_bulk(self, n_slots: int) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized reclaim burst: freed ``(slots, pages)`` arrays."""
        return self.reclaimable.reclaim_bulk(n_slots, self.pool)

    def reclaim_bulk_held(self, n_slots: int, epoch: int, finish_us: float
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Async-daemon reclaim: same vectorized burst, but the freed slots
        go into an epoch-tagged pool hold (``finish_us`` = simulated daemon
        completion) instead of straight back onto the free stack — the
        foreground cannot allocate them until an epoch boundary (or a
        fence) commits the hold."""
        slots, pages = self.reclaimable.reclaim_bulk(n_slots, self.pool)
        if slots.size:
            held = self.pool.hold_from_free(int(slots.size), epoch, finish_us)
            assert held == int(slots.size)
        return slots, pages

    # -- invariants ----------------------------------------------------------

    def check_invariants(self):
        self.pool.check_invariants()
        staged_slots = [s for ws in self.staging.entries() for s in ws.slots]
        for s in staged_slots:
            st = int(self.pool.state[s])
            assert st == _IN_USE, \
                f"staged slot {s} in state {SlotState(st).name}"
        # a page's latest pending slot must never be RECLAIMABLE
        pend_slots = self._pend[self._pend >= 0]
        assert not np.any(self.pool.state[pend_slots] == _RECLAIMABLE)
        assert self._n_deferred == int(np.count_nonzero(self._defer >= 0))
