"""Staging / Reclaimable queues with Update flags (paper §4.1, §5.2).

One ``WriteSet`` is the paper's 24-byte ``tree_entry``: the pages of a single
write transaction.  The pipeline is:

  write completes into local pool  ->  entry enqueued on StagingQueue
  remote send (async, coalesced)   ->  entry moves to ReclaimableQueue
  reclaim                           ->  slots returned to the pool

§5.2 consistency: when two write-sets update the same page, the older one's
slot must NOT be reclaimed before the newer one is sent (its pool slot holds
the only up-to-date copy).  The ``update_flag`` on the slot implements the
skip; both orderings (distance larger/smaller than queue size) are safe.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.pool import SlotState, ValetMempool


@dataclass(slots=True)
class WriteSet:
    """One write transaction: logical pages + their pool slots."""
    seq: int
    pages: Tuple[int, ...]
    slots: Tuple[int, ...]
    migrating_hold: bool = False   # parked while its target block migrates


class StagingQueue:
    """Writes accepted locally but not yet replicated to a remote peer.

    Writing (paging-out) is serialized (paper §3.1 Reliability): entries
    leave in FIFO order, via ``take_batch`` (message coalescing + batch send).
    """

    def __init__(self, max_entries: int):
        self.max_entries = max_entries
        self._q: Deque[WriteSet] = deque()
        self._n_held = 0               # entries currently parked (migration)

    def __len__(self):
        return len(self._q)

    def full(self) -> bool:
        return len(self._q) >= self.max_entries

    def room(self) -> int:
        """Free staging entries — the batch engine's overrun bound."""
        return self.max_entries - len(self._q)

    def push(self, ws: WriteSet) -> bool:
        if self.full():
            return False
        self._q.append(ws)
        return True

    def peek(self) -> Optional[WriteSet]:
        return self._q[0] if self._q else None

    def take_batch(self, n: int, skip_held: bool = True) -> List[WriteSet]:
        """Dequeue up to n sendable entries (held entries stay, FIFO kept).

        With no held entries (the common case — migrations are rare events)
        the whole batch pops without inspecting per-entry hold flags."""
        q = self._q
        if not self._n_held or not skip_held:
            take = min(n, len(q))
            out = [q.popleft() for _ in range(take)]
            if self._n_held:               # skip_held=False popped held ones
                self._n_held -= sum(1 for ws in out if ws.migrating_hold)
            return out
        out: List[WriteSet] = []
        requeue: List[WriteSet] = []
        while q and len(out) < n:
            ws = q.popleft()
            if ws.migrating_hold:
                requeue.append(ws)
            else:
                out.append(ws)
        for ws in reversed(requeue):
            q.appendleft(ws)
        return out

    def hold_pages(self, pages, hold: bool):
        """Park/unpark write-sets touching ``pages`` (migration §3.5)."""
        pages = set(pages)
        held = self._n_held
        for ws in self._q:
            if ws.migrating_hold != hold and pages.intersection(ws.pages):
                ws.migrating_hold = hold
                held += 1 if hold else -1
        self._n_held = held

    def entries(self) -> List[WriteSet]:
        return list(self._q)


class ReclaimableQueue:
    """Write-sets whose remote replica exists; slots are reclaim candidates."""

    def __init__(self, max_entries: int):
        self.max_entries = max_entries
        self._q: Deque[WriteSet] = deque()

    def __len__(self):
        return len(self._q)

    def push(self, ws: WriteSet):
        self._q.append(ws)

    def reclaim_up_to(self, n_slots: int, pool: ValetMempool
                      ) -> List[Tuple[int, int]]:
        """Reclaim oldest entries' slots (LRU over write order).

        Slots whose page has a pending newer update (``update_flag``) are
        skipped per §5.2 — ``mark_reclaimable`` already kept them IN_USE.
        Returns [(slot, logical_page)] actually freed.
        """
        freed: List[Tuple[int, int]] = []
        while self._q and len(freed) < n_slots:
            ws = self._q.popleft()
            for slot, pg in zip(ws.slots, ws.pages):
                m = pool.slots[slot]
                if m.state is SlotState.RECLAIMABLE and m.logical_page == pg:
                    pool.reclaim(slot)
                    freed.append((slot, pg))
        return freed

    def reclaim_bulk(self, n_slots: int, pool: ValetMempool
                     ) -> List[Tuple[int, int]]:
        """``reclaim_up_to`` with the per-slot pool transition inlined —
        identical state changes and counters, none of the per-slot method
        dispatch (reclaim runs in pool-sized bursts on the batched path)."""
        q = self._q
        meta = pool.slots
        free_list = pool._free
        size = pool.size
        used = pool._used
        n_rec = pool.n_reclaimed
        reclaimable = SlotState.RECLAIMABLE
        free_state = SlotState.FREE
        freed: List[Tuple[int, int]] = []
        append = freed.append
        free_append = free_list.append
        popleft = q.popleft
        while q and len(freed) < n_slots:
            ws = popleft()
            slots = ws.slots
            if len(slots) == 1:
                # the dominant shape (one write transaction = one page):
                # no zip machinery, no inner loop
                slot = slots[0]
                pg = ws.pages[0]
                m = meta[slot]
                if m.state is reclaimable and m.logical_page == pg:
                    m.state = free_state
                    m.logical_page = -1
                    m.update_flag = False
                    m.reclaim_flag = False
                    if slot < size:
                        used -= 1
                    free_append(slot)
                    n_rec += 1
                    append((slot, pg))
                continue
            for slot, pg in zip(slots, ws.pages):
                m = meta[slot]
                if m.state is reclaimable and m.logical_page == pg:
                    m.state = free_state
                    m.logical_page = -1
                    m.update_flag = False
                    m.reclaim_flag = False
                    if slot < size:
                        used -= 1
                    free_append(slot)
                    n_rec += 1
                    append((slot, pg))
        pool._used = used
        pool.n_reclaimed = n_rec
        return freed


class WritePipeline:
    """Pool + staging + reclaimable wired together (the write critical path).

    ``write()`` is the paper's Figure 7 left side: it completes as soon as
    pages are in the local pool.  ``flush()`` is the asynchronous Remote
    Sender Thread: it coalesces staged entries, "sends" them (caller-supplied
    callback = replication to a peer/host tier), then marks slots
    reclaimable.
    """

    def __init__(self, pool: ValetMempool, queue_len: int = 4096):
        self.pool = pool
        self.staging = StagingQueue(queue_len)
        self.reclaimable = ReclaimableQueue(queue_len)
        self._seq = 0
        # page -> latest pending slot (for update_flag maintenance)
        self._pending_slot: Dict[int, int] = {}
        # page -> older slot whose reclaim §5.2 deferred until the newer
        # write-set for the page is sent (FIFO flush ⇒ at most one per page)
        self._deferred: Dict[int, int] = {}

    def write(self, pages: Tuple[int, ...], step: int,
              alloc_fallback=None) -> Optional[WriteSet]:
        """Accept a write transaction into the pool.  Returns the WriteSet
        (write is complete for the caller) or None if allocation failed or
        the staging queue is full — either way with NO residual effects
        (slots released, pending-slot map and §5.2 flags restored), so the
        caller's reclaim/stall retry sequence never strands IN_USE slots."""
        slots = []
        prevs = []
        pend = self._pending_slot
        for pg in pages:
            slot = self.pool.alloc(pg, step)
            if slot is None and alloc_fallback is not None:
                slot = alloc_fallback(pg, step)
            if slot is None:
                self._rollback(pages, slots, prevs)
                return None
            prev = pend.get(pg)
            if prev is not None:
                # §5.2 multiple updates: older slot must not be reclaimed
                # before this newer write-set is sent.
                self.pool.slots[prev].update_flag = True
            prevs.append(prev)
            pend[pg] = slot
            slots.append(slot)
        ws = WriteSet(self._seq, tuple(pages), tuple(slots))
        if not self.staging.push(ws):
            # staging overrun: the write did NOT happen — undo everything
            # (leaking here would pin the slots IN_USE forever: they are
            # neither staged nor reclaimable)
            self._rollback(pages, slots, prevs)
            return None
        self._seq += 1
        return ws

    def _rollback(self, pages, slots, prevs):
        """Undo a partially accepted write transaction: release the slots
        and restore each page's previous pending slot + its §5.2 flag (the
        latest pending slot is never update-flagged, so clearing is exact).
        """
        pend = self._pending_slot
        meta = self.pool.slots
        # newest-first so duplicate pages in one transaction unwind exactly
        # (zip truncates to the pages actually processed before the failure)
        for pg, slot, prev in reversed(list(zip(pages, slots, prevs))):
            if prev is not None:
                meta[prev].update_flag = False
                pend[pg] = prev
            else:
                pend.pop(pg, None)
            self.pool.release(slot)

    def stage_batch(self, pages, slots) -> Optional[List[WriteSet]]:
        """Stage one single-page WriteSet per (page, slot) pair in bulk.

        Scalar-equivalent to ``write((pg,), ...)`` per page with the pool
        allocation done up front by ``ValetMempool.alloc_batch``: same seq
        numbers, same FIFO staging order, and the same §5.2 update-flag
        maintenance for duplicate pages (the older pending slot is flagged
        so it is not reclaimed before the newer write-set is sent).

        Requires staging room for the whole batch; returns None without
        side effects otherwise (callers pre-check and fall back to the
        scalar path).
        """
        n = len(pages)
        if self.staging.max_entries - len(self.staging) < n:
            return None
        pend = self._pending_slot
        pool_slots = self.pool.slots
        q = self.staging._q
        seq = self._seq
        out: List[WriteSet] = []
        for pg, slot in zip(pages, slots):
            prev = pend.get(pg)
            if prev is not None:
                pool_slots[prev].update_flag = True
            pend[pg] = slot
            ws = WriteSet(seq, (pg,), (slot,))
            seq += 1
            q.append(ws)
            out.append(ws)
        self._seq = seq
        return out

    def staging_room(self) -> int:
        """Writes acceptable before the staging queue overruns — the batch
        engine bounds each bulk segment with this, so the op that would
        stall lands on the inline boundary path instead."""
        return self.staging.room()

    def complete_fill_batch(self, pages, slots):
        """Cache-fill bookkeeping in bulk: each filled slot is clean (a
        remote copy exists), so it is marked reclaimable and queued as its
        own single-page write-set — the exact per-slot transitions of the
        scalar ``_cache_fill`` tail (``mark_reclaimable`` + push), with the
        method dispatch hoisted out of the loop."""
        meta = self.pool.slots
        q = self.reclaimable._q
        reclaimable = SlotState.RECLAIMABLE
        for pg, slot in zip(pages, slots):
            m = meta[slot]
            if m.update_flag:          # §5.2 deferral, as mark_reclaimable
                m.update_flag = False
            else:
                m.state = reclaimable
                m.reclaim_flag = True
            q.append(WriteSet(-1, (pg,), (slot,)))

    def flush(self, n: int, send_fn) -> List[WriteSet]:
        """Remote Sender Thread step: coalesce + send + mark reclaimable."""
        batch = self.staging.take_batch(n)
        for ws in batch:
            send_fn(ws)
            for pg, slot in zip(ws.pages, ws.slots):
                if self._pending_slot.get(pg) == slot:
                    del self._pending_slot[pg]
                # §5.2 second half: this send supersedes any older slot for
                # the page whose reclaim was deferred — release it now (its
                # original queue entry may already have been popped, so a
                # fresh single-page entry re-queues it)
                deferred = self._deferred.pop(pg, None)
                if deferred is not None and \
                        self.pool.mark_reclaimable(deferred):
                    self.reclaimable.push(WriteSet(-1, (pg,), (deferred,)))
                if not self.pool.mark_reclaimable(slot):
                    self._deferred[pg] = slot
            self.reclaimable.push(ws)
        return batch

    def take_flush_batch(self, n: int) -> List[WriteSet]:
        """Dequeue up to ``n`` sendable write-sets (the batched flush's first
        half; ``complete_flush`` is the second)."""
        return self.staging.take_batch(n)

    def complete_flush(self, batch: List[WriteSet]):
        """Post-send bookkeeping for a taken flush batch, in bulk.

        Identical state transitions to the per-write-set tail of ``flush``
        (pending-slot retirement, §5.2 deferred-release handling, the
        reclaimable pushes) with the method-call and attribute overhead
        hoisted out of the loop.  The caller performs the "send" (placement)
        itself — placement touches peers/blocks/page-table only, this loop
        touches pool/queues only, so running them back to back instead of
        interleaved per write-set reaches the same state."""
        pend = self._pending_slot
        deferred = self._deferred
        slots_meta = self.pool.slots
        push = self.reclaimable.push
        reclaimable = SlotState.RECLAIMABLE
        for ws in batch:
            slots = ws.slots
            if len(slots) == 1:       # dominant shape: one page per ws
                pairs = ((ws.pages[0], slots[0]),)
            else:
                pairs = zip(ws.pages, slots)
            for pg, slot in pairs:
                if pend.get(pg) == slot:
                    del pend[pg]
                d = deferred.pop(pg, None) if deferred else None
                if d is not None:
                    m = slots_meta[d]
                    if m.update_flag:
                        m.update_flag = False
                    else:
                        m.state = reclaimable
                        m.reclaim_flag = True
                        push(WriteSet(-1, (pg,), (d,)))
                m = slots_meta[slot]
                if m.update_flag:
                    m.update_flag = False
                    deferred[pg] = slot
                else:
                    m.state = reclaimable
                    m.reclaim_flag = True
            push(ws)

    def reclaim(self, n_slots: int) -> List[Tuple[int, int]]:
        return self.reclaimable.reclaim_up_to(n_slots, self.pool)

    def reclaim_bulk(self, n_slots: int) -> List[Tuple[int, int]]:
        return self.reclaimable.reclaim_bulk(n_slots, self.pool)

    # -- invariants ----------------------------------------------------------

    def check_invariants(self):
        self.pool.check_invariants()
        staged_slots = [s for ws in self.staging.entries() for s in ws.slots]
        for s in staged_slots:
            st = self.pool.slots[s].state.name
            assert st == "IN_USE", f"staged slot {s} in state {st}"
        # a page's latest pending slot must never be RECLAIMABLE
        for pg, slot in self._pending_slot.items():
            assert self.pool.slots[slot].state.name != "RECLAIMABLE"
