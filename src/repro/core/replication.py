"""Replication & fault-tolerance semantics (paper §5.1, §5.3, Table 3).

Replication is the default (RDMA replica >> disk backup in the paper's
measurements); disk backup maps to our COLD tier.  The four Table-3 modes:

  replication + backup   : read replica first, cold tier if replica fails
  replication only       : read replica; peer loss survivable up to R-1
  backup only            : read cold tier on peer loss
  neither                : remote data loss on peer failure (caching use)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.activity import power_of_two_choices
from repro.core.page_table import GlobalPageTable, Location, Tier


@dataclass(frozen=True)
class FaultConfig:
    replication: int = 1           # number of EXTRA copies (0 = none)
    cold_backup: bool = False      # disk-backup analogue


class ReplicaPlacer:
    """Choose replica peers distinct from the primary (p2c per replica)."""

    def __init__(self, rng: Optional[np.random.Generator] = None):
        self.rng = rng or np.random.default_rng(1)

    def place(self, primary: int, free_counts: Sequence[int],
              n_replicas: int) -> List[int]:
        chosen: List[int] = []
        for _ in range(n_replicas):
            p = power_of_two_choices(free_counts, self.rng,
                                     exclude=[primary] + chosen)
            if p is None:
                break
            chosen.append(p)
        return chosen


def fail_peer(gpt: GlobalPageTable, peer: int, *, cold_fetch=None
              ) -> Tuple[int, int]:
    """Handle a peer failure: repoint pages to replicas, else cold tier.

    Returns (recovered_via_replica, lost_or_cold).
    """
    recovered = lost = 0
    for pg in list(gpt.pages_on_peer(peer)):
        if gpt.repoint_replica(pg):
            recovered += 1
        else:
            if cold_fetch is not None:
                cold_fetch(pg)
                gpt.map_remote(pg, Location(Tier.COLD))
            else:
                gpt.drop_remote(pg)
            lost += 1
    return recovered, lost
