"""Replication & fault-tolerance semantics (paper §5.1, §5.3, Table 3).

Replication is the default (RDMA replica >> disk backup in the paper's
measurements); disk backup maps to our COLD tier.  The four Table-3 modes:

  replication + backup   : read replica first, cold tier if replica fails
  replication only       : read replica; peer loss survivable up to R-1
  backup only            : read cold tier on peer loss
  neither                : remote data loss on peer failure (caching use)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.activity import power_of_two_choices
from repro.core.page_table import GlobalPageTable, Location, Tier


@dataclass(frozen=True)
class FaultConfig:
    replication: int = 1           # number of EXTRA copies (0 = none)
    cold_backup: bool = False      # disk-backup analogue


class ReplicaPlacer:
    """Choose replica peers distinct from the primary (p2c per replica).

    With ``domains`` set (peer -> failure-domain id, e.g. rack), placement
    is *strictly* cross-domain: a replica never lands in the same failure
    domain as the primary or any earlier copy, so one correlated rack
    failure cannot take out every copy.  When no cross-domain peer has
    room the replica set comes up short — the caller's existing
    short-replica path (repair-queue push) owns convergence, which keeps
    the domain-disjointness invariant unconditional instead of
    "unless we fell back".  ``domains=None`` adds no exclusions and no
    extra rng draws — bitwise-identical placement to the flat placer.
    """

    def __init__(self, rng: Optional[np.random.Generator] = None,
                 domains: Optional[Sequence[int]] = None):
        self.rng = rng or np.random.default_rng(1)
        self.domains = list(domains) if domains is not None else None

    def _domain_peers(self, taken: Sequence[int]) -> List[int]:
        """Every peer sharing a failure domain with any peer in ``taken``."""
        dom = self.domains
        bad = {dom[q] for q in taken if 0 <= q < len(dom)}
        return [p for p, d in enumerate(dom) if d in bad]

    def place(self, primary: int, free_counts: Sequence[int],
              n_replicas: int, *,
              exclude: Sequence[int] = ()) -> List[int]:
        """``exclude`` bars additional peers beyond the primary — the
        repair path passes the peers already holding a copy, so a block
        never gets two replicas on one peer (or, with domains, in one
        failure domain)."""
        chosen: List[int] = []
        base = [primary, *exclude]
        for _ in range(n_replicas):
            ex = base + chosen
            if self.domains is not None:
                ex = ex + self._domain_peers(ex)
            p = power_of_two_choices(free_counts, self.rng, exclude=ex)
            if p is None:
                break
            chosen.append(p)
        return chosen


def fail_peer(gpt: GlobalPageTable, peer: int, *, cold_fetch=None,
              peer_alive=None) -> Tuple[int, int]:
    """Handle a peer failure: repoint pages to replicas, else cold tier.

    The scalar reference sweep (``fail_peer_batched`` is pinned bitwise
    against it).  ``peer_alive`` (optional ``peer -> bool``) keeps a
    correlated failure from promoting a replica on another DOWN peer.
    Returns (recovered_via_replica, lost_or_cold).
    """
    recovered = lost = 0
    for pg in list(gpt.pages_on_peer(peer)):
        if gpt.repoint_replica(pg, alive=peer_alive):
            recovered += 1
        else:
            if cold_fetch is not None:
                cold_fetch(pg)
                gpt.map_remote(pg, Location(Tier.COLD))
            else:
                gpt.drop_remote(pg)
            lost += 1
    return recovered, lost


def fail_peer_batched(gpt: GlobalPageTable, peer: int, *, cold_fetch=None,
                      peer_alive=None) -> Tuple[int, int]:
    """Bulk ``fail_peer``: the recovery-storm hot path.

    One masked ``flatnonzero`` finds every page on the dead peer, the
    replica dict is probed once per page (sparse — only replicated pages
    carry tuples), and the page table is updated with two scatters: one
    ``map_remote_batch`` promotes every recoverable page to its first
    live replica, one ``drop_remote_batch`` (or a COLD remap, per the
    Table-3 mode) clears the lost ones.  Final page-table state and the
    ``(recovered, lost)`` counts are bitwise identical to the scalar
    reference — promotions and drops touch disjoint pages, so the
    scatter order cannot matter.
    """
    mask = (gpt._r_tier == int(Tier.PEER)) & (gpt._r_peer == peer) \
        & gpt._r_mapped
    pages = np.flatnonzero(mask)
    if not pages.size:
        return 0, 0
    rd = gpt._replicas
    peer_t = int(Tier.PEER)
    promote: List[int] = []
    new_peer: List[int] = []
    new_slot: List[int] = []
    new_reps: List[Tuple[Tuple[int, int], ...]] = []
    lost_pages: List[int] = []
    if rd:
        for pg in pages.tolist():
            reps = rd.get(pg)
            if reps:
                if peer_alive is not None:
                    reps = tuple(r for r in reps if peer_alive(r[0]))
                if reps:
                    promote.append(pg)
                    new_peer.append(reps[0][0])
                    new_slot.append(reps[0][1])
                    new_reps.append(reps[1:])
                    continue
            lost_pages.append(pg)
    else:
        lost_pages = pages.tolist()
    if promote:
        gpt.map_remote_batch(promote, [peer_t] * len(promote),
                             new_peer, new_slot, new_reps)
    if lost_pages:
        if cold_fetch is not None:
            for pg in lost_pages:
                cold_fetch(pg)
            m = len(lost_pages)
            gpt.map_remote_batch(lost_pages, [int(Tier.COLD)] * m,
                                 [-1] * m, [-1] * m, None)
        else:
            gpt.drop_remote_batch(lost_pages)
    return len(promote), len(lost_pages)
