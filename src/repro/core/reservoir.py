"""Bounded, deterministic latency reservoir for percentile reporting.

``Stats`` aggregates used to be mean-only (total ``time_us`` / ``ops``); the
async orchestration work is judged on *tail* latency, so per-op critical-path
latencies are streamed into this reservoir and ``latency_p50()`` /
``latency_p99()`` read percentiles out of it.

The reservoir is bounded (default 64Ki samples) and fully deterministic: no
RNG is involved, so two runs over the same trace produce identical
percentiles (required — the ``tail_latency`` benchmark is CI-gated on the
sync/async p99 ratio).  When the buffer fills, it is decimated in place
(every other retained sample is kept) and the acceptance stride doubles, so
the retained set is always "every ``stride``-th observation", a uniform
systematic sample of the stream.
"""
from __future__ import annotations

import numpy as np


class LatencyReservoir:
    """Streaming systematic sample of a latency series (microseconds)."""

    __slots__ = ("_cap", "_buf", "_n", "_stride", "_seen")

    def __init__(self, cap: int = 1 << 16):
        if cap < 2:
            raise ValueError("reservoir cap must be >= 2")
        self._cap = int(cap)
        self._buf = np.empty(self._cap, np.float64)
        self._n = 0          # filled prefix of _buf
        self._stride = 1     # keep every _stride-th observation
        self._seen = 0       # total observations offered

    # -- recording ---------------------------------------------------------

    def reset(self) -> None:
        """Drop every sample (benchmarks reset after their warm-up phase)."""
        self._n = 0
        self._stride = 1
        self._seen = 0

    def record(self, us: float) -> None:
        self.record_many(np.asarray([us], np.float64))

    def record_many(self, lats) -> None:
        arr = np.asarray(lats, np.float64).ravel()
        if arr.size == 0:
            return
        if self._stride > 1:
            off = (-self._seen) % self._stride
            self._seen += arr.size
            arr = arr[off::self._stride]
        else:
            self._seen += arr.size
        i = 0
        while i < arr.size:
            take = min(self._cap - self._n, arr.size - i)
            self._buf[self._n:self._n + take] = arr[i:i + take]
            self._n += take
            i += take
            if self._n == self._cap:
                half = self._cap // 2
                self._buf[:half] = self._buf[: 2 * half:2].copy()
                self._n = half
                self._stride *= 2
                arr = arr[i::2]
                i = 0

    # -- reading -----------------------------------------------------------

    @property
    def count(self) -> int:
        """Total observations offered (not the retained sample size)."""
        return self._seen

    def __len__(self) -> int:
        return self._n

    def percentile(self, q: float) -> float:
        """q-th percentile of the retained sample (0.0 when empty)."""
        if self._n == 0:
            return 0.0
        return float(np.percentile(self._buf[:self._n], q))

    def p50(self) -> float:
        return self.percentile(50.0)

    def p99(self) -> float:
        return self.percentile(99.0)

    def p999(self) -> float:
        """99.9th percentile — the SLO-grade tail the workload suite gates.

        Resolution note: p999 needs >= ~1000 retained samples to sit above
        p99; the default 64Ki cap keeps exact streams up to 64Ki ops and a
        stride-decimated systematic sample beyond, which is still an
        unbiased p999 estimator for the deterministic traces we replay."""
        return self.percentile(99.9)

    def summary(self) -> dict:
        """p50/p90/p99/p999/max over the retained sample plus counts."""
        if self._n == 0:
            return {"count": 0, "p50_us": 0.0, "p90_us": 0.0,
                    "p99_us": 0.0, "p999_us": 0.0, "max_us": 0.0}
        live = self._buf[:self._n]
        return {
            "count": self._seen,
            "p50_us": float(np.percentile(live, 50.0)),
            "p90_us": float(np.percentile(live, 90.0)),
            "p99_us": float(np.percentile(live, 99.0)),
            "p999_us": float(np.percentile(live, 99.9)),
            "max_us": float(live.max()),
        }


from dataclasses import dataclass, field  # noqa: E402  (mixin below)


@dataclass
class LatencyStatsMixin:
    """Shared reservoir-backed latency surface for stats dataclasses.

    ``Stats`` (trace store) and ``EngineStats`` (serving engine) both carry
    a per-op critical-path reservoir behind ``latency_p50/p99/p999`` and —
    since the async engines fence on the background daemon — a per-fence
    wait reservoir behind ``fence_wait_p50/p99``.  Both stats classes
    inherit this mixin instead of copy-pasting the accessors.

    The reservoirs are excluded from dataclass equality: two bitwise-equal
    drivers may sample through different entry points (scalar loop vs
    ``access_batch``), and the parity suites compare the counters, not the
    sampling stream.
    """

    # per-op critical-path latency samples (us)
    lat: LatencyReservoir = field(default_factory=LatencyReservoir,
                                  compare=False, repr=False)
    # per-fence simulated wait samples (us); empty in synchronous mode
    fence_lat: LatencyReservoir = field(default_factory=LatencyReservoir,
                                        compare=False, repr=False)

    def latency_p50(self) -> float:
        """Median critical-path op latency (simulated us)."""
        return self.lat.p50()

    def latency_p99(self) -> float:
        """99th-percentile critical-path op latency (simulated us)."""
        return self.lat.p99()

    def latency_p999(self) -> float:
        """99.9th-percentile critical-path op latency (us, SLO tail)."""
        return self.lat.p999()

    def fence_wait_p50(self) -> float:
        """Median simulated wait absorbed by one daemon fence (us)."""
        return self.fence_lat.p50()

    def fence_wait_p99(self) -> float:
        """99th-percentile simulated wait absorbed by one fence (us)."""
        return self.fence_lat.p99()

    def fence_summary(self) -> dict:
        """Reservoir summary of per-fence waits (count/p50/p99/... us)."""
        return self.fence_lat.summary()
