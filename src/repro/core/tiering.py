"""TieredPageStore — the full Valet orchestration over HBM / peer / host /
cold tiers (paper §3 + §4 wired together).

This is the control-plane state machine used by BOTH:

* the **trace simulator** (benchmarks/): drives it with synthetic page-access
  traces (YCSB ETC/SYS analogues) and accumulates simulated microseconds from
  a ``CostModel`` — this reproduces Table 1 / Figures 8, 10, 19-23;
* the **serving engine** (serve/): drives it with real decode steps, where
  the data plane is jnp arrays (``device_ops``) and the cost counters are
  informational.

Policy knobs (``policies.py``) select between Valet and the baseline systems
(Infiniswap / nbdX / OS-swap) without changing the workload code.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.activity import (ActivityTracker,
                                 PairSampler,
                                 select_victims_random)
from repro.core.config import OrchestrationConfig, config_from_legacy_kwargs
from repro.core.faults import PeerHealth, RepairQueue
from repro.core.migration import MigrationEngine
from repro.core.page_table import GlobalPageTable, Location, Tier
from repro.core.policies import CostModel, Policy
from repro.core.pool import SlotState, ValetMempool
from repro.core.queues import WritePipeline
from repro.core.replication import (ReplicaPlacer, fail_peer,
                                    fail_peer_batched)
from repro.core.reservoir import LatencyStatsMixin
from repro.core.tiers import DeviceTier

_IN_USE = int(SlotState.IN_USE)
_RECLAIMABLE = int(SlotState.RECLAIMABLE)


@dataclass
class PeerState:
    """A remote memory donor (receiver module)."""
    capacity: int
    used: int = 0
    connected: bool = False
    mapped_blocks: int = 0
    failed: bool = False

    def free(self) -> int:
        return 0 if self.failed else self.capacity - self.used


@dataclass
class Stats(LatencyStatsMixin):
    """Trace-store counters.  The latency/fence reservoirs and their
    percentile accessors live on the shared ``LatencyStatsMixin`` (also
    inherited by the serve engine's ``EngineStats``)."""
    time_us: float = 0.0
    ops: int = 0
    local_hits: int = 0
    remote_hits: int = 0
    host_hits: int = 0
    cold_hits: int = 0
    writes: int = 0
    write_stall_us: float = 0.0
    evictions: int = 0
    migrations: int = 0
    connects: int = 0
    maps: int = 0
    # async orchestration engine (zero in synchronous mode, so the bitwise
    # dataclass-equality parity asserts between sync drivers still hold)
    fences: int = 0
    fence_wait_us: float = 0.0
    daemon_us: float = 0.0
    # device-tier repoints (zero in the default bitwise-parity mode): reads
    # served by repointing a demoted-but-resident page back to its old pool
    # slot instead of reading a copy from host/remote.  Counted inside
    # local_hits too (after the repoint the page IS local).
    device_hits: int = 0
    # fault handling (core/faults.py; all zero until a fault is injected,
    # so the bitwise dataclass-equality parity asserts keep holding):
    # retry/backoff waits against SUSPECT peers, and re-replication repair
    # traffic (informational — repair runs off the critical path)
    retries: int = 0
    retry_wait_us: float = 0.0
    repair_pages: int = 0
    repair_us: float = 0.0

    def hit_ratio(self) -> Dict[str, float]:
        n = max(self.local_hits + self.remote_hits + self.host_hits
                + self.cold_hits, 1)
        return {
            "local": self.local_hits / n,
            "remote": self.remote_hits / n,
            "host": self.host_hits / n,
            "cold": self.cold_hits / n,
        }


class TieredPageStore:
    """Valet (or baseline) orchestration of one sender node's pages."""

    def __init__(self, policy: Optional[Policy] = None,
                 costs: Optional[CostModel] = None, *,
                 config: Optional[OrchestrationConfig] = None,
                 **legacy):
        """Build a store from ``config`` (the stable API surface).

        ``policy``/``costs`` positionals override the config's when given.
        Every pre-config keyword (``pool_capacity=...`` etc.) still works as
        a deprecated alias: it emits a ``DeprecationWarning`` and folds into
        the config, producing a bitwise-identical store either way."""
        cfg = config if config is not None else OrchestrationConfig()
        if policy is not None:
            cfg = cfg.replace(policy=policy)
        if costs is not None:
            cfg = cfg.replace(costs=costs)
        cfg = config_from_legacy_kwargs(cfg, legacy, owner="TieredPageStore")
        self.config = cfg
        policy = cfg.policy
        costs = cfg.costs
        self.policy = policy
        self.costs = costs
        self.pages_per_block = cfg.pages_per_block
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = Stats()
        self.step = 0
        self.data_plane = cfg.data_plane
        # vectorized off-critical-path pipeline (flush placement, victim
        # selection/migration, delete eviction); False = scalar reference
        self.batch_reclaim = cfg.batch_reclaim

        pool_capacity = cfg.pool_capacity
        n_peers = cfg.n_peers
        peer_capacity_blocks = cfg.peer_capacity_blocks
        host_capacity = cfg.host_capacity
        coordinator = cfg.coordinator
        max_pool = cfg.max_pool or pool_capacity
        min_pool = cfg.min_pool
        if not policy.dynamic_pool:
            min_pool = max_pool
        # §3.4 multi-container mode: the pool leases its pages from a shared
        # HostMemoryCoordinator instead of probing a synthetic host-free
        # callable — growth is granted (possibly reclaiming idle containers'
        # memory) and every shrink returns pages to the shared slab
        self.coordinator = coordinator
        self._lease = None
        if coordinator is not None:
            self._lease = coordinator.register(
                min_pages=min_pool, max_pages=max_pool,
                weight=cfg.weight, name=cfg.container_name)
        self.pool = ValetMempool(pool_capacity, min_pages=min_pool,
                                 max_pages=max_pool,
                                 free_memory_fn=cfg.free_memory_fn,
                                 grow_step=cfg.grow_step,
                                 lease=self._lease)
        if coordinator is not None:
            coordinator.set_donor(self._lease.cid, self.host_donate,
                                  size_fn=lambda: self.pool.size)
            # coordinator-aware remote pressure (§3.4 follow-up): expose this
            # container's per-peer MR-block footprint (dense membership
            # columns) and its pressure handler for coordinated fan-out
            reg = getattr(coordinator, "register_peer_footprint", None)
            if reg is not None:
                reg(self._lease.cid, self._peer_block_footprint,
                    self.peer_pressure)
        self.pipeline = WritePipeline(self.pool,
                                      queue_len=cfg.staging_depth)
        self.gpt = GlobalPageTable()
        # heterogeneous peer profiles (core/cluster.py): per-peer capacity
        # overrides, extra read latency, and failure domains.  None (the
        # default) keeps the flat homogeneous peer set — bitwise identical
        # to every pre-cluster run.
        profiles = cfg.peer_profiles
        if profiles is not None and len(profiles) != n_peers:
            raise ValueError(f"peer_profiles has {len(profiles)} entries "
                             f"for {n_peers} peers")
        self.peers = [PeerState(capacity=(
            profiles[i].capacity_blocks
            if profiles is not None
            and profiles[i].capacity_blocks is not None
            else peer_capacity_blocks)) for i in range(n_peers)]
        if profiles is not None:
            doms = [p.domain for p in profiles]
            self._peer_domain = doms if len(set(doms)) > 1 else None
            lat = np.array([p.latency_us for p in profiles], np.float64)
            self._peer_lat_extra = lat if lat.any() else None
        else:
            self._peer_domain = None
            self._peer_lat_extra = None
        # remote blocks: (peer, block_slot) -> list of logical pages
        self.blocks: Dict[Tuple[int, int], List[int]] = {}
        # dense per-peer block-table membership columns: ``_blk_live[p][s]``
        # is True while MR block (p, s) is allocated, ``_blk_replica[p][s]``
        # while it serves as some primary's replica.  The pressure paths
        # select victim candidates with one masked flatnonzero over these
        # instead of scanning the block dict; the per-block page lists stay
        # list-backed (append-heavy, variable length).
        self._blk_live = [np.zeros(1024, bool) for _ in range(n_peers)]
        self._blk_replica = [np.zeros(1024, bool) for _ in range(n_peers)]
        self.block_replicas: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        # reverse index: replica block -> its primary.  Replica blocks are
        # not independent victims (migrating one would leave the primary's
        # replica list and the page table dangling), so pressure paths skip
        # them and ``_free_block`` keeps both directions consistent.
        self._replica_of: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._next_block_slot = [0] * n_peers
        self._open_block: Dict[int, Tuple[int, int]] = {}   # peer -> block key
        # sized to cover the block-id stride (peer << 20 | slot) upfront so
        # the dense activity arrays never re-grow mid-run (calloc is lazy —
        # untouched pages cost nothing)
        self.tracker = ActivityTracker(max(n_peers, 1) << 20)
        # the pair stream gets its own child generator so bulk pre-drawing
        # (draw_batch) never shifts the replica-placement / migration draws
        # that stay on self.rng — scalar and batched paths consume identical
        # streams from both generators
        self._pairs = PairSampler(n_peers, self.rng.spawn(1)[0]) \
            if n_peers >= 2 else None
        self.placer = ReplicaPlacer(self.rng, domains=self._peer_domain)
        self.host_pages: Dict[int, bool] = {}
        # dense mirror of host_pages membership (append-only): batch
        # classification gathers it instead of probing the dict per page
        self._host_mask = np.zeros(1 << 12, bool)
        # cached peer-failed vector (invalidated by fail_peer) — peers only
        # ever fail through fail_peer, so the batch paths never rebuild it
        self._peer_failed = np.zeros(max(n_peers, 1), bool)
        # fault subsystem (core/faults.py): per-peer health state machine,
        # the cached SUSPECT vector (placement avoidance + retry/backoff
        # pricing), and the re-replication repair queue.  All dormant — and
        # bitwise invisible to the parity suites — until a fault is injected
        # (mark_suspect / fail_peer / the FaultInjector).
        self.health = PeerHealth(n_peers,
                                 suspect_timeout_us=cfg.suspect_timeout_us)
        self._peer_suspect = np.zeros(max(n_peers, 1), bool)
        self._any_suspect = False
        # True while some peer is SUSPECT or REJOINING: the scalar ops poll
        # the health machine (timeout escalation, rejoin activation) only
        # behind this flag, keeping the healthy hot path untouched
        self._health_dirty = False
        self.repairq = RepairQueue()
        # REJOINING warm-up ramp: block grants left before a rejoined peer
        # advertises full free capacity again.  All-zero (and _any_ramp
        # False) until a rejoin event, so fault-free placement never pays
        # the extra arithmetic and stays bitwise identical.
        self._ramp_left = np.zeros(max(n_peers, 1), np.int64)
        self._any_ramp = False
        # whether the coordinator currently holds a non-zero degraded
        # report from us (so the backlog-drained clear fires exactly once)
        self._degraded_reported = False
        # the full exponential backoff ladder, paid per access to a SUSPECT
        # peer: base * (2^0 + 2^1 + ... + 2^(retry_limit-1))
        self._retry_penalty_us = \
            cfg.backoff_base_us * ((1 << cfg.retry_limit) - 1)
        # boundary events of the plan-once batch engine install a list here;
        # _reclaim appends every page whose local mapping it drops, so the
        # engine re-classifies exactly the invalidated pages afterwards
        self._unmap_log: Optional[list] = None
        # PR 8 device tier: remember each reclaimed page's (slot, gen) so a
        # re-access while the slot is still FREE repoints instead of reading
        # the host/remote copy.  Opt-in — the default keeps the bitwise
        # scalar/batch parity of the reference suites (repoints change hit
        # classification and free-stack order).
        self.device = DeviceTier() if cfg.device_tier else None
        self.host_capacity = host_capacity
        # the engine sees encoded block ids (peer<<20|slot); decode for the
        # slot-level data/metadata callbacks
        dec = lambda bid: bid % (1 << 20)
        self.migrator = MigrationEngine(
            self.gpt, self.tracker,
            free_counts_fn=lambda: [
                0 if self._peer_suspect[i] else self._ramp_free(i, p.free())
                for i, p in enumerate(self.peers)],
            copy_fn=lambda sp, sb, dp_, ds: self._copy_block(sp, dec(sb), dp_, ds),
            alloc_fn=self._alloc_block_slot,
            free_fn=lambda p, b: self._free_block(p, dec(b)),
            park_fn=self._park_pages,
            rng=self.rng)
        if self._peer_domain is not None:
            # failure-domain-aware migration: a migrated primary never
            # lands in a rack already holding one of its replicas
            self.migrator.domains = self._peer_domain
            self.migrator.replica_peers_fn = self._block_replica_peers
        # async orchestration engine (tentpole): a background daemon that
        # drains the reclaimable queue / flushes write-sets / charges
        # migration copies off the critical path, with an epoch/fence
        # protocol in place of the inline stall.  None = synchronous mode
        # (bitwise-parity guaranteed, the default).
        self.orchestrator = None
        if cfg.async_mode and policy.use_local_pool:
            from repro.core.async_engine import AsyncOrchestrator
            self.orchestrator = AsyncOrchestrator(
                self, epoch_len=cfg.epoch_len,
                daemon_budget=cfg.daemon_budget,
                real_thread=cfg.real_thread)
            self.migrator.on_block_copied = \
                self.orchestrator.note_block_copied

    @classmethod
    def from_config(cls, config: OrchestrationConfig, *,
                    policy: Optional[Policy] = None,
                    costs: Optional[CostModel] = None) -> "TieredPageStore":
        """The non-deprecated construction path: one config object in,
        no sprawling keyword surface.  ``policy``/``costs`` override the
        config's fields when given (convenient for policy sweeps)."""
        return cls(policy, costs, config=config)

    # -- host-tier membership --------------------------------------------------

    def _host_add(self, page: int):
        """Record a host-tier spill in both the dict (scalar probes) and the
        dense membership bitmap (batch classification gathers)."""
        self.host_pages[page] = True
        hm = self._host_mask
        if page >= hm.shape[0]:
            grown = np.zeros(max(hm.shape[0] * 2, page + 1), bool)
            grown[:hm.shape[0]] = hm
            self._host_mask = grown
            self._host_mask[page] = True
        else:
            hm[page] = True

    # -- block-id helpers ------------------------------------------------------

    def _block_id(self, peer: int, slot: int) -> int:
        return peer * (1 << 20) + slot

    def _blk_ensure(self, peer: int, slot: int):
        """Grow the dense block-membership columns to cover ``slot``."""
        arr = self._blk_live[peer]
        if slot < arr.shape[0]:
            return
        new = max(arr.shape[0] * 2, slot + 1)
        for cols in (self._blk_live, self._blk_replica):
            g = np.zeros(new, bool)
            g[:cols[peer].shape[0]] = cols[peer]
            cols[peer] = g

    def _ramp_free(self, peer: int, free: int) -> int:
        """Warm-up discount on a freshly rejoined peer's advertised free
        count: ramps linearly over its first ``rejoin_ramp_grants`` block
        grants, never below 1 while room exists (the peer must stay
        placeable to warm up at all).  Identity while no ramp is live."""
        if not self._any_ramp or free <= 0:
            return free
        left = int(self._ramp_left[peer])
        if left <= 0:
            return free
        k = self.config.rejoin_ramp_grants
        return max(1, free * (k - left) // k)

    def _ramp_note_grant(self, peer: int) -> None:
        """A block grant landed on a warming-up peer: one ramp step."""
        if self._ramp_left[peer] > 0:
            self._ramp_left[peer] -= 1
            if not self._ramp_left.any():
                self._any_ramp = False

    def _block_replica_peers(self, bid: int) -> List[int]:
        """Peers holding replicas of the (encoded-id) block — the
        migration engine's domain-avoidance probe."""
        key = (bid >> 20, bid % (1 << 20))
        return [r[0] for r in self.block_replicas.get(key, ())]

    def _alloc_block_slot(self, peer: int) -> Optional[int]:
        p = self.peers[peer]
        if p.failed or self._peer_suspect[peer] or p.free() <= 0:
            return None
        if self._any_ramp:
            self._ramp_note_grant(peer)
        slot = self._next_block_slot[peer]
        self._next_block_slot[peer] += 1
        p.used += 1
        p.mapped_blocks += 1
        self.blocks[(peer, slot)] = []
        self._blk_ensure(peer, slot)
        self._blk_live[peer][slot] = True
        if not p.connected:
            p.connected = True
            self.stats.connects += 1
            self.stats.time_us += 0.0 if self.policy.use_local_pool \
                else self.costs.connect
        self.stats.maps += 1
        if not self.policy.use_local_pool:
            self.stats.time_us += self.costs.map_block
        return slot

    def _free_block(self, peer: int, slot: int, *,
                    free_replicas: bool = False):
        """Release one MR block.

        ``free_replicas=True`` (the delete-eviction paths) additionally
        garbage-collects the freed primary's replica blocks: a replica that
        no page references any more — neither as its primary location (a
        ``repoint_replica`` promotion) nor in its replica tuple — is dead
        weight on its peer and is freed too.  Migration keeps the default:
        a migrated primary's pages still carry their replica tuples, so
        those blocks stay live (they are merely detached here)."""
        self.peers[peer].used -= 1
        key = (peer, slot)
        pages = self.blocks.pop(key, None)
        self._blk_live[peer][slot] = False
        if self._open_block.get(peer) == key:
            self._open_block.pop(peer)
        prim = self._replica_of.pop(key, None)
        if prim is not None:
            self._blk_replica[peer][slot] = False
            reps = self.block_replicas.get(prim)
            if reps:
                self.block_replicas[prim] = tuple(r for r in reps
                                                  if r != key)
        for r in self.block_replicas.pop(key, ()):
            # freeing a primary orphans its replicas: they stop being
            # replicas (and become ordinary eviction candidates) ...
            self._replica_of.pop(r, None)
            self._blk_replica[r[0]][r[1]] = False
            if free_replicas and not self._block_referenced(r):
                # ... unless nothing references them at all — then the
                # orphan would leak its peer memory forever (ROADMAP
                # follow-up): free it symmetrically with its primary
                self._free_block(*r)
        return pages

    def _block_referenced(self, key: Tuple[int, int]) -> bool:
        """True if any page in ``key``'s block still resolves to it — as its
        remote primary (replica promotion) or inside its replica tuple."""
        peer, slot = key
        for pg in self.blocks.get(key, ()):
            loc = self.gpt.remote_location(pg)
            if loc is None:
                continue
            if loc.tier == Tier.PEER and loc.peer == peer \
                    and loc.slot == slot:
                return True
            if key in loc.replicas:
                return True
        return False

    def _copy_block(self, src_peer, src_slot, dst_peer, dst_slot):
        pages = self.blocks.get((src_peer, src_slot), [])
        self.blocks[(dst_peer, dst_slot)] = list(pages)
        self.tracker.touch(self._block_id(dst_peer, dst_slot), self.step)
        # migration copy cost lands on peers, NOT the sender critical path
        if self.data_plane is not None:
            self.data_plane.copy_block(src_peer, src_slot, dst_peer, dst_slot)

    def _park_pages(self, pages, hold: bool):
        self.pipeline.staging.hold_pages(pages, hold)

    # -- placement -------------------------------------------------------------

    def _place_remote_raw(self, page: int
                          ) -> Optional[Tuple[int, int, Tuple]]:
        """Append the page to an open MR block (p2c peer choice per page).

        Returns ``(peer, slot, replicas)`` or None.  Runs once per flushed
        page, so the peer pair comes from the buffered ``PairSampler`` and
        only the two sampled peers' free counts are computed (same p2c
        decision as scanning all of them)."""
        if not self.policy.use_remote:
            return None
        peers = self.peers
        susp = self._peer_suspect
        if self._pairs is not None:
            a, b = self._pairs.draw()
            pa, pb = peers[a], peers[b]
            fa = 0 if pa.failed or susp[a] else pa.capacity - pa.used
            fb = 0 if pb.failed or susp[b] else pb.capacity - pb.used
            if self._any_ramp:
                fa = self._ramp_free(a, fa)
                fb = self._ramp_free(b, fb)
            peer, best_free = (a, fa) if fa >= fb else (b, fb)
        elif peers:
            peer, best_free = 0, peers[0].free()
        else:
            return None                   # no peers configured: host spill
        if best_free <= 0:
            return None
        blk = self._open_block.get(peer)
        if blk is None or len(self.blocks.get(blk, [])) >= self.pages_per_block:
            slot = self._alloc_block_slot(peer)
            if slot is None:
                return None
            blk = (peer, slot)
            self._open_block[peer] = blk
            # replicas are allocated at BLOCK granularity alongside the primary
            reps = []
            if self.policy.replication > 0:
                free = [0 if susp[j] else self._ramp_free(j, p.free())
                        for j, p in enumerate(peers)]
                for rp in self.placer.place(peer, free,
                                            self.policy.replication):
                    rslot = self._alloc_block_slot(rp)
                    if rslot is not None:
                        reps.append((rp, rslot))
                        self._replica_of[(rp, rslot)] = blk
                        self._blk_replica[rp][rslot] = True
            # tuple, like the bulk placement path: block_replicas values are
            # immutable once the block closes
            self.block_replicas[blk] = tuple(reps)
            if len(reps) < self.policy.replication:
                # degraded from birth (no live peer had room): queue for
                # background re-replication once the topology improves
                self.repairq.push(blk)
        self.blocks[blk].append(page)
        self.tracker.touch(self._block_id(*blk), self.step)
        reps = self.block_replicas.get(blk, ())
        for rp, rs in reps:
            self.blocks[(rp, rs)].append(page)
        return blk[0], blk[1], tuple(reps)

    def _place_remote(self, page: int) -> Optional[Location]:
        placed = self._place_remote_raw(page)
        if placed is None:
            return None
        peer, slot, reps = placed
        return Location(Tier.PEER, peer=peer, slot=slot, replicas=reps)

    def _place_pages_bulk(self, pages, *, flush: bool):
        """Bulk ``_place_remote_raw`` over a page sequence.

        One ``PairSampler.draw_batch`` pre-draws every p2c pair, peer
        capacity/usage and the open-block fill state are tracked in local
        scalars, and activity tags are scattered once at the end — the
        placement *decisions* (peer choice, block boundaries, replica
        placement, spill fallbacks, rng consumption) are identical to
        calling the scalar helper once per page.

        ``flush=True`` (lazy-send path): failed placements spill to the HOST
        tier (``host_pages`` updated) and each page costs host/remote write;
        no critical-path time is charged here.  ``flush=False``
        (write-through write run): failed placements fall to COLD, per-page
        latency plus block connect/map costs accumulate into
        ``stats.time_us`` in exactly the scalar interleaving, and the
        activity tag carries the per-op step.

        Returns ``(tiers, peers, slots, replicas, costs)`` parallel lists,
        ready for one ``map_remote_batch`` scatter.
        """
        n = len(pages)
        pol = self.policy
        c = self.costs
        peer_tier = int(Tier.PEER)
        if flush:
            spill_tier, spill_cost = int(Tier.HOST), c.host_write
            hit_cost = c.remote_write
        else:
            spill_tier, spill_cost = int(Tier.COLD), c.cold_write
            hit_cost = c.remote_write
            if pol.receiver_side_cpu:
                hit_cost = hit_cost + c.receiver_cpu
        tiers = [spill_tier] * n
        peers_out = [-1] * n
        slots_out = [-1] * n
        reps_out: List[Tuple] = [()] * n
        costs = [spill_cost] * n

        st = self.stats
        peers = self.peers
        if not pol.use_remote or not peers:
            if flush:
                hadd = self._host_add
                for pg in pages:
                    hadd(pg)
            else:
                st.time_us = self._accumulate_time(
                    st.time_us, np.full(n, spill_cost, np.float64))
            return tiers, peers_out, slots_out, reps_out, costs

        pairs = self._pairs
        if pairs is not None:
            pa, pb = pairs.draw_batch(n)
            pa_l, pb_l = pa.tolist(), pb.tolist()
        n_peers = len(peers)
        cap = [p.capacity for p in peers]
        used = [p.used for p in peers]
        # SUSPECT peers are unplaceable exactly like failed ones (the scalar
        # helper zeroes their free counts the same way), so one merged list
        # serves every free-count probe below
        susp = self._peer_suspect
        failed = [p.failed or bool(susp[j]) for j, p in enumerate(peers)]
        connected = [p.connected for p in peers]
        mapped = [p.mapped_blocks for p in peers]
        next_slot = list(self._next_block_slot)
        blocks = self.blocks
        block_replicas = self.block_replicas
        open_block = self._open_block
        ppb = self.pages_per_block
        repl = pol.replication
        place_reps = self.placer.place
        use_local_pool = pol.use_local_pool
        step = self.step
        hadd = self._host_add
        connects = st.connects
        maps = st.maps
        t = st.time_us
        touch: Dict[int, int] = {}          # block id -> last-writer step
        # per-peer open-block cache:
        # [slot, page_list, replicas, rep_lists, block id]
        open_cache: Dict[int, list] = {}

        def load_open(peer):
            blk = open_block.get(peer)
            if blk is None:
                return None
            lst = blocks[blk]
            reps = tuple(block_replicas.get(blk, ()))
            entry = [blk[1], lst, reps, [blocks[r] for r in reps],
                     peer * (1 << 20) + blk[1]]
            open_cache[peer] = entry
            return entry

        def alloc_slot(peer):
            nonlocal connects, maps, t
            if failed[peer] or cap[peer] - used[peer] <= 0:
                return None
            if self._any_ramp:
                self._ramp_note_grant(peer)
            slot = next_slot[peer]
            next_slot[peer] = slot + 1
            used[peer] += 1
            mapped[peer] += 1
            lst: List[int] = []
            blocks[(peer, slot)] = lst
            self._blk_ensure(peer, slot)
            self._blk_live[peer][slot] = True
            if not connected[peer]:
                connected[peer] = True
                connects += 1
                if not use_local_pool:
                    t += c.connect
            maps += 1
            if not use_local_pool:
                t += c.map_block
            return slot, lst

        for i, pg in enumerate(pages):
            if not flush:
                step += 1                    # scalar write() bumps per op
            if pairs is not None:
                a = pa_l[i]
                b = pb_l[i]
                fa = 0 if failed[a] else cap[a] - used[a]
                fb = 0 if failed[b] else cap[b] - used[b]
                if self._any_ramp:
                    fa = self._ramp_free(a, fa)
                    fb = self._ramp_free(b, fb)
                if fa >= fb:
                    peer, best_free = a, fa
                else:
                    peer, best_free = b, fb
            else:
                peer = 0
                best_free = 0 if failed[0] else cap[0] - used[0]
                if self._any_ramp:
                    best_free = self._ramp_free(0, best_free)
            placed = False
            if best_free > 0:
                entry = open_cache.get(peer)
                if entry is None:
                    entry = load_open(peer)
                if entry is None or len(entry[1]) >= ppb:
                    res = alloc_slot(peer)
                    if res is None:
                        entry = None
                    else:
                        slot, lst = res
                        open_block[peer] = (peer, slot)
                        reps: List[Tuple[int, int]] = []
                        rep_lists: List[list] = []
                        if repl > 0:
                            free_now = [0 if failed[j] else cap[j] - used[j]
                                        for j in range(n_peers)]
                            if self._any_ramp:
                                free_now = [self._ramp_free(j, f)
                                            for j, f in enumerate(free_now)]
                            for rp in place_reps(peer, free_now, repl):
                                r = alloc_slot(rp)
                                if r is not None:
                                    reps.append((rp, r[0]))
                                    rep_lists.append(r[1])
                                    self._replica_of[(rp, r[0])] = \
                                        (peer, slot)
                                    self._blk_replica[rp][r[0]] = True
                        entry = [slot, lst, tuple(reps), rep_lists,
                                 peer * (1 << 20) + slot]
                        block_replicas[(peer, slot)] = entry[2]
                        if len(reps) < repl:
                            # degraded from birth — same enqueue (and same
                            # condition) as the scalar helper, so the
                            # parity traces agree on the repair queue too
                            self.repairq.push((peer, slot))
                        open_cache[peer] = entry
                if entry is not None:
                    entry[1].append(pg)
                    touch[entry[4]] = step
                    for rl in entry[3]:
                        rl.append(pg)
                    tiers[i] = peer_tier
                    peers_out[i] = peer
                    slots_out[i] = entry[0]
                    reps_out[i] = entry[2]
                    costs[i] = hit_cost
                    placed = True
            if not placed and flush:
                hadd(pg)
            if not flush:
                t += costs[i]

        for j in range(n_peers):
            p = peers[j]
            p.used = used[j]
            p.mapped_blocks = mapped[j]
            p.connected = connected[j]
        self._next_block_slot = next_slot
        if touch:
            self.tracker.on_write_map(touch)
        st.connects = connects
        st.maps = maps
        if not flush:
            st.time_us = t
        return tiers, peers_out, slots_out, reps_out, costs

    # -- the two critical-path operations ---------------------------------------

    def write(self, page: int) -> float:
        """Write (page-out) one page.  Returns critical-path latency (us)."""
        self.step += 1
        if self._health_dirty:
            self._poll_health()
        self.stats.writes += 1
        lat = 0.0

        if self.policy.use_local_pool:
            ws = self.pipeline.write((page,), self.step)
            if ws is None:
                # pool exhausted: reclaim from reclaimable queue (pointer move)
                self._reclaim(max(1, self.pages_per_block))
                ws = self.pipeline.write((page,), self.step)
            if ws is None:
                # still nothing reclaimable: must flush synchronously (stall)
                lat += self._flush(self.pages_per_block, in_critical_path=True)
                self._reclaim(self.pages_per_block)
                ws = self.pipeline.write((page,), self.step)
            if ws is not None:
                self.gpt.map_local(page, ws.slots[0])
                if self.data_plane is not None:
                    self.data_plane.local_write(page, ws.slots[0])
                lat += self.costs.local_write
            else:
                lat += self.costs.cold_write       # total pressure: spill cold
                self._host_add(page)
        else:
            # write-through systems: remote send in the critical path
            loc = self._place_remote(page)
            if loc is not None:
                self.gpt.map_remote(page, loc)
                lat += self.costs.remote_write
                if self.policy.receiver_side_cpu:
                    lat += self.costs.receiver_cpu
                if self.policy.cold_backup:
                    pass                           # async disk backup
            else:
                self.gpt.map_remote(page, Location(Tier.COLD))
                lat += self.costs.cold_write
        self.stats.time_us += lat
        self.stats.ops += 1
        return lat

    def _device_repoint(self, pages) -> int:
        """Zero-copy device-tier hits (PR 8, opt-in via ``device_tier``).

        Pages whose reclaimed pool slot is still FREE with an unchanged
        generation are *repointed*: the slot is claimed back off the free
        list and the page mapped local again with pure metadata moves — no
        host/remote read.  The repointed slot re-enters the store exactly
        like a cache fill (RECLAIMABLE + on the reclaimable queue, remote
        copy kept as the replica), so the invariant checker's no-lost-writes
        and staging invariants keep holding.  Stale shadows — a page that
        re-entered the pool through a write since its demotion — are dropped
        here, never claimed.  Returns the number of pages repointed."""
        dt = self.device
        if dt is None or not dt.shadow:
            return 0
        cand = []
        for pg in pages:
            pg = int(pg)
            if pg not in dt:
                continue
            if self.gpt.local_slot(pg) is not None:
                dt.drop((pg,))      # stale: page already local via a write
            else:
                cand.append(pg)
        if not cand:
            return 0
        rp_pages, rp_slots, _ = dt.split(cand, self.pool.free_gen)
        if not rp_pages:
            return 0
        self.pool.claim_batch(rp_slots, rp_pages, self.step)
        self.gpt.map_local_batch(np.asarray(rp_pages, np.int64),
                                 np.asarray(rp_slots, np.int64))
        for pg, sl in zip(rp_pages, rp_slots):
            self.pool.mark_reclaimable(sl)
            self.pipeline.reclaimable.push_row(pg, sl)
        self.stats.device_hits += len(rp_pages)
        return len(rp_pages)

    def read(self, page: int) -> float:
        """Read (page-in) one page.  Returns critical-path latency (us)."""
        self.step += 1
        if self._health_dirty:
            self._poll_health()
        if self.device is not None:
            # device-tier pre-check: a still-resident demoted page becomes
            # LOCAL here, so the classification below counts a local hit
            self._device_repoint((page,))
        lat = 0.0
        loc = self.gpt.lookup(page)
        if loc.tier == Tier.LOCAL:
            self.stats.local_hits += 1
            lat = self.costs.local_read
        elif loc.tier == Tier.PEER and not self.peers[loc.peer].failed:
            self.stats.remote_hits += 1
            lat = self.costs.remote_read
            if self._peer_lat_extra is not None:
                # heterogeneous peers (PeerProfile): far racks cost more
                lat += self._peer_lat_extra[loc.peer]
            if self.policy.receiver_side_cpu:
                lat += self.costs.receiver_cpu
            if self._any_suspect and self._peer_suspect[loc.peer]:
                # SUSPECT peer: the op times out and retries with
                # exponential backoff — latency degrades, durability
                # doesn't (the data is still there)
                lat += self._suspect_penalty()
            self._cache_fill(page)
        elif loc.tier == Tier.HOST or page in self.host_pages:
            self.stats.host_hits += 1
            lat = self.costs.host_read
            self._cache_fill(page)
        else:
            self.stats.cold_hits += 1
            lat = self.costs.cold_read
        self.stats.time_us += lat
        self.stats.ops += 1
        return lat

    # -- batched critical path (vectorized orchestration) ------------------------

    def access_batch(self, pages, is_write) -> np.ndarray:
        """Batched page accesses: exact-parity fast path for ``write``/``read``.

        ``pages`` is an int sequence; ``is_write`` is a bool (whole batch is
        one op) or a bool sequence (a mixed trace slice).  Returns the per-op
        critical-path latency array, identical to calling the scalar ops in
        sequence — Stats (counts AND accumulated microseconds) are bitwise
        equal to the scalar loop.

        For local-pool policies (Valet) the whole mixed batch runs through
        the plan-once engine (``_access_pooled``): one snapshot gather plus
        one stable argsort resolve every location and intra-batch dependency
        (read-after-write, duplicate reads after a cache fill) up front,
        then the batch executes as bulk segments separated by inline
        boundary events.  A segment ends where the pool free list (growth
        included) or the staging queue would be overrun; the overrunning op
        replays the exact scalar reclaim / flush-stall schedule inline, and
        only the pages that event invalidated are re-classified — the batch
        is never re-analyzed, which keeps the tight-pool (high eviction
        pressure) regime vectorized.

        Write-through policies run per homogeneous run: reads (which never
        mutate state — there is no local pool to fill) are classified with
        one snapshot gather, and writes go through the bulk placement engine
        (``_place_pages_bulk``) with pre-drawn power-of-two-choices pairs and
        one ``map_remote_batch`` scatter — unless ``batch_reclaim`` is off,
        in which case writes keep the scalar reference loop.
        """
        pages = np.asarray(pages, np.int64)
        n = pages.size
        lats = np.empty(n, np.float64)
        iw = np.broadcast_to(np.asarray(is_write, bool), (n,))
        if self._health_dirty:
            self._poll_health()
        if (self._any_suspect or self._peer_lat_extra is not None) \
                and self.orchestrator is None:
            # degraded mode: the plan-once engine's cost LUT cannot price
            # the per-peer retry/backoff ladder — nor per-peer latency
            # profiles — so faulted/heterogeneous batches replay the
            # scalar ops (the async orchestrator is already per-op and
            # prices both inside read()).  Healthy homogeneous batches
            # never reach this branch — the fast paths stay bitwise intact.
            if self._lease is not None:
                self.coordinator.note_activity(self._lease.cid, n)
            for k in range(n):
                lats[k] = self.write(int(pages[k])) if iw[k] \
                    else self.read(int(pages[k]))
            self.stats.lat.record_many(lats)
            return lats
        if self.device is not None and self.device.shadow:
            # device-tier pre-pass: repoint still-resident demoted pages this
            # batch will read, so the snapshot below classifies them LOCAL
            self._device_repoint(np.unique(pages[~iw]))
        if self._lease is not None:
            # per-container demand signal (§3.4): recently busy containers
            # are reclaimed from last under host pressure.  Accounting only —
            # never changes classification, rng draws, or Stats.
            self.coordinator.note_activity(self._lease.cid, n)
        if self.orchestrator is not None:
            # async mode: ops pin the current epoch; reclaim/flush commit at
            # epoch boundaries inside run_batch (not bitwise-parity — see
            # AsyncOrchestrator / InvariantChecker)
            self.orchestrator.run_batch(pages, iw, lats)
            self.stats.lat.record_many(lats)
            return lats
        if self.policy.use_local_pool:
            self._access_pooled(pages, iw, lats)
            self.stats.lat.record_many(lats)
            return lats
        i = 0
        while i < n:
            j = i + 1
            w = iw[i]
            while j < n and iw[j] == w:
                j += 1
            if w:
                if self.batch_reclaim:
                    lats[i:j] = self._write_run_writethrough(pages[i:j])
                else:
                    for k in range(i, j):
                        lats[k] = self.write(int(pages[k]))
            else:
                lats[i:j] = self._read_run_writethrough(pages[i:j])
            i = j
        self.stats.lat.record_many(lats)
        return lats

    # classification codes, mirroring the scalar read's resolution order
    _CLS_LOCAL, _CLS_REMOTE, _CLS_HOST, _CLS_COLD = 0, 1, 2, 3

    def _snapshot_classes(self, pages: np.ndarray, *,
                          known: bool = False) -> np.ndarray:
        """Vectorized read classification against the current table state.

        Fully gather-based: host-tier membership comes from the dense
        ``_host_mask`` bitmap and peer liveness from the cached
        ``_peer_failed`` vector, so there is no per-page Python in here.
        ``known=True`` skips the page-table growth check (targeted
        re-gathers over pages already resolved this batch)."""
        n = pages.size
        if known:
            l_slot, r_tier, r_peer = self.gpt.lookup_raw_known(pages)
        else:
            l_slot, r_tier, r_peer = self.gpt.lookup_raw(pages)
        is_local = l_slot >= 0
        is_peer = ~is_local & (r_tier == int(Tier.PEER))
        remote_hit = is_peer
        if is_peer.any():
            failed = self._peer_failed
            if failed.any():
                remote_hit = is_peer.copy()
                pi = np.flatnonzero(is_peer)
                remote_hit[pi] = ~failed[r_peer[pi]]
        rest = ~is_local & ~remote_hit
        host_hit = np.zeros(n, bool)
        if rest.any():
            ri = np.flatnonzero(rest)
            if self.host_pages:
                hm = self._host_mask
                pr = pages[ri]
                memb = (pr < hm.shape[0]) \
                    & hm[np.minimum(pr, hm.shape[0] - 1)]
                host_hit[ri] = (r_tier[ri] == int(Tier.HOST)) | memb
            else:
                host_hit[ri] = r_tier[ri] == int(Tier.HOST)
        cls = np.full(n, self._CLS_COLD, np.int8)
        cls[is_local] = self._CLS_LOCAL
        cls[remote_hit] = self._CLS_REMOTE
        cls[host_hit] = self._CLS_HOST
        return cls

    def _classify_scalar(self, pg: int) -> int:
        """Scalar mirror of ``_snapshot_classes`` for one page (targeted
        boundary re-classification): same resolution order — local mapping,
        live-peer remote, host membership (tier or spill dict), cold."""
        gpt = self.gpt
        if gpt._l_slot[pg] >= 0:
            return self._CLS_LOCAL
        t = int(gpt._r_tier[pg])
        if t == int(Tier.PEER) and not self._peer_failed[gpt._r_peer[pg]]:
            return self._CLS_REMOTE
        if t == int(Tier.HOST) or (self.host_pages
                                   and pg in self.host_pages):
            return self._CLS_HOST
        return self._CLS_COLD

    def _cost_lut(self) -> np.ndarray:
        """Per-class cost table; entry 4 is the write cost so a single fancy
        index prices a mixed batch (writes carry class 4 in ``eff``).
        Cached — ``CostModel`` and ``Policy`` are frozen."""
        lut = getattr(self, "_lut_cache", None)
        if lut is None:
            c = self.costs
            rr = c.remote_read
            if self.policy.receiver_side_cpu:
                rr = rr + c.receiver_cpu
            lut = np.array([c.local_read, rr, c.host_read, c.cold_read,
                            c.local_write], np.float64)
            self._lut_cache = lut
        return lut

    @staticmethod
    def _accumulate_time(t: float, costs: np.ndarray) -> float:
        """Left-to-right float accumulation of ``t + c0 + c1 + ...`` — the
        same double-add sequence as the scalar loop's ``time_us += lat``
        (cumsum is sequential in C), so totals stay bitwise identical."""
        tmp = np.empty(costs.size + 1, np.float64)
        tmp[0] = t
        tmp[1:] = costs
        return float(np.add.accumulate(tmp)[-1])

    def _access_pooled(self, pages: np.ndarray, iw: np.ndarray,
                       out_lats: np.ndarray) -> None:
        """Plan-once batch engine for local-pool policies.

        The dependency analysis — one stable argsort by page, the per-page
        group structure, the effective per-op classes and the alloc plan —
        is computed ONCE for the whole batch.  The batch then executes as
        bulk segments separated by *inline boundary events*: a segment is
        sized so its allocations fit the pool (``alloc_prefix_capacity``,
        growth included) and its writes fit the staging queue, and the op
        that would overrun runs through ``_boundary_write`` /
        ``_boundary_fill_read``, which replay the exact scalar schedule
        (same ``_reclaim(pages_per_block)`` sizes, same flush-stall
        accounting, same rng draw order).  After the event, only the pages
        the reclaim/fill invalidated are re-classified (one targeted
        ``lookup_raw`` gather) and their remaining ops re-planned — the
        batch is never re-sorted or re-snapshotted, so ``Stats`` stay
        bitwise identical to the scalar loop at a fraction of the old
        prefix-restart cost under pressure."""
        n = pages.size
        cls = self._snapshot_classes(pages)
        fillable = (cls == self._CLS_REMOTE) | (cls == self._CLS_HOST)
        lut = self._cost_lut()

        if not iw.any() and not fillable.any():
            # pure local/cold reads: no state change, no dependencies —
            # straight group accounting, no per-page work at all
            st = self.stats
            counts4 = np.bincount(cls, minlength=4)
            st.local_hits += int(counts4[0])
            st.cold_hits += int(counts4[3])
            costs = lut[cls]
            st.time_us = self._accumulate_time(st.time_us, costs)
            st.ops += n
            out_lats[:n] = costs
            self.step += n
            return

        # group ops by page (argsort stable ⇒ op order within each group) to
        # resolve dependencies: a read behind a write to the same page is a
        # LOCAL hit; the first read of a remote/host page (with no write
        # before it) cache-fills, turning that page's later reads LOCAL too.
        order = np.argsort(pages, kind="stable")
        pg_s = pages[order]
        iw_s = iw[order]
        new_grp = np.empty(n, bool)
        new_grp[0] = True
        np.not_equal(pg_s[1:], pg_s[:-1], out=new_grp[1:])
        starts = np.flatnonzero(new_grp)
        sizes = np.diff(np.append(starts, n))
        group_pages = pg_s[starts]                 # unique pages, ascending
        cw = np.cumsum(iw_s)                       # writes, cumulative
        wr_before_s = cw - np.repeat(cw[starts] - iw_s[starts], sizes) - iw_s
        cand_s = ~iw_s & (wr_before_s == 0)        # reads seeing table state
        cs = np.cumsum(cand_s)
        first_cand_s = cand_s & \
            (cs - np.repeat(cs[starts] - cand_s[starts], sizes) == 1)
        has_ew = np.empty(n, bool)                 # same-page write earlier
        cand = np.empty(n, bool)
        decider = np.empty(n, bool)                # first such read per page
        has_ew[order] = wr_before_s > 0
        cand[order] = cand_s
        decider[order] = first_cand_s

        fill = decider & fillable
        eff = cls                                  # effective per-op class
        # LOCAL for reads behind a same-page write, and for reads of a
        # remote/host page behind its cache-filling first read; writes carry
        # the sentinel class 4 (prices + counts them in one pass)
        eff[~iw & (has_ew | (cand & ~decider & fillable))] = self._CLS_LOCAL
        eff[iw] = 4
        alloc_mask = iw | fill
        # last op position per group: a boundary event only re-plans groups
        # that still have ops after it (vectorized via this gather)
        glast = order[starts + sizes - 1]
        pages_l = pages.tolist()        # one materialization for the batch

        # running-cumulative bounds: the write cumsum is fixed for the batch
        # (is_write never changes); the alloc cumsum and the hoisted
        # execution arrays below are recomputed only when a boundary event
        # actually re-planned some group (rare)
        cum_wr = np.cumsum(iw)
        total_w = int(cum_wr[-1])
        cum_alloc = np.cumsum(alloc_mask)
        total_a = int(cum_alloc[-1])
        # batch-hoisted execution arrays: every segment takes contiguous
        # slices of these instead of re-deriving them per segment
        alloc_pos = np.flatnonzero(alloc_mask)     # positions of alloc ops
        apages_all = pages[alloc_pos]
        aw_all = iw[alloc_pos]                     # write (vs fill) allocs
        costs_all = lut[eff]                       # per-op latencies

        # boundary-side lookup structures, built lazily on the first
        # boundary (pressure-free batches never pay for them)
        page_group = None
        glast_l = None

        # deferred accounting: Stats counters, the step counter, and the
        # sequential time accumulation for segment-executed ops flush in
        # one pass per scalar interruption (a stall tail reads the live
        # Stats) and once at batch end — executed ops' classes never change
        # (re-plans only touch ops behind the boundary), and concatenated
        # accumulate slices reproduce the per-segment double-add sequence
        # bit for bit
        st = self.stats
        step_base = self.step
        acct = 0
        lat_override: List[Tuple[int, float]] = []

        def flush_acct(upto: int):
            nonlocal acct
            if upto > acct:
                c0, c1, c2, c3, c4 = np.bincount(
                    eff[acct:upto], minlength=5).tolist()
                st.writes += c4
                st.ops += upto - acct
                st.local_hits += c0
                st.remote_hits += c1
                st.host_hits += c2
                st.cold_hits += c3
                st.time_us = self._accumulate_time(
                    st.time_us, costs_all[acct:upto])
                self.step += upto - acct
                acct = upto

        s = 0
        while s < n:
            # segment bound: allocations (writes + fills) must fit what the
            # pool can serve without a reclaim (growth included) and writes
            # must fit the staging queue (no stall may run mid-segment)
            base_a = int(cum_alloc[s - 1]) if s else 0
            need = total_a - base_a
            cap = self.pool.alloc_prefix_capacity(need)
            if cap >= need:
                m = n - s
                pool_bound = False
            else:
                m = int(np.searchsorted(cum_alloc, base_a + cap,
                                        side="right")) - s
                pool_bound = True
            room = self.pipeline.staging_room()
            base_w = int(cum_wr[s - 1]) if s else 0
            staging_bound = False
            if total_w - base_w > room:
                mw = int(np.searchsorted(cum_wr, base_w + room,
                                         side="right")) - s
                if mw < m:
                    m = mw
                    pool_bound = False
                    staging_bound = True
                elif mw == m:
                    staging_bound = True
            if m:
                self._run_segment(pages_l, eff, alloc_mask, alloc_pos,
                                  apages_all, aw_all, step_base, s, m)
                s += m
            if s < n:
                if page_group is None:
                    page_group = {p: g for g, p in
                                  enumerate(group_pages.tolist())}
                    glast_l = glast.tolist()
                if pool_bound and not staging_bound \
                        and self.pool.size >= self.pool.max_pages:
                    # pure pool overrun on a pool pinned at max_pages: the
                    # reclaim replays scalar, the op itself is absorbed into
                    # the next segment
                    s2, replanned = self._boundary_inline(
                        pages_l, iw, eff, alloc_mask, s, lat_override,
                        flush_acct, order, starts, sizes, group_pages,
                        page_group, glast_l)
                    if s2 > s:         # stall tail accounted op s scalar
                        acct = s2
                    s = s2
                else:
                    flush_acct(s)
                    s, replanned = self._boundary_event(
                        pages_l, iw, eff, alloc_mask, s, lat_override,
                        order, starts, sizes, group_pages, page_group,
                        glast_l)
                    acct = s           # the boundary op accounted scalar
                if replanned:
                    cum_alloc = np.cumsum(alloc_mask)
                    total_a = int(cum_alloc[-1])
                    alloc_pos = np.flatnonzero(alloc_mask)
                    apages_all = pages[alloc_pos]
                    aw_all = iw[alloc_pos]
                    costs_all = lut[eff]
        flush_acct(n)
        out_lats[:n] = costs_all
        for idx, lat in lat_override:  # scalar-accounted boundary ops
            out_lats[idx] = lat

    # below this op count a fused scalar replay beats the fixed cost of the
    # ~20 numpy kernels the vectorized segment pays (boundary-to-boundary
    # slivers of a few ops are common under extreme pressure; threshold
    # picked empirically on the pressure_speedup trace)
    _SMALL_SEGMENT = 12

    def _run_segment(self, pages_l, eff, alloc_mask, alloc_pos, apages_all,
                     aw_all, step_base, s, m):
        """Execute one bulk segment [s, s+m) whose allocations are known to
        fit: identical free-stack pops, page-table maps, staging rows and
        §5.2 flags as the scalar op sequence, with one gather/scatter per
        metadata column for the whole segment.  Accounting (Stats, the
        step counter, per-op latencies) is deferred to the caller's
        batch-level flush — bitwise the same totals.

        The segment's alloc set comes as contiguous slices of the hoisted
        batch arrays (two ``searchsorted`` probes, no re-scan); for pools
        pinned at ``max_pages`` (the pressure regime) the commit is fully
        fused — writes and fills land with a single state scatter each
        plus the row appends.  Growable pools replay the scalar growth
        triggers inside ``alloc_batch``."""
        if m <= self._SMALL_SEGMENT:
            return self._run_segment_small(pages_l, eff, alloc_mask,
                                           step_base, s, m)
        e = s + m
        lo = int(np.searchsorted(alloc_pos, s))
        hi = int(np.searchsorted(alloc_pos, e))
        if lo == hi:
            return
        k = hi - lo
        apages = apages_all[lo:hi]
        wmask = aw_all[lo:hi]
        asteps = alloc_pos[lo:hi] + (step_base + 1)
        pool = self.pool
        if pool.size >= pool.max_pages and pool._free_top >= k \
                and self.data_plane is None:
            # fused commit: pop the run off the free stack, scatter every
            # column once (fills go straight to RECLAIMABLE — clean slots),
            # map, stage the writes, queue the fills
            top = pool._free_top - k
            sl = pool._free_arr[top:pool._free_top][::-1].copy()
            pool._free_top = top
            if wmask.all():
                pool.state[sl] = _IN_USE
                fills = False
            else:
                fmask = ~wmask
                pool.state[sl] = np.where(wmask, np.int8(_IN_USE),
                                          np.int8(_RECLAIMABLE))
                fsl = sl[fmask]
                pool.reclaim_flag[fsl] = True
                fills = True
            pool.owner[sl] = apages
            pool.last_step[sl] = asteps
            if pool.size == pool.capacity:
                pool._used += k
            else:
                pool._used += int(np.count_nonzero(sl < pool.size))
            pool.n_alloc_from_pool += k
            # the batch-start snapshot gather already grew the page table
            # over every page in this batch, so the local map is one scatter
            self.gpt.map_local_known(apages, sl)
            if fills:
                wpg = apages[wmask]
                if wpg.size:
                    self.pipeline.stage_rows(wpg, sl[wmask])
                self.pipeline.reclaimable.push_rows(apages[fmask], fsl)
            else:
                self.pipeline.stage_rows(apages, sl)
            return
        slots = np.asarray(
            pool.alloc_batch(apages.tolist(), asteps.tolist(),
                             allow_deficit=True), np.int64)
        self.gpt.map_local_known(apages, slots)
        if wmask.all():
            self.pipeline.stage_rows(apages, slots)
        else:
            wsel = np.flatnonzero(wmask)
            if wsel.size:
                self.pipeline.stage_rows(apages[wsel], slots[wsel])
            # filled slots are clean (a remote copy exists): immediately
            # reclaimable, no send needed — and fresh, so the §5.2
            # deferral gather is skipped
            fsel = np.flatnonzero(~wmask)
            self.pipeline.fill_rows(apages[fsel], slots[fsel])
        if self.data_plane is not None:
            lw_batch = getattr(self.data_plane, "local_write_batch", None)
            if lw_batch is not None:
                # one gather/scatter for the whole alloc run (fills and
                # write allocs alike) instead of one update per page
                lw_batch(apages.tolist(), slots.tolist())
            else:
                for pg, slt in zip(apages.tolist(), slots.tolist()):
                    self.data_plane.local_write(pg, slt)

    def _run_segment_small(self, pages_l, eff, alloc_mask, step_base, s, m):
        """Scalar replay of a tiny segment (a couple of ops between
        adjacent boundaries): the same alloc/stage/fill transitions in op
        order without the fixed cost of the fused path's kernels.
        Accounting is deferred like the vectorized path.

        For a pool that cannot grow (the pressure regime), allocation,
        local mapping, staging and fill bookkeeping fuse into one loop of
        per-slot column writes; growable pools keep the batched sub-calls
        (their growth triggers live inside ``alloc_batch``)."""
        e = s + m
        eff_l = eff[s:e].tolist()
        am_l = alloc_mask[s:e].tolist()
        base = step_base + s
        pool = self.pool

        if pool.size >= pool.max_pages and self.data_plane is None:
            pipeline = self.pipeline
            free_arr = pool._free_arr
            state = pool.state
            owner = pool.owner
            last = pool.last_step
            uflag = pool.update_flag
            rflag = pool.reclaim_flag
            size = pool.size
            used = pool._used
            n_alloc = 0
            l_slot = self.gpt._l_slot
            stq = pipeline.staging
            rq = pipeline.reclaimable
            seq = pipeline._seq
            for kk in range(m):
                if am_l[kk]:
                    c = eff_l[kk]
                    pg = pages_l[s + kk]
                    top = pool._free_top - 1
                    pool._free_top = top
                    slot = int(free_arr[top])
                    owner[slot] = pg
                    last[slot] = base + kk + 1
                    if slot < size:
                        used += 1
                    n_alloc += 1
                    l_slot[pg] = slot
                    if c == 4:
                        state[slot] = _IN_USE
                        pipeline._ensure_page(pg)
                        pend = pipeline._pend
                        prev = pend[pg]
                        if prev >= 0:
                            uflag[prev] = True
                        pend[pg] = slot
                        stq.push_row(seq, pg, slot)
                        seq += 1
                    else:
                        # cache fill: clean slot, immediately reclaimable
                        state[slot] = _RECLAIMABLE
                        rflag[slot] = True
                        rq.push_row(pg, slot)
            pool._used = used
            pool.n_alloc_from_pool += n_alloc
            pipeline._seq = seq
            return
        apages: List[int] = []
        asteps: List[int] = []
        awrite: List[bool] = []
        for kk in range(m):
            if am_l[kk]:
                apages.append(pages_l[s + kk])
                asteps.append(base + kk + 1)
                awrite.append(eff_l[kk] == 4)
        if apages:
            slots = pool.alloc_batch(apages, asteps, allow_deficit=True)
            assert slots is not None
            self.gpt.map_local_batch(np.asarray(apages, np.int64),
                                     np.asarray(slots, np.int64))
            if all(awrite):
                self.pipeline.stage_rows(apages, slots)
            else:
                wpg: List[int] = []
                wsl: List[int] = []
                fpg: List[int] = []
                fsl: List[int] = []
                for pg, slt, w in zip(apages, slots, awrite):
                    if w:
                        wpg.append(pg)
                        wsl.append(slt)
                    else:
                        fpg.append(pg)
                        fsl.append(slt)
                if wpg:
                    self.pipeline.stage_rows(wpg, wsl)
                self.pipeline.complete_fill_batch(fpg, fsl)
            if self.data_plane is not None:
                lw_batch = getattr(self.data_plane, "local_write_batch",
                                   None)
                if lw_batch is not None:
                    lw_batch(apages, slots)
                else:
                    for pg, slt in zip(apages, slots):
                        self.data_plane.local_write(pg, slt)

    def _boundary_event(self, pages_l, iw, eff, alloc_mask, m, lat_override,
                        order, starts, sizes, group_pages, page_group,
                        glast_l) -> Tuple[int, bool]:
        """Inline boundary event at batch position ``m``: run the one op
        that would overrun pool/staging through the exact scalar schedule
        (reclaim sizes, flush-stall accounting, rng draws), then re-plan
        ONLY the ops invalidated by it via one targeted gather.

        Invalidated means: pages whose local mappings the event's reclaims
        dropped, plus the op's own page when the op FAILED (a host spill or
        an unfilled read).  A successful boundary write/fill lands its page
        LOCAL, which is exactly what the plan already encodes for the ops
        behind it, so the common case re-plans nothing at all — the
        ``page_group``/``glast_l`` probes keep only invalidated pages that
        are in this batch AND still have ops behind the boundary (under
        pressure that is almost always nobody: reclaim victims are old
        flushed pages, rarely re-read within the same batch).  Returns
        ``(m + 1, whether any group was re-planned)``."""
        pg = pages_l[m]
        self._unmap_log = unmapped = []
        if iw[m]:
            lat, ok = self._boundary_write(pg)
        else:
            lat, ok = self._boundary_fill_read(pg, int(eff[m]))
        lat_override.append((m, lat))
        self._unmap_log = None
        replanned = self._replan_after_boundary(
            unmapped, None if ok else pg, m, False, iw, eff, alloc_mask,
            order, starts, sizes, group_pages, page_group, glast_l)
        return m + 1, replanned

    def _boundary_inline(self, pages_l, iw, eff, alloc_mask, m, lat_override,
                         flush_acct, order, starts, sizes, group_pages,
                         page_group, glast_l) -> Tuple[int, bool]:
        """Pool-overrun boundary for pools pinned at ``max_pages``: replay
        the scalar schedule's side effects — the failed alloc probe (whose
        only effect is the ``n_alloc_failed`` counter: ``maybe_grow`` is
        provably futile at max and short-circuits) and the
        ``_reclaim(pages_per_block)`` burst — then ABSORB the overrunning
        op into the next segment instead of replaying it scalar.  The
        scalar retry would pop exactly the slot the next segment's bulk
        alloc pops first, so the op's transitions vectorize with its
        successors: same free-stack order, same staging row and seq, same
        step/latency accounting sequence.  When the reclaim frees nothing
        the op must stall (write: synchronous flush) or stay unfilled
        (read) — those rare paths replay the remaining scalar schedule via
        the stall tails.  Returns ``(next index, replanned)``; next index
        is ``m`` itself when the op was absorbed."""
        pool = self.pool
        pool.n_alloc_failed += 1       # the alloc attempt on an empty list
        self._unmap_log = unmapped = []
        self._reclaim(max(1, self.pages_per_block))
        absorbed = pool._free_top > 0
        ok = True
        if not absorbed:
            # the stall tail reads live Stats/step: settle the deferred
            # accounting through op m first
            flush_acct(m)
            if iw[m]:
                lat, ok = self._boundary_write_stall(pages_l[m])
            else:
                lat, ok = self._boundary_fill_miss(int(eff[m]))
            lat_override.append((m, lat))
        self._unmap_log = None
        replanned = self._replan_after_boundary(
            unmapped, None if ok else pages_l[m], m, absorbed, iw,
            eff, alloc_mask, order, starts, sizes, group_pages, page_group,
            glast_l)
        return (m if absorbed else m + 1), replanned

    def _replan_after_boundary(self, unmapped, fail_pg, m, include_m, iw,
                               eff, alloc_mask, order, starts, sizes,
                               group_pages, page_group, glast_l) -> bool:
        """Re-plan ONLY the pages a boundary event invalidated: pages whose
        local mappings its reclaims dropped, plus the op's own page when
        the op FAILED (a host spill or an unfilled read).  A successful
        boundary write/fill lands its page LOCAL — exactly what the plan
        already encodes for the ops behind it — so the common case re-plans
        nothing; the ``page_group``/``glast_l`` probes keep only pages that
        are in this batch AND still have ops behind the boundary.

        ``include_m`` is True for absorbed boundaries: op ``m`` has NOT
        executed yet (it runs as the next segment's first op), so it is
        part of the remaining window — an absorbed boundary write whose
        page's OLD slot the reclaim just unmapped must stay the group's
        first remaining op, keeping the reads behind it LOCAL."""
        groups = set()
        for arr in unmapped:            # lists of plain ints (see _reclaim)
            for p in arr:
                g = page_group.get(p)
                if g is not None and glast_l[g] > m:
                    groups.add(g)
        if fail_pg is not None:
            g = page_group.get(fail_pg)
            if g is not None and glast_l[g] > m:
                groups.add(g)
        if not groups:
            return False
        side = "left" if include_m else "right"
        todo = []
        for g in sorted(groups):
            ops = order[starts[g]: starts[g] + sizes[g]]
            lo = int(np.searchsorted(ops, m, side=side))
            if lo < ops.size:
                todo.append((int(group_pages[g]), ops[lo:]))
        if not todo:
            return False
        if len(todo) <= 4:
            # a boundary invalidates a handful of pages at most: per-page
            # scalar resolution beats the vector gather's fixed cost
            cls_new = [self._classify_scalar(p) for p, _ in todo]
        else:
            cls_new = self._snapshot_classes(
                np.fromiter((t[0] for t in todo), np.int64, len(todo)),
                known=True).tolist()
        local_c = np.int8(self._CLS_LOCAL)
        for (_, K), c in zip(todo, cls_new):
            iwK = iw[K]
            effK = np.where(iwK, np.int8(4), local_c)
            allocK = iwK.copy()
            if c != self._CLS_LOCAL:
                # reads before the first remaining write see class ``c``; a
                # fillable class cache-fills on the FIRST such read (its
                # later duplicates go LOCAL), COLD never fills
                nw = np.flatnonzero(iwK)
                stop = int(nw[0]) if nw.size else K.size
                rd = np.flatnonzero(~iwK[:stop])
                if rd.size:
                    if c == self._CLS_COLD:
                        effK[rd] = np.int8(c)
                    else:
                        effK[rd[0]] = np.int8(c)
                        allocK[rd[0]] = True
            eff[K] = effK
            alloc_mask[K] = allocK
        return True

    def _boundary_write(self, pg: int) -> Tuple[float, bool]:
        """The scalar ``write`` schedule for one boundary op, inlined:
        staged-write attempt, pointer-move reclaim, synchronous flush stall,
        host spill — byte-for-byte the reference sequence.  Returns
        ``(latency, staged ok)``."""
        self.step += 1
        st = self.stats
        st.writes += 1
        lat = 0.0
        ws = self.pipeline.write((pg,), self.step)
        if ws is None:
            # pool exhausted: reclaim from reclaimable queue (pointer move)
            self._reclaim(max(1, self.pages_per_block))
            ws = self.pipeline.write((pg,), self.step)
        if ws is None:
            # still nothing reclaimable: must flush synchronously (stall)
            lat += self._flush(self.pages_per_block, in_critical_path=True)
            self._reclaim(self.pages_per_block)
            ws = self.pipeline.write((pg,), self.step)
        if ws is not None:
            self.gpt.map_local(pg, ws.slots[0])
            if self.data_plane is not None:
                self.data_plane.local_write(pg, ws.slots[0])
            lat += self.costs.local_write
        else:
            lat += self.costs.cold_write           # total pressure: spill
            self._host_add(pg)
        st.time_us += lat
        st.ops += 1
        return lat, ws is not None

    def _boundary_fill_read(self, pg: int, cls_m: int) -> Tuple[float, bool]:
        """The scalar ``read`` schedule for one boundary fill-read, inlined.
        Boundary reads are remote/host hits by construction (only
        cache-filling reads allocate), so the hit class comes from the
        plan instead of a fresh table lookup; the cache-fill replays the
        scalar alloc/reclaim sequence exactly.  Returns
        ``(latency, filled ok)``."""
        self.step += 1
        st = self.stats
        if cls_m == self._CLS_REMOTE:
            st.remote_hits += 1
        else:
            st.host_hits += 1
        lat = float(self._cost_lut()[cls_m])
        # _cache_fill, inlined (the filled slot is clean: a remote copy
        # exists, so it is immediately reclaimable without a send)
        slot = self.pool.alloc(pg, self.step)
        if slot is None:
            self._reclaim(max(self.pages_per_block, 1))
            slot = self.pool.alloc(pg, self.step)
        if slot is not None:
            self.gpt.map_local(pg, slot)
            if self.data_plane is not None:
                self.data_plane.local_write(pg, slot)
            self.pool.mark_reclaimable(slot)
            self.pipeline.reclaimable.push_row(pg, slot)
        st.time_us += lat
        st.ops += 1
        return lat, slot is not None

    def _boundary_write_stall(self, pg: int) -> Tuple[float, bool]:
        """Scalar tail of an absorbed-boundary write whose reclaim freed
        nothing: the post-reclaim alloc probe fails too, then the
        synchronous flush stall + reclaim + final attempt — byte-for-byte
        the reference sequence from that point.  (The scalar ``write``
        bumps the step before its alloc attempts; nothing before the flush
        reads it, so bumping here is equivalent.)"""
        self.pool.n_alloc_failed += 1  # the post-reclaim retry found nothing
        self.step += 1
        st = self.stats
        st.writes += 1
        lat = self._flush(self.pages_per_block, in_critical_path=True)
        self._reclaim(self.pages_per_block)
        ws = self.pipeline.write((pg,), self.step)
        if ws is not None:
            self.gpt.map_local(pg, ws.slots[0])
            if self.data_plane is not None:
                self.data_plane.local_write(pg, ws.slots[0])
            lat += self.costs.local_write
        else:
            lat += self.costs.cold_write           # total pressure: spill
            self._host_add(pg)
        st.time_us += lat
        st.ops += 1
        return lat, ws is not None

    def _boundary_fill_miss(self, cls_m: int) -> Tuple[float, bool]:
        """Scalar tail of an absorbed-boundary fill-read whose reclaim
        freed nothing: the retry alloc fails as well, the page stays
        unfilled, and the hit class from the plan is accounted exactly as
        the scalar read would."""
        self.pool.n_alloc_failed += 1  # the post-reclaim retry found nothing
        self.step += 1
        st = self.stats
        if cls_m == self._CLS_REMOTE:
            st.remote_hits += 1
        else:
            st.host_hits += 1
        lat = float(self._cost_lut()[cls_m])
        st.time_us += lat
        st.ops += 1
        return lat, False

    def _read_run_writethrough(self, pages: np.ndarray) -> np.ndarray:
        """All-reads run for pool-less policies: reads never mutate state
        (no pool to cache-fill), so one snapshot classification is exact for
        the whole run, duplicates included."""
        cls = self._snapshot_classes(pages)
        st = self.stats
        counts4 = np.bincount(cls, minlength=4)
        st.local_hits += int(counts4[0])
        st.remote_hits += int(counts4[1])
        st.host_hits += int(counts4[2])
        st.cold_hits += int(counts4[3])
        lats = self._cost_lut()[cls]
        st.time_us = self._accumulate_time(st.time_us, lats)
        st.ops += pages.size
        self.step += pages.size
        return lats

    def _write_run_writethrough(self, pages: np.ndarray) -> np.ndarray:
        """All-writes run for pool-less policies: bulk placement (pre-drawn
        p2c pairs) + one page-table scatter, with per-op latencies and
        Stats bitwise identical to the scalar ``write`` loop."""
        pages_l = pages.tolist()
        tiers, peers_out, slots_out, reps_out, costs = \
            self._place_pages_bulk(pages_l, flush=False)
        self.gpt.map_remote_batch(pages_l, tiers, peers_out, slots_out,
                                  reps_out)
        n = pages.size
        st = self.stats
        st.writes += n
        st.ops += n
        self.step += n
        return np.asarray(costs, np.float64)

    def _cache_fill(self, page: int):
        """Read miss fills the local mempool (it is a cache for remote data,
        §3.2/§3.3; LRU replacement via the reclaimable queue).  The filled
        slot is clean — a remote copy exists — so it is immediately
        reclaimable without a send."""
        if not self.policy.use_local_pool:
            return
        slot = self.pool.alloc(page, self.step)
        if slot is None:
            self._reclaim(max(self.pages_per_block, 1))
            slot = self.pool.alloc(page, self.step)
        if slot is None:
            return
        self.gpt.map_local(page, slot)
        if self.data_plane is not None:
            self.data_plane.local_write(page, slot)
        self.pool.mark_reclaimable(slot)
        self.pipeline.reclaimable.push_row(page, slot)

    # -- background machinery ----------------------------------------------------

    def _reclaim(self, n: int) -> int:
        """Reclaim pool slots; drop local mappings that pointed at them.

        Batched path: one inlined queue drain (``reclaim_bulk``) and one
        gather/scatter drops every stale local mapping — a page freed twice
        in one burst matches at most one of its slots, exactly like the
        sequential check-then-unmap.

        When a plan-once boundary event is active (``_unmap_log`` installed)
        every page whose local mapping is dropped is recorded, so the batch
        engine re-classifies exactly the invalidated pages afterwards."""
        if self.batch_reclaim:
            slots, pages = self.pipeline.reclaim_bulk(n)
            k = int(slots.size)
            if k:
                # a page freed twice in one burst matches at most one of its
                # slots, exactly like the sequential check-then-unmap (freed
                # pages were mapped once, so the growth check is skipped)
                mask = self.gpt.local_slots_known(pages) == slots
                live = pages[mask]
                if live.size:
                    if self.device is not None:
                        # demoted-but-resident: the bytes stay in the FREE
                        # slot until someone allocates it, so remember
                        # (slot, gen) for a zero-copy repoint on re-access
                        lsl = slots[mask]
                        self.device.demote(live.tolist(), lsl.tolist(),
                                           self.pool.gen[lsl].tolist())
                    self.gpt._l_slot[live] = -1
                    if self._unmap_log is not None:
                        self._unmap_log.append(live.tolist())
            return k
        freed = self.pipeline.reclaim(n)
        dropped = [] if self._unmap_log is not None else None
        for slot, pg in freed:
            if self.gpt.local_slot(pg) == slot:
                if self.device is not None:
                    self.device.demote((pg,), (slot,),
                                       (int(self.pool.gen[slot]),))
                self.gpt.unmap_local(pg)
                if dropped is not None:
                    dropped.append(pg)
        if dropped:
            self._unmap_log.append(dropped)
        return len(freed)

    def _reclaim_held(self, n: int, epoch: int, finish_us: float) -> int:
        """Daemon-side reclaim (async engine): identical slot transitions
        and local-mapping drops to the batched ``_reclaim``, except the
        freed slots enter an epoch-tagged pool hold — the foreground cannot
        allocate them until an epoch boundary (or a fence) commits them."""
        slots, pages = self.pipeline.reclaim_bulk_held(n, epoch, finish_us)
        k = int(slots.size)
        if k:
            live = pages[self.gpt.local_slots_known(pages) == slots]
            if live.size:
                self.gpt._l_slot[live] = -1
        return k

    def _flush(self, n: int, in_critical_path: bool = False) -> float:
        """Remote Sender Thread: send staged write-sets to peers.

        Dispatches to the vectorized single-pass placement
        (``_flush_batched``) unless ``batch_reclaim`` is off, in which case
        the scalar per-write-set reference runs — both reach bitwise
        identical state."""
        if self.batch_reclaim:
            return self._flush_batched(n, in_critical_path)
        return self._flush_scalar(n, in_critical_path)

    def _flush_batched(self, n: int, in_critical_path: bool = False) -> float:
        """One bulk placement pass over the whole flush batch: the staged
        rows pop as three column arrays (no WriteSet objects), placement
        runs with pre-drawn p2c pairs, the pool/queue bookkeeping is the
        vectorized ``complete_flush_rows`` and one ``map_remote_batch``
        scatter lands the batch.  Held or multi-page entries (migration
        parks; the generic ``write()`` API) fall back to the WriteSet
        walk — bitwise the same state either way."""
        rows = self.pipeline.take_flush_rows(n)
        if rows is None:
            return self._flush_batched_ws(n, in_critical_path)
        _seqs, parr, sarr = rows
        if not parr.size:
            return 0.0
        pages = parr.tolist()
        tiers, peers_out, slots_out, reps_out, costs = \
            self._place_pages_bulk(pages, flush=True)
        self.pipeline.complete_flush_rows(parr, sarr)
        self.gpt.map_remote_batch(pages, tiers, peers_out, slots_out,
                                  reps_out)
        cost = self._accumulate_time(0.0, np.asarray(costs, np.float64))
        if in_critical_path:
            self.stats.write_stall_us += cost
        # lazy send: cost stays off the critical path (stats untouched) but
        # is returned so the async daemon can charge it to its own clock
        return cost

    def _flush_batched_ws(self, n: int,
                          in_critical_path: bool = False) -> float:
        """WriteSet-walk fallback of ``_flush_batched`` (held/multi-page
        staging entries)."""
        batch = self.pipeline.take_flush_batch(n)
        if not batch:
            return 0.0
        pages = [pg for ws in batch for pg in ws.pages]
        tiers, peers_out, slots_out, reps_out, costs = \
            self._place_pages_bulk(pages, flush=True)
        self.pipeline.complete_flush(batch)
        if pages:
            self.gpt.map_remote_batch(pages, tiers, peers_out, slots_out,
                                      reps_out)
        cost = self._accumulate_time(0.0, np.asarray(costs, np.float64))
        if in_critical_path:
            self.stats.write_stall_us += cost
        return cost                     # lazy: returned for daemon charging

    def _flush_scalar(self, n: int, in_critical_path: bool = False) -> float:
        """Scalar flush reference (per-write-set loop; parity-tested against
        ``_flush_batched``).

        Page-table updates for the whole flush batch are buffered and
        applied with one ``map_remote_batch`` scatter at the end (nothing
        reads the table mid-flush, and last-writer-wins matches sequential
        ``map_remote`` for pages flushed twice in one batch)."""
        cost = 0.0
        mp: List[int] = []
        mt: List[int] = []
        mpe: List[int] = []
        ms: List[int] = []
        mreps: List[Tuple] = []
        peer_tier = int(Tier.PEER)
        host_tier = int(Tier.HOST)

        def send(ws):
            nonlocal cost
            for pg in ws.pages:
                placed = self._place_remote_raw(pg)
                if placed is None:
                    self._host_add(pg)
                    mp.append(pg)
                    mt.append(host_tier)
                    mpe.append(-1)
                    ms.append(-1)
                    mreps.append(())
                    cost += self.costs.host_write
                else:
                    peer, slot, reps = placed
                    mp.append(pg)
                    mt.append(peer_tier)
                    mpe.append(peer)
                    ms.append(slot)
                    mreps.append(reps)
                    cost += self.costs.remote_write

        self.pipeline.flush(n, send)
        if mp:
            self.gpt.map_remote_batch(mp, mt, mpe, ms, mreps)
        if in_critical_path:
            self.stats.write_stall_us += cost
        return cost                     # lazy: returned for daemon charging

    def _report_repair_backlog(self) -> None:
        """Keep the coordinator's degraded-admission signal in sync with
        the repair queue: a non-empty backlog is reported (lease grants
        shed to floor), and the drain-to-empty transition fires
        ``clear_degraded`` exactly once so growth resumes.  Shared by the
        sync tick and the async daemon slice."""
        if self._lease is None:
            return
        if self.repairq:
            note = getattr(self.coordinator, "note_degraded", None)
            if note is not None:
                note(self._lease.cid, len(self.repairq))
                self._degraded_reported = True
        elif self._degraded_reported:
            clear = getattr(self.coordinator, "clear_degraded", None)
            if clear is not None:
                clear(self._lease.cid)
            self._degraded_reported = False

    def background_tick(self, flush_batch: Optional[int] = None):
        """One async maintenance tick: lazy send + pool sizing."""
        if flush_batch is None:
            flush_batch = self.config.flush_batch
        if self.orchestrator is not None:
            # async mode: the daemon owns flush/reclaim scheduling — a tick
            # is just an extra epoch boundary with a raised budget
            self.orchestrator.tick(flush_batch)
            return
        if self.policy.lazy_send:
            self._flush(flush_batch)
        if self.repairq:
            # background re-replication repair, off the critical path
            self._drain_repairs(self.config.repair_rate)
        self._report_repair_backlog()
        if self.policy.dynamic_pool:
            self.pool.shrink_for_pressure()
            # admission throttle while degraded: don't grow the local pool
            # until the repair backlog drains (repairs need peer headroom)
            if not self.repairq:
                self.pool.maybe_grow()
        # reclaim only when pool is tight (use-pool-first otherwise)
        if self.pool.free_count() == 0:
            self._reclaim(flush_batch)

    def drain(self):
        """Flush everything (end of run / checkpoint barrier)."""
        if self.orchestrator is not None:
            self.orchestrator.quiesce()
        while len(self.pipeline.staging):
            self._flush(1 << 12)

    # -- remote pressure: eviction or migration -----------------------------------

    def _peer_block_footprint(self, peer: int) -> int:
        """Victim-candidate MR blocks this container holds on ``peer`` —
        one masked count over the dense per-peer membership columns (live,
        non-replica blocks; replicas only move or die with their primary).
        The coordinator's peer-pressure fan-out uses this to route pressure
        to the containers that actually occupy the pressured peer."""
        if peer < 0 or peer >= len(self.peers):
            return 0
        hi = self._next_block_slot[peer]
        return int(np.count_nonzero(self._blk_live[peer][:hi]
                                    & ~self._blk_replica[peer][:hi]))

    def peer_pressure(self, peer: int, blocks_to_free: int) -> int:
        """A peer's native applications claimed memory; free MR blocks.

        Victim candidates come from one masked ``flatnonzero`` over the
        dense per-peer block-membership columns (slots are allocated
        monotonically and never reused, so ascending slot order equals the
        old dict-scan insertion order).  Replica blocks are skipped as
        victims — they only move or die with their primary (victimizing one
        independently would dangle the primary's replica list and the
        page-table replica tuples)."""
        hi = self._next_block_slot[peer]
        cand_slots = np.flatnonzero(self._blk_live[peer][:hi]
                                    & ~self._blk_replica[peer][:hi])
        if not cand_slots.size:
            return 0
        cand_ids = peer * (1 << 20) + cand_slots    # dense, already ordered
        blk = 1 << 20

        if self.policy.evict_action == "migrate":
            migs = self.migrator.handle_pressure(
                peer, blocks_to_free,
                block_pages=lambda bid: list(
                    self.blocks.get((bid // blk, bid % blk), [])),
                candidate_blocks=cand_ids, step=self.step,
                batched=self.batch_reclaim)
            done = 0
            for mig in migs:
                if mig.phase.name == "DONE":
                    # migrate_block already freed src + repointed pages
                    self._open_block.pop(peer, None)
                    done += 1
                    self.stats.migrations += 1
            return done

        # delete-style eviction (Infiniswap/nbdX): pages fall to backup/cold
        if self.policy.victim == "random":
            victims = select_victims_random(self.rng, cand_ids, blocks_to_free)
        else:
            victims = cand_ids[:blocks_to_free]
        if self.batch_reclaim:
            return self._evict_delete_batched(victims, peer)
        for bid in victims:
            bid = int(bid)
            key = (bid // blk, bid % blk)
            for pg in self.blocks.get(key, []):
                if self.gpt.remote_location(pg) and \
                        self.gpt.remote_location(pg).peer == peer:
                    tier = Tier.COLD if self.policy.cold_backup else Tier.NONE
                    if self.gpt.repoint_replica(pg):
                        pass
                    else:
                        self.gpt.map_remote(pg, Location(tier))
            self._free_block(*key, free_replicas=True)
            self._open_block.pop(peer, None)
            self.stats.evictions += 1
        return len(victims)

    def _evict_delete_batched(self, victims, peer: int) -> int:
        """Delete-style eviction in bulk: one gather classifies every victim
        page, non-replicated pages drop to backup/cold with one
        ``map_remote_batch`` scatter.  Replicated pages (rare on the
        delete-policy baselines, which run replication=0) keep the scalar
        per-occurrence walk — a promoted replica may land back on the
        pressured peer and must be re-checked in order."""
        tier = Tier.COLD if self.policy.cold_backup else Tier.NONE
        blk = 1 << 20
        pages: List[int] = []
        victims = [int(b) for b in victims]
        for bid in victims:
            pages.extend(self.blocks.get((bid // blk, bid % blk), []))
        if pages:
            if self.gpt.has_replicas():
                for pg in pages:
                    if self.gpt.remote_location(pg) and \
                            self.gpt.remote_location(pg).peer == peer:
                        if not self.gpt.repoint_replica(pg):
                            self.gpt.map_remote(pg, Location(tier))
            else:
                parr = np.asarray(pages, np.int64)
                _t, r_peer, _s, mapped = self.gpt.remote_raw_batch(parr)
                hit = parr[mapped & (r_peer == peer)]
                if hit.size:
                    # duplicates are idempotent here (same scatter value)
                    m = hit.size
                    self.gpt.map_remote_batch(hit, [int(tier)] * m,
                                              [-1] * m, [-1] * m, None)
        for bid in victims:
            self._free_block(bid // blk, bid % blk, free_replicas=True)
            self._open_block.pop(peer, None)
            self.stats.evictions += 1
        return len(victims)

    # -- fault handling (core/faults.py; paper §5.1/§5.3, Table 3) -----------------

    def _peer_alive(self, peer: int) -> bool:
        return not bool(self._peer_failed[peer])

    def _suspect_penalty(self) -> float:
        """Price one access against a SUSPECT peer: the op retries
        ``retry_limit`` times with exponential backoff before succeeding
        (the peer is slow, not gone)."""
        self.stats.retries += self.config.retry_limit
        self.stats.retry_wait_us += self._retry_penalty_us
        return self._retry_penalty_us

    def _poll_health(self) -> None:
        """Lazy health poll (runs only while a peer is SUSPECT/REJOINING):
        escalate timed-out suspects to DOWN, activate rejoined peers that
        survived to the next access (REJOINING -> UP)."""
        now = self.stats.time_us
        for p in self.health.expired_suspects(now):
            self.fail_peer(p)
        for p in self.health.rejoining_peers():
            self.health.activate(p, now)
        self._any_suspect = bool(self._peer_suspect.any())
        self._health_dirty = self.health.any_transient()

    def mark_suspect(self, peer: int) -> bool:
        """Transient fault observed (UP -> SUSPECT): every access to the
        peer now pays the retry/backoff ladder and no new block lands
        there, but its data stays readable — latency degrades before
        durability (the paper's replication-first ordering).  Escalates to
        DOWN through ``fail_peer`` once ``suspect_timeout_us`` of simulated
        time passes without a ``clear_suspect``."""
        if self.peers[peer].failed:
            return False
        if not self.health.suspect(peer, now=self.stats.time_us):
            return False
        self._peer_suspect[peer] = True
        self._any_suspect = True
        self._health_dirty = True
        return True

    def clear_suspect(self, peer: int) -> bool:
        """The blip healed (SUSPECT -> UP): penalties stop, placement
        resumes."""
        if not self.health.recover(peer, now=self.stats.time_us):
            return False
        self._peer_suspect[peer] = False
        self._any_suspect = bool(self._peer_suspect.any())
        self._health_dirty = self.health.any_transient()
        return True

    def fail_peer(self, peer: int) -> Tuple[int, int]:
        """Hard peer failure (-> DOWN): the batched recovery sweep.

        Every page on the peer is repointed to its first *live* replica
        (bulk ``map_remote_batch``) or dropped to cold/NONE per the
        Table-3 mode; stale replica tuples referencing the dead peer are
        purged from surviving pages; every MR block the peer held is
        released (its capacity died with it — used returns to 0, and a
        later ``rejoin_peer`` starts empty); and each block left degraded
        — a surviving primary that lost a replica, or a promoted
        ex-replica now holding the only copy — enters the repair queue for
        background re-replication.  Returns ``(recovered, lost)`` page
        counts, bitwise identical between the scalar and batched sweeps."""
        p = self.peers[peer]
        if p.failed:
            return 0, 0
        p.failed = True
        self._peer_failed[peer] = True
        if self._ramp_left[peer] > 0:
            # a crash mid-warm-up ends the ramp (the peer starts over on
            # its next rejoin)
            self._ramp_left[peer] = 0
            self._any_ramp = bool(self._ramp_left.any())
        self.health.down(peer, now=self.stats.time_us)
        if self._peer_suspect[peer]:
            self._peer_suspect[peer] = False
            self._any_suspect = bool(self._peer_suspect.any())
        self._health_dirty = self.health.any_transient()
        cold = (lambda pg: None) if self.policy.cold_backup else None
        sweep = fail_peer_batched if self.batch_reclaim else fail_peer
        recovered, lost = sweep(self.gpt, peer, cold_fetch=cold,
                                peer_alive=self._peer_alive)
        # no surviving page may still carry a replica on the dead peer
        self.gpt.purge_replicas_on_peer(peer)
        # release every MR block the peer held, collecting the blocks the
        # failure degraded: surviving primaries that lost a replica here,
        # and promoted ex-replicas (now sole copies) the free cascade kept
        # because pages still resolve to them
        repair: List[Tuple[int, int]] = []
        hi = self._next_block_slot[peer]
        for s in np.flatnonzero(self._blk_live[peer][:hi]).tolist():
            key = (peer, int(s))
            prim = self._replica_of.get(key)
            reps = tuple(self.block_replicas.get(key, ()))
            self._free_block(peer, int(s), free_replicas=True)
            if prim is not None and prim in self.blocks:
                repair.append(prim)
            for r in reps:
                if r in self.blocks:
                    repair.append(r)
        self._open_block.pop(peer, None)
        p.connected = False            # a rejoin must reconnect
        if self.policy.replication > 0:
            for key in repair:
                self.repairq.push(key)
        return recovered, lost

    def rejoin_peer(self, peer: int) -> bool:
        """A crashed peer came back (DOWN -> REJOINING): its capacity
        returns empty (the crash lost its contents) and placement may use
        it immediately — queued repairs re-replicate onto it on the next
        drain.  The next health poll activates it (REJOINING -> UP)."""
        p = self.peers[peer]
        if not p.failed:
            return False
        if not self.health.rejoin(peer, now=self.stats.time_us):
            return False
        p.failed = False
        self._peer_failed[peer] = False
        self._health_dirty = True
        k = self.config.rejoin_ramp_grants
        if k > 0:
            # warm-up bias: the rejoined peer re-enters placement at a
            # discounted weight, ramping to full over its first k grants
            self._ramp_left[peer] = k
            self._any_ramp = True
        return True

    def _drain_repairs(self, max_pages: int) -> int:
        """Drain the re-replication repair queue (off the critical path).

        Pops degraded primary blocks and places fresh replica blocks via
        the ``ReplicaPlacer`` (DOWN/SUSPECT peers and peers already
        holding a copy excluded) until each is back at
        ``policy.replication`` copies or ``max_pages`` pages were copied
        this round.  A block that cannot be fully repaired — no live peer
        has room — is re-queued and, when nothing at all is placeable,
        the round stops instead of spinning: graceful degradation (the
        store keeps serving from the remaining copies with host/cold
        spill) until a rejoin or eviction changes the topology.  Returns
        pages copied; their cost accrues to ``stats.repair_us``, never
        ``time_us``."""
        R = self.policy.replication
        q = self.repairq
        if R <= 0 or not q:
            return 0
        st = self.stats
        copied = 0
        blocked: List[Tuple[int, int]] = []
        page_cost = self.costs.remote_read + self.costs.remote_write
        susp = self._peer_suspect
        while q and copied < max_pages:
            key = q.pop()
            # the block may have died (eviction / migration / failure) or
            # become a replica itself since it was queued
            if key not in self.blocks or key in self._replica_of \
                    or self.peers[key[0]].failed:
                continue
            reps = tuple(self.block_replicas.get(key, ()))
            deficit = R - len(reps)
            if deficit <= 0:
                q.n_repaired += 1
                continue
            free = [0 if susp[j] else self._ramp_free(j, pr.free())
                    for j, pr in enumerate(self.peers)]
            progressed = False
            for rp in self.placer.place(key[0], free, deficit,
                                        exclude=[r[0] for r in reps]):
                rslot = self._alloc_block_slot(rp)
                if rslot is None:
                    break
                blist = list(self.blocks[key])
                self.blocks[(rp, rslot)] = blist
                self._replica_of[(rp, rslot)] = key
                self._blk_replica[rp][rslot] = True
                reps = reps + ((rp, rslot),)
                self.block_replicas[key] = reps
                self.gpt.add_replica_batch(blist, key, (rp, rslot))
                copied += len(blist)
                st.repair_pages += len(blist)
                st.repair_us += len(blist) * page_cost
                progressed = True
            if len(reps) < R:
                blocked.append(key)
                if not progressed:
                    break
            else:
                q.n_repaired += 1
        for key in blocked:
            q.requeue(key)
        return copied

    def repair_quiesce(self, max_rounds: int = 1 << 10) -> int:
        """Drain the repair queue to empty (or to a stuck under-provisioned
        state: no live peer has room).  Test/benchmark barrier — production
        drains ride the background ticks and the async daemon.  Returns
        pages copied."""
        total = 0
        for _ in range(max_rounds):
            if not self.repairq:
                break
            n = self._drain_repairs(self.config.repair_rate)
            total += n
            if n == 0:
                break
        return total

    # -- local pool pressure (container imbalance, §3.4) ---------------------------

    def local_pressure(self, reclaim_pages: int):
        """Host free memory dropped: shrink pool, reclaiming LRU pages."""
        self._flush(reclaim_pages)
        n = self._reclaim(reclaim_pages)
        self.pool.shrink_for_pressure()
        return n

    def host_donate(self, n_pages: int) -> int:
        """Coordinator-requested donation (§3.4 weighted-fair reclamation).

        The pool can only shed its *tail* slots (the effective size is a
        prefix of the slot array), so donation targets them directly: flush
        everything staged (slots can't leave while they hold the only copy),
        then reclaim the RECLAIMABLE slots inside the shrink window
        out-of-FIFO-order with one masked gather/scatter
        (``ValetMempool.reclaim_window``) — §5.2 safety comes from the slot
        state, not the queue order; their stale queue entries are skipped
        later by the (slot, page) match guard.  Returns pages actually
        donated — fewer than asked when live (IN_USE) data pins the tail."""
        pool = self.pool
        target = max(pool.size - n_pages, pool.min_pages)
        if target >= pool.size:
            return 0
        if self.policy.lazy_send:
            self._flush(len(self.pipeline.staging))
        slots, pgs = self.pipeline.reclaim_window(target, pool.size)
        if pgs.size:
            stale = pgs[self.gpt.local_slots_batch(pgs) == slots]
            if stale.size:
                self.gpt.unmap_local_batch(stale)
        return pool.shrink_by(n_pages)
