"""TieredPageStore — the full Valet orchestration over HBM / peer / host /
cold tiers (paper §3 + §4 wired together).

This is the control-plane state machine used by BOTH:

* the **trace simulator** (benchmarks/): drives it with synthetic page-access
  traces (YCSB ETC/SYS analogues) and accumulates simulated microseconds from
  a ``CostModel`` — this reproduces Table 1 / Figures 8, 10, 19-23;
* the **serving engine** (serve/): drives it with real decode steps, where
  the data plane is jnp arrays (``device_ops``) and the cost counters are
  informational.

Policy knobs (``policies.py``) select between Valet and the baseline systems
(Infiniswap / nbdX / OS-swap) without changing the workload code.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.activity import (ActivityTracker, select_victims_mass,
                                 select_victims_nad, select_victims_random,
                                 power_of_two_choices)
from repro.core.migration import MigrationEngine
from repro.core.page_table import GlobalPageTable, Location, Tier
from repro.core.policies import CostModel, Policy
from repro.core.pool import SlotState, ValetMempool
from repro.core.queues import WritePipeline, WriteSet
from repro.core.replication import ReplicaPlacer, fail_peer


@dataclass
class PeerState:
    """A remote memory donor (receiver module)."""
    capacity: int
    used: int = 0
    connected: bool = False
    mapped_blocks: int = 0
    failed: bool = False

    def free(self) -> int:
        return 0 if self.failed else self.capacity - self.used


@dataclass
class Stats:
    time_us: float = 0.0
    ops: int = 0
    local_hits: int = 0
    remote_hits: int = 0
    host_hits: int = 0
    cold_hits: int = 0
    writes: int = 0
    write_stall_us: float = 0.0
    evictions: int = 0
    migrations: int = 0
    connects: int = 0
    maps: int = 0

    def hit_ratio(self) -> Dict[str, float]:
        n = max(self.local_hits + self.remote_hits + self.host_hits
                + self.cold_hits, 1)
        return {
            "local": self.local_hits / n,
            "remote": self.remote_hits / n,
            "host": self.host_hits / n,
            "cold": self.cold_hits / n,
        }


class TieredPageStore:
    """Valet (or baseline) orchestration of one sender node's pages."""

    def __init__(self, policy: Policy, costs: CostModel, *,
                 pool_capacity: int = 1024,
                 min_pool: int = 64,
                 max_pool: Optional[int] = None,
                 n_peers: int = 4,
                 peer_capacity_blocks: int = 1024,
                 pages_per_block: int = 16,
                 host_capacity: int = 1 << 30,
                 free_memory_fn: Optional[Callable[[], int]] = None,
                 seed: int = 0,
                 data_plane=None):
        self.policy = policy
        self.costs = costs
        self.pages_per_block = pages_per_block
        self.rng = np.random.default_rng(seed)
        self.stats = Stats()
        self.step = 0
        self.data_plane = data_plane

        max_pool = max_pool or pool_capacity
        if not policy.dynamic_pool:
            min_pool = max_pool
        self.pool = ValetMempool(pool_capacity, min_pages=min_pool,
                                 max_pages=max_pool,
                                 free_memory_fn=free_memory_fn)
        self.pipeline = WritePipeline(self.pool, queue_len=1 << 16)
        self.gpt = GlobalPageTable()
        self.peers = [PeerState(capacity=peer_capacity_blocks)
                      for _ in range(n_peers)]
        # remote blocks: (peer, block_slot) -> list of logical pages
        self.blocks: Dict[Tuple[int, int], List[int]] = {}
        self.block_replicas: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        self._next_block_slot = [0] * n_peers
        self._open_block: Dict[int, Tuple[int, int]] = {}   # peer -> block key
        self.tracker = ActivityTracker(n_peers * peer_capacity_blocks * 2)
        self.placer = ReplicaPlacer(self.rng)
        self.host_pages: Dict[int, bool] = {}
        self.host_capacity = host_capacity
        # the engine sees encoded block ids (peer<<20|slot); decode for the
        # slot-level data/metadata callbacks
        dec = lambda bid: bid % (1 << 20)
        self.migrator = MigrationEngine(
            self.gpt, self.tracker,
            free_counts_fn=lambda: [p.free() for p in self.peers],
            copy_fn=lambda sp, sb, dp_, ds: self._copy_block(sp, dec(sb), dp_, ds),
            alloc_fn=self._alloc_block_slot,
            free_fn=lambda p, b: self._free_block(p, dec(b)),
            park_fn=self._park_pages,
            rng=self.rng)

    # -- block-id helpers ------------------------------------------------------

    def _block_id(self, peer: int, slot: int) -> int:
        return peer * (1 << 20) + slot

    def _alloc_block_slot(self, peer: int) -> Optional[int]:
        p = self.peers[peer]
        if p.failed or p.free() <= 0:
            return None
        slot = self._next_block_slot[peer]
        self._next_block_slot[peer] += 1
        p.used += 1
        p.mapped_blocks += 1
        self.blocks[(peer, slot)] = []
        if not p.connected:
            p.connected = True
            self.stats.connects += 1
            self.stats.time_us += 0.0 if self.policy.use_local_pool \
                else self.costs.connect
        self.stats.maps += 1
        if not self.policy.use_local_pool:
            self.stats.time_us += self.costs.map_block
        return slot

    def _free_block(self, peer: int, slot: int):
        self.peers[peer].used -= 1
        self.blocks.pop((peer, slot), None)

    def _copy_block(self, src_peer, src_slot, dst_peer, dst_slot):
        pages = self.blocks.get((src_peer, src_slot), [])
        self.blocks[(dst_peer, dst_slot)] = list(pages)
        self.tracker.on_write([self._block_id(dst_peer, dst_slot)], self.step)
        # migration copy cost lands on peers, NOT the sender critical path
        if self.data_plane is not None:
            self.data_plane.copy_block(src_peer, src_slot, dst_peer, dst_slot)

    def _park_pages(self, pages, hold: bool):
        self.pipeline.staging.hold_pages(pages, hold)

    # -- placement -------------------------------------------------------------

    def _place_remote(self, page: int) -> Optional[Location]:
        """Append the page to an open MR block (p2c peer choice per block)."""
        if not self.policy.use_remote:
            return None
        free = [p.free() for p in self.peers]
        peer = power_of_two_choices(free, self.rng)
        if peer is None or free[peer] <= 0:
            return None
        blk = self._open_block.get(peer)
        if blk is None or len(self.blocks.get(blk, [])) >= self.pages_per_block:
            slot = self._alloc_block_slot(peer)
            if slot is None:
                return None
            blk = (peer, slot)
            self._open_block[peer] = blk
            # replicas are allocated at BLOCK granularity alongside the primary
            reps = []
            if self.policy.replication > 0:
                for rp in self.placer.place(peer, free,
                                            self.policy.replication):
                    rslot = self._alloc_block_slot(rp)
                    if rslot is not None:
                        reps.append((rp, rslot))
            self.block_replicas[blk] = reps
        self.blocks[blk].append(page)
        self.tracker.on_write([self._block_id(*blk)], self.step)
        for rp, rs in self.block_replicas.get(blk, []):
            self.blocks[(rp, rs)].append(page)
        return Location(Tier.PEER, peer=blk[0], slot=blk[1],
                        replicas=tuple(self.block_replicas.get(blk, ())))

    # -- the two critical-path operations ---------------------------------------

    def write(self, page: int) -> float:
        """Write (page-out) one page.  Returns critical-path latency (us)."""
        self.step += 1
        self.stats.writes += 1
        lat = 0.0

        if self.policy.use_local_pool:
            ws = self.pipeline.write((page,), self.step)
            if ws is None:
                # pool exhausted: reclaim from reclaimable queue (pointer move)
                self._reclaim(max(1, self.pages_per_block))
                ws = self.pipeline.write((page,), self.step)
            if ws is None:
                # still nothing reclaimable: must flush synchronously (stall)
                lat += self._flush(self.pages_per_block, in_critical_path=True)
                self._reclaim(self.pages_per_block)
                ws = self.pipeline.write((page,), self.step)
            if ws is not None:
                self.gpt.map_local(page, ws.slots[0])
                if self.data_plane is not None:
                    self.data_plane.local_write(page, ws.slots[0])
                lat += self.costs.local_write
            else:
                lat += self.costs.cold_write       # total pressure: spill cold
                self.host_pages[page] = True
        else:
            # write-through systems: remote send in the critical path
            loc = self._place_remote(page)
            if loc is not None:
                self.gpt.map_remote(page, loc)
                lat += self.costs.remote_write
                if self.policy.receiver_side_cpu:
                    lat += self.costs.receiver_cpu
                if self.policy.cold_backup:
                    pass                           # async disk backup
            else:
                self.gpt.map_remote(page, Location(Tier.COLD))
                lat += self.costs.cold_write
        self.stats.time_us += lat
        self.stats.ops += 1
        return lat

    def read(self, page: int) -> float:
        """Read (page-in) one page.  Returns critical-path latency (us)."""
        self.step += 1
        lat = 0.0
        loc = self.gpt.lookup(page)
        if loc.tier == Tier.LOCAL:
            self.stats.local_hits += 1
            lat = self.costs.local_read
        elif loc.tier == Tier.PEER and not self.peers[loc.peer].failed:
            self.stats.remote_hits += 1
            lat = self.costs.remote_read
            if self.policy.receiver_side_cpu:
                lat += self.costs.receiver_cpu
            self._cache_fill(page)
        elif loc.tier == Tier.HOST or page in self.host_pages:
            self.stats.host_hits += 1
            lat = self.costs.host_read
            self._cache_fill(page)
        else:
            self.stats.cold_hits += 1
            lat = self.costs.cold_read
        self.stats.time_us += lat
        self.stats.ops += 1
        return lat

    def _cache_fill(self, page: int):
        """Read miss fills the local mempool (it is a cache for remote data,
        §3.2/§3.3; LRU replacement via the reclaimable queue).  The filled
        slot is clean — a remote copy exists — so it is immediately
        reclaimable without a send."""
        if not self.policy.use_local_pool:
            return
        slot = self.pool.alloc(page, self.step)
        if slot is None:
            self._reclaim(max(self.pages_per_block, 1))
            slot = self.pool.alloc(page, self.step)
        if slot is None:
            return
        self.gpt.map_local(page, slot)
        if self.data_plane is not None:
            self.data_plane.local_write(page, slot)
        ws = WriteSet(-1, (page,), (slot,))
        self.pool.mark_reclaimable(slot)
        self.pipeline.reclaimable.push(ws)

    # -- background machinery ----------------------------------------------------

    def _reclaim(self, n: int) -> int:
        """Reclaim pool slots; drop local mappings that pointed at them."""
        freed = self.pipeline.reclaim(n)
        for slot, pg in freed:
            if self.gpt.local_slot(pg) == slot:
                self.gpt.unmap_local(pg)
        return len(freed)

    def _flush(self, n: int, in_critical_path: bool = False) -> float:
        """Remote Sender Thread: send staged write-sets to peers."""
        cost = 0.0

        def send(ws):
            nonlocal cost
            for pg in ws.pages:
                loc = self._place_remote(pg)
                if loc is None:
                    self.host_pages[pg] = True
                    self.gpt.map_remote(pg, Location(Tier.HOST))
                    cost += self.costs.host_write
                else:
                    self.gpt.map_remote(pg, loc)
                    cost += self.costs.remote_write

        self.pipeline.flush(n, send)
        if in_critical_path:
            self.stats.write_stall_us += cost
            return cost
        return 0.0                      # lazy send: off the critical path

    def background_tick(self, flush_batch: int = 64):
        """One async maintenance tick: lazy send + pool sizing."""
        if self.policy.lazy_send:
            self._flush(flush_batch)
        if self.policy.dynamic_pool:
            self.pool.shrink_for_pressure()
            self.pool.maybe_grow()
        # reclaim only when pool is tight (use-pool-first otherwise)
        if self.pool.free_count() == 0:
            self._reclaim(flush_batch)

    def drain(self):
        """Flush everything (end of run / checkpoint barrier)."""
        while len(self.pipeline.staging):
            self._flush(1 << 12)

    # -- remote pressure: eviction or migration -----------------------------------

    def peer_pressure(self, peer: int, blocks_to_free: int) -> int:
        """A peer's native applications claimed memory; free MR blocks."""
        keys = [k for k in self.blocks if k[0] == peer]
        if not keys:
            return 0
        cand_ids = [self._block_id(*k) for k in keys]
        id_to_key = dict(zip(cand_ids, keys))

        if self.policy.evict_action == "migrate":
            migs = self.migrator.handle_pressure(
                peer, blocks_to_free,
                block_pages=lambda bid: list(
                    self.blocks.get(id_to_key[bid], [])),
                candidate_blocks=cand_ids, step=self.step)
            done = 0
            for mig in migs:
                if mig.phase.name == "DONE":
                    # migrate_block already freed src + repointed pages
                    self._open_block.pop(peer, None)
                    done += 1
                    self.stats.migrations += 1
            return done

        # delete-style eviction (Infiniswap/nbdX): pages fall to backup/cold
        if self.policy.victim == "random":
            victims = select_victims_random(self.rng, cand_ids, blocks_to_free)
        else:
            victims = cand_ids[:blocks_to_free]
        for bid in victims:
            key = id_to_key[bid]
            for pg in self.blocks.get(key, []):
                if self.gpt.remote_location(pg) and \
                        self.gpt.remote_location(pg).peer == peer:
                    tier = Tier.COLD if self.policy.cold_backup else Tier.NONE
                    if self.gpt.repoint_replica(pg):
                        pass
                    else:
                        self.gpt.map_remote(pg, Location(tier))
            self._free_block(*key)
            self._open_block.pop(peer, None)
            self.stats.evictions += 1
        return len(victims)

    def fail_peer(self, peer: int) -> Tuple[int, int]:
        """Hard peer failure (fault-tolerance path, Table 3)."""
        self.peers[peer].failed = True
        return fail_peer(self.gpt, peer,
                         cold_fetch=(lambda pg: None)
                         if self.policy.cold_backup else None)

    # -- local pool pressure (container imbalance, §3.4) ---------------------------

    def local_pressure(self, reclaim_pages: int):
        """Host free memory dropped: shrink pool, reclaiming LRU pages."""
        self._flush(reclaim_pages)
        n = self._reclaim(reclaim_pages)
        self.pool.shrink_for_pressure()
        return n
