"""First-class memory tiers behind one small protocol (DESIGN.md §2).

Until PR 8 the device (HBM) KV pool was a *private* resource of whoever
owned it — the serve engine spilled KV through an ad-hoc ``host_store``
dict, and the trace store forgot a page's slot the moment ``_reclaim``
dropped its local mapping.  Both lose the paper's cheapest move: a page
whose pool slot has not been reused yet is still byte-identical in device
memory, so bringing it back is a *pointer repoint* (map the page to its old
slot again), not a data transfer — the serving analogue of the paper's
pointer-move reclaim (§5.1) and the vLLM-style "restore is block-table
repointing" shape.

Two tier objects implement the protocol:

* ``DeviceTier`` — tracks *demoted-but-resident* pages: pages whose pool
  slot was released (preemption / reclaim) but whose bytes are still
  sitting untouched in the slot.  Entries are validated lazily against the
  pool's per-slot generation counter (``ValetMempool.gen``), so no
  allocation hot path pays a hook: a slot that was reused since demotion
  simply fails validation.
* ``HostTier`` — holds the host-DRAM KV blobs (one per spilled page), the
  placement target of the background flush pipeline.  It replaces the serve
  engine's ``host_store`` dict; the trace store's host tier stays the
  simulated ``host_pages`` membership (no real bytes there).

The lifecycle both owners follow::

    preempt/reclaim --demote()--> device-resident (shadow, dirty)
        background flush ------>  + host copy (clean, still repointable)
        slot reused ----------->  evicted: host copy only (stream to return)
    restore/read --claim()----->  repoint (zero copy)   [common case]
                 --stream------>  per-page host read     [slot was reused]
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.page_table import Tier


class PageTier:
    """Minimal tier protocol: named residency tracking for logical pages.

    Concrete tiers add their own movement verbs (``demote``/``claim`` for
    the device tier, ``put``/``pop`` for the host tier); the shared surface
    is what ``TieredPageStore``/``GlobalPageTable`` need to *track* pages
    across tiers: membership, count, and bulk drop.
    """

    #: the ``page_table.Tier`` value this object backs
    tier: Tier = Tier.NONE
    name: str = "none"

    def __contains__(self, page: int) -> bool:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def drop(self, pages: Iterable[int]) -> int:
        """Forget ``pages`` (freed sequences); returns entries dropped."""
        raise NotImplementedError


class DeviceTier(PageTier):
    """Demoted-but-resident pages of the device (HBM) KV pool.

    ``shadow`` maps page -> (slot, generation-at-demotion).  An entry is
    *valid* while the pool slot is still FREE with an unchanged generation
    — i.e. nobody allocated it since the demotion — which makes claiming it
    back a pure metadata move.  Validation is lazy; ``evict_slots`` exists
    for owners (the serve engine) that must copy dirty bytes out *before* a
    reused slot is overwritten.
    """

    tier = Tier.DEVICE
    name = "device"

    def __init__(self):
        self.shadow: Dict[int, Tuple[int, int]] = {}   # page -> (slot, gen)
        self._by_slot: Dict[int, int] = {}             # slot -> page
        # counters (benchmarks / tests)
        self.demotions = 0
        self.repoints = 0
        self.evictions = 0

    def __contains__(self, page: int) -> bool:
        return page in self.shadow

    def __len__(self) -> int:
        return len(self.shadow)

    def demote(self, pages: Iterable[int], slots: Iterable[int],
               gens: Iterable[int]) -> None:
        """Register pages as demoted-but-resident at their released slots."""
        shadow = self.shadow
        by_slot = self._by_slot
        n = 0
        for pg, sl, g in zip(pages, slots, gens):
            old = shadow.get(pg)
            if old is not None:
                by_slot.pop(old[0], None)
            shadow[pg] = (int(sl), int(g))
            by_slot[int(sl)] = int(pg)
            n += 1
        self.demotions += n

    def slot_of(self, page: int) -> Optional[int]:
        e = self.shadow.get(page)
        return None if e is None else e[0]

    def claim(self, page: int, gen_of) -> Optional[int]:
        """Validate + consume one entry: returns the slot if the page is
        still resident (slot FREE, generation unchanged — ``gen_of(slot)``
        returns the pool's current generation or ``None`` when the slot is
        not claimable), else ``None``.  Either way the entry is removed."""
        e = self.shadow.pop(page, None)
        if e is None:
            return None
        slot, gen = e
        self._by_slot.pop(slot, None)
        cur = gen_of(slot)
        if cur is None or cur != gen:
            self.evictions += 1
            return None
        self.repoints += 1
        return slot

    def split(self, pages: Iterable[int], gen_of
              ) -> Tuple[List[int], List[int], List[int]]:
        """Bulk ``claim``: partition ``pages`` into (repointable pages,
        their slots, missed pages).  Consumes every entry it touches."""
        rp_pages: List[int] = []
        rp_slots: List[int] = []
        missed: List[int] = []
        for pg in pages:
            slot = self.claim(pg, gen_of)
            if slot is None:
                missed.append(pg)
            else:
                rp_pages.append(pg)
                rp_slots.append(slot)
        return rp_pages, rp_slots, missed

    def evict_slots(self, slots: Iterable[int]) -> List[Tuple[int, int]]:
        """Slots were just re-allocated: pop and return the shadow
        ``(page, slot)`` pairs that lived there (the owner must secure a
        host copy of any dirty one before the new data lands)."""
        out: List[Tuple[int, int]] = []
        by_slot = self._by_slot
        if not by_slot:
            return out
        for sl in slots:
            pg = by_slot.pop(int(sl), None)
            if pg is not None:
                self.shadow.pop(pg, None)
                out.append((pg, int(sl)))
        self.evictions += len(out)
        return out

    def drop(self, pages: Iterable[int]) -> int:
        n = 0
        for pg in pages:
            e = self.shadow.pop(pg, None)
            if e is not None:
                self._by_slot.pop(e[0], None)
                n += 1
        return n


class HostTier(PageTier):
    """Host-DRAM KV blobs, one per spilled page (pinned-host analogue).

    ``blobs[page]`` holds whatever the owner spilled — the serve engine
    stores ``{layer: (k, v)}`` numpy pairs.  This is the placement target of
    the background flush: a demoted page gains a host copy here ("clean")
    without losing its device residency, so restore still repoints.
    """

    tier = Tier.HOST
    name = "host"

    def __init__(self):
        self.blobs: Dict[int, dict] = {}
        self.puts = 0

    def __contains__(self, page: int) -> bool:
        return page in self.blobs

    def __len__(self) -> int:
        return len(self.blobs)

    def put(self, page: int, blob) -> None:
        self.blobs[page] = blob
        self.puts += 1

    def pop(self, page: int):
        """Remove and return a blob (stream-in consumes the host copy)."""
        return self.blobs.pop(page)

    def get(self, page: int):
        return self.blobs.get(page)

    def drop(self, pages: Iterable[int]) -> int:
        n = 0
        for pg in pages:
            if self.blobs.pop(pg, None) is not None:
                n += 1
        return n
