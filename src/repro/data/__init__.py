from repro.data.pipeline import (DataConfig, TrainDataset, batch_for_step,
                                 TraceConfig, ETC, SYS, generate_trace)
