from repro.data.pipeline import (DataConfig, TrainDataset, batch_for_step,
                                 TraceConfig, ETC, SYS, generate_trace)
from repro.data.workloads import (WorkloadTrace, YCSBConfig, MLTraceConfig,
                                  MixedTenantConfig, YCSB_MIXES, ycsb_trace,
                                  ml_trace, mixed_tenant_traces,
                                  interleave_tenants, tenant_lifetimes)
