"""Deterministic synthetic data pipeline (tokens + access traces).

Training batches are a pure function of (seed, step, shard) so that

* restarts resume exactly (fault tolerance),
* elastic resharding is a renumbering, not a reshuffle,
* every host materializes only its shard.

The trace generators reproduce the paper's workload mixes: Facebook ETC
(95% GET / 5% SET) and SYS (75/25) over zipfian keys [21], driven through
the TieredPageStore by the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np



@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _fold(seed: int, *xs: int) -> np.random.Generator:
    return np.random.default_rng(np.uint64(seed) * np.uint64(0x9E3779B9)
                                 + sum(np.uint64(x) << (i * 16)
                                       for i, x in enumerate(xs)))


def batch_for_step(cfg: DataConfig, step: int, shard: int, n_shards: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens, labels) for one shard of one step.  Pure & deterministic.

    Synthetic LM task with learnable structure: a marker token induces a
    copy pattern, so training loss measurably decreases (integration tests
    assert this).
    """
    assert cfg.global_batch % n_shards == 0
    b = cfg.global_batch // n_shards
    rng = _fold(cfg.seed, step, shard)
    toks = rng.integers(2, cfg.vocab, size=(b, cfg.seq_len + 1),
                        dtype=np.int64)
    # plant short periodic copies: token[t] == token[t-4] on marked runs
    for i in range(b):
        start = int(rng.integers(0, max(cfg.seq_len // 2, 1)))
        length = min(cfg.seq_len - start, int(rng.integers(8, 64)))
        toks[i, start] = 1                                  # marker
        for t in range(start + 4, start + length):
            toks[i, t] = toks[i, t - 4]
    return toks[:, :-1], toks[:, 1:]


class TrainDataset:
    """Iterator over global-step batches for a fixed shard layout."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.step = start_step

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self):
        out = batch_for_step(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return out

    def reshard(self, shard: int, n_shards: int) -> "TrainDataset":
        """Elastic scaling: same stream, new shard layout, same step."""
        return TrainDataset(self.cfg, shard, n_shards, self.step)


# --------------------------------------------------------------------------
# Access traces (paper workloads)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TraceConfig:
    n_pages: int
    n_ops: int
    get_fraction: float        # ETC: 0.95, SYS: 0.75
    zipf_a: float = 1.2
    seed: int = 0


ETC = lambda pages, ops, seed=0: TraceConfig(pages, ops, 0.95, seed=seed)
SYS = lambda pages, ops, seed=0: TraceConfig(pages, ops, 0.75, seed=seed)


def generate_trace(cfg: TraceConfig):
    """Yield ("read"|"write", page) ops with zipfian key popularity."""
    rng = np.random.default_rng(cfg.seed)
    keys = np.clip(rng.zipf(cfg.zipf_a, cfg.n_ops), 1, cfg.n_pages) - 1
    # zipf rank -> random page id (so hot pages are spread out)
    perm = rng.permutation(cfg.n_pages)
    is_get = rng.random(cfg.n_ops) < cfg.get_fraction
    for k, g in zip(keys, is_get):
        yield ("read" if g else "write", int(perm[k]))
