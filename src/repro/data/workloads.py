"""Trace-driven workload suite: the paper's evaluation matrix, replayable.

The paper's headline numbers (226x over OS swap, up to 5.5x over remote
paging) were measured on NoSQL (Memcached/Redis/VoltDB-style) and ML
workloads; the synthetic uniform/zipfian traces in ``pipeline.py`` only
approximate their *mix ratios*.  This module closes the fidelity gap with
three seeded, fully deterministic workload classes (ROADMAP item 5):

* **YCSB-style key-value mixes** (``ycsb_trace``): workloads A (update
  heavy, 50/50), B (read mostly, 95/5), C (read only) and D (latest-skewed
  reads over a growing keyspace) over a zipfian keyspace, with *hotset
  rotation*: the trace is divided into phases and the zipf head is remapped
  to a different page region at every phase boundary, so a cache sized for
  one phase's hot set pays re-warming costs at each rotation — the
  Memcached/Redis steady-state-plus-churn shape the paper measures.

* **ML-training working-set trace** (``ml_trace``): layer activations
  cycling through the pool — a forward sweep *writes* each layer's
  activation pages in order, the backward sweep *reads* them in reverse
  (and frees them by overwrite on the next step).  Per-layer footprints are
  sized off the real ``repro.configs`` model zoo (relative layer widths
  from ``ArchConfig``, sequence/batch from the ``ShapeConfig`` shapes used
  by the ``train/`` stack), proportionally scaled to a bounded page budget
  so the simulator stays fast.

* **Mixed-tenant combinations** (``mixed_tenant_traces`` +
  ``interleave_tenants``): K tenants — any mix of YCSB and ML classes —
  each with its own page-id space, round-robin time-sliced so their demand
  overlaps in time on one shared host slab (driven through
  ``HostMemoryCoordinator`` by ``benchmarks/workloads.py``).

Everything is a pure function of its config (numpy ``default_rng`` seeded
per trace), so two runs produce bitwise-identical traces — required, since
the workload benchmarks gate CI on deterministic simulated-us metrics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "WorkloadTrace", "YCSBConfig", "MLTraceConfig", "MixedTenantConfig",
    "YCSB_MIXES", "ycsb_trace", "ml_trace", "mixed_tenant_traces",
    "interleave_tenants", "phase_segments",
]


@dataclass(eq=False)
class WorkloadTrace:
    """A replayable page-access trace plus its provenance metadata.

    ``pages``/``is_write`` are parallel arrays ready for
    ``TieredPageStore.access_batch``; ``n_pages`` is the page-id space (for
    pool sizing and pre-population); ``phase_bounds`` marks the op indices
    where a new phase begins (hotset rotation for YCSB, sweep boundaries
    for ML) — index 0 is always implied, not listed.
    """
    name: str
    pages: np.ndarray
    is_write: np.ndarray
    n_pages: int
    phase_bounds: Tuple[int, ...] = ()

    def __post_init__(self):
        self.pages = np.ascontiguousarray(self.pages, np.int64)
        self.is_write = np.ascontiguousarray(self.is_write, bool)
        assert len(self.pages) == len(self.is_write)

    def __len__(self) -> int:
        return len(self.pages)

    def read_fraction(self) -> float:
        n = max(len(self), 1)
        return float((~self.is_write).sum()) / n


# --------------------------------------------------------------------------
# YCSB-style key-value mixes
# --------------------------------------------------------------------------

# read fraction per YCSB core workload; the write op is an update-in-place
# for A/B (C is read-only) and an *insert* (new key) for D, whose reads are
# latest-skewed instead of rotation-phased.
YCSB_MIXES = {
    "A": {"read": 0.50, "update": 0.50},
    "B": {"read": 0.95, "update": 0.05},
    "C": {"read": 1.00, "update": 0.00},
    "D": {"read": 0.95, "insert": 0.05},
}


@dataclass(frozen=True)
class YCSBConfig:
    """One YCSB-style trace: mix letter + keyspace + rotation schedule."""
    workload: str = "B"            # "A" | "B" | "C" | "D"
    n_pages: int = 2048            # keyspace (one page per key)
    n_ops: int = 24_000
    zipf_a: float = 1.2            # key-popularity skew
    n_phases: int = 4              # hotset rotations (A/B/C; D drifts)
    seed: int = 0

    def __post_init__(self):
        if self.workload not in YCSB_MIXES:
            raise ValueError(f"unknown YCSB workload {self.workload!r}; "
                             f"available: {sorted(YCSB_MIXES)}")


def _zipf_ranks(rng, a: float, n_ops: int, n_keys: int) -> np.ndarray:
    """Zipf ranks in [0, n_keys) — rank 0 is the hottest key."""
    return np.clip(rng.zipf(a, n_ops), 1, n_keys) - 1


def ycsb_trace(cfg: YCSBConfig) -> WorkloadTrace:
    """Deterministic YCSB-style trace per ``cfg`` (see module docstring)."""
    if cfg.workload == "D":
        return _ycsb_latest(cfg)
    rng = np.random.default_rng(cfg.seed)
    mix = YCSB_MIXES[cfg.workload]
    ranks = _zipf_ranks(rng, cfg.zipf_a, cfg.n_ops, cfg.n_pages)
    is_write = rng.random(cfg.n_ops) >= mix["read"]
    # one shared rank->page permutation spreads hot keys across the id
    # space; each phase then rotates the mapping by a fixed offset so the
    # zipf head lands on a disjoint page region (the hot set *moves*, the
    # popularity law does not)
    perm = rng.permutation(cfg.n_pages).astype(np.int64)
    n_phases = max(cfg.n_phases, 1)
    bounds = [cfg.n_ops * p // n_phases for p in range(1, n_phases)]
    phase_of = np.searchsorted(np.asarray(bounds), np.arange(cfg.n_ops),
                               side="right")
    rot = cfg.n_pages // n_phases
    pages = perm[(ranks + phase_of * rot) % cfg.n_pages]
    return WorkloadTrace(f"ycsb_{cfg.workload.lower()}", pages, is_write,
                         cfg.n_pages, tuple(bounds))


def _ycsb_latest(cfg: YCSBConfig) -> WorkloadTrace:
    """Workload D: inserts append fresh keys, reads skew to the latest.

    The live keyspace starts at ``n_pages // 2`` keys and grows with each
    insert; read popularity is zipfian over *recency* (rank 0 = the newest
    key), so the hot set drifts forward continuously — the rotation is
    built into the workload instead of scheduled.  Key ids wrap at
    ``n_pages`` (the oldest, coldest keys are overwritten), keeping the
    page-id space bounded for the simulator.
    """
    rng = np.random.default_rng(cfg.seed)
    ins_frac = YCSB_MIXES["D"]["insert"]
    n_init = cfg.n_pages // 2
    is_ins = rng.random(cfg.n_ops) < ins_frac
    cum = np.cumsum(is_ins)                      # inserts up to and incl. op
    newest = n_init - 1 + cum                    # newest key id after op i
    prev_newest = newest - is_ins                # newest existing before op
    ranks = _zipf_ranks(rng, cfg.zipf_a, cfg.n_ops, cfg.n_pages)
    live = np.minimum(prev_newest + 1, cfg.n_pages)
    pages = np.where(is_ins, newest,
                     prev_newest - ranks % live) % cfg.n_pages
    return WorkloadTrace("ycsb_d", pages, is_ins, cfg.n_pages)


# --------------------------------------------------------------------------
# ML-training working-set trace
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MLTraceConfig:
    """Activation-cycling trace sized off the ``repro.configs`` model zoo.

    ``arch``/``shape`` name a real ``ArchConfig``/``ShapeConfig``; per-layer
    activation footprints keep the zoo's *relative* widths (attention
    residual stream + the layer's active FFN width) but are proportionally
    scaled so the whole working set is ``total_pages`` — big enough to
    oversubscribe a pool, small enough to replay in milliseconds.
    """
    arch: str = "granite-3-8b"
    shape: str = "train_4k"
    n_steps: int = 3               # fwd+bwd sweeps
    total_pages: int = 2048        # working-set budget (all layers)
    seed: int = 0


def _layer_weights(arch) -> np.ndarray:
    """Relative activation footprint per layer from the arch config.

    Residual stream (d_model) plus a quarter of the *active* FFN width:
    full ``d_ff`` for dense layers, ``top_k * d_expert`` (or d_ff) for MoE
    layers, the SSD state width for SSM layers.  The absolute scale is
    normalized away by ``total_pages``; only the per-layer ratios matter.
    """
    w = []
    for layer in range(arch.n_layers):
        ffn = arch.d_ff
        if arch.moe is not None and layer >= arch.n_dense_layers:
            d_exp = arch.moe.d_expert or arch.d_ff
            ffn = (arch.moe.top_k + arch.moe.n_shared) * d_exp
        elif arch.n_dense_layers and layer < arch.n_dense_layers:
            ffn = arch.dense_d_ff or arch.d_ff
        if arch.ssm is not None and arch.n_heads == 0:
            ffn = arch.ssm.expand * arch.d_model
        w.append(arch.d_model + ffn // 4)
    return np.asarray(w, np.float64)


def ml_trace(cfg: MLTraceConfig) -> WorkloadTrace:
    """Forward-write / backward-read sweeps over per-layer activation pages.

    Each training step writes layer 0..L-1's activation pages in order
    (forward), then reads L-1..0's in reverse (backward).  Early layers'
    activations are the *oldest* data when the pool fills mid-forward —
    exactly the paper's ML scenario where they spill remote and the
    backward sweep pays the remote-read tail.  ``phase_bounds`` marks every
    sweep boundary (2 per step).
    """
    from repro.configs import ARCHS, SHAPES
    arch = ARCHS[cfg.arch]
    _ = SHAPES[cfg.shape]          # validated; sizing is relative (see doc)
    w = _layer_weights(arch)
    pages_per_layer = np.maximum(
        np.rint(w * cfg.total_pages / w.sum()).astype(np.int64), 1)
    layer_base = np.concatenate(([0], np.cumsum(pages_per_layer)[:-1]))
    n_pages = int(pages_per_layer.sum())

    rng = np.random.default_rng(cfg.seed)
    fwd_chunks, bwd_chunks = [], []
    for layer in range(arch.n_layers):
        ids = layer_base[layer] + np.arange(pages_per_layer[layer],
                                            dtype=np.int64)
        # activation pages are produced in compute order but consumed with
        # a seeded within-layer shuffle (recompute boundaries, attention
        # blocks) — the same shuffle every run
        fwd_chunks.append(ids)
        bwd_chunks.append(rng.permutation(ids))
    fwd = np.concatenate(fwd_chunks)
    bwd = np.concatenate(bwd_chunks[::-1])

    pages, is_write, bounds, pos = [], [], [], 0
    for _step in range(cfg.n_steps):
        for sweep, writes in ((fwd, True), (bwd, False)):
            if pos:
                bounds.append(pos)
            pages.append(sweep)
            is_write.append(np.full(len(sweep), writes))
            pos += len(sweep)
    return WorkloadTrace(f"ml_{cfg.arch}", np.concatenate(pages),
                         np.concatenate(is_write), n_pages, tuple(bounds))


# --------------------------------------------------------------------------
# Mixed-tenant combinations
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MixedTenantConfig:
    """K tenants (any mix of YCSB/ML traces) time-sliced over one slab.

    Demand is *phase-staggered* (the §3.4 skew scenario, and what a shared
    host actually sees): there is one global phase per tenant, and tenant t
    is hot exactly in phase t — a KV tenant replays its full YCSB trace
    there and only a small keyspace-head trickle elsewhere (diurnal load);
    an ML tenant runs its fwd/bwd sweeps there and is silent elsewhere (the
    training job starts and finishes).  Pooled memory wins when the cold
    tenants' idle share can follow the hot tenant around; static
    partitioning pays the hot tenant's overflow in every phase.
    """
    kv: Tuple[YCSBConfig, ...] = (
        YCSBConfig("B", n_pages=1024, n_ops=18_000, seed=11),
        YCSBConfig("A", n_pages=1024, n_ops=18_000, seed=12))
    ml: Tuple[MLTraceConfig, ...] = (MLTraceConfig(seed=13),)
    idle_ops: int = 800            # KV trickle ops per cold phase
    idle_pages: int = 96           # trickle working set (keyspace head)
    slice_ops: int = 128           # round-robin time slice
    # tenant churn (ROADMAP item 5 follow-up): extra KV tenants that join
    # mid-run and leave again — live only for a bounded phase window
    # around their own hot phase (register/deregister against the
    # coordinator at the window edges).  Empty by default, which keeps the
    # emitted traces bitwise identical to the churn-free suite.
    churn_kv: Tuple[YCSBConfig, ...] = ()
    churn_linger_phases: int = 1   # live phases before/after the hot phase


def tenant_lifetimes(cfg: MixedTenantConfig) -> List[Tuple[int, int]]:
    """Per-tenant live-phase windows ``[join, leave)``.

    Base tenants (``kv`` + ``ml``) live for the whole run.  Churn tenants
    join ``churn_linger_phases`` before their hot phase and leave the same
    margin after it — a driver registers the tenant's container with the
    coordinator at ``join`` and deregisters it at ``leave``."""
    n_base = len(cfg.kv) + len(cfg.ml)
    n_tenants = n_base + len(cfg.churn_kv)
    linger = max(int(cfg.churn_linger_phases), 0)
    out = [(0, n_tenants)] * n_base
    for t in range(n_base, n_tenants):
        out.append((max(t - linger, 0), min(t + linger + 1, n_tenants)))
    return out


def mixed_tenant_traces(cfg: MixedTenantConfig) -> List[WorkloadTrace]:
    """Per-tenant phased traces (KV tenants first, then ML, then churn KV).

    Each tenant's trace has exactly ``n_tenants`` phase segments (its
    ``phase_bounds`` mark the cuts; segments may be empty) aligned with the
    global schedule: segment p is what the tenant does while tenant p is
    hot.  Page-id spaces are per-tenant — the *slab* is shared, the
    keyspaces are not.  Use ``phase_segments`` to slice a trace back into
    its per-phase (start, end) ranges.

    Churn tenants behave like KV tenants inside their ``tenant_lifetimes``
    window (full trace in their hot phase, keyspace-head trickle in the
    linger phases) and emit *empty* segments outside it — op conservation
    over the interleaved schedule therefore holds with or without churn.
    """
    n_base = len(cfg.kv) + len(cfg.ml)
    n_tenants = n_base + len(cfg.churn_kv)
    hot: List[WorkloadTrace] = ([ycsb_trace(c) for c in cfg.kv]
                                + [ml_trace(c) for c in cfg.ml]
                                + [ycsb_trace(c) for c in cfg.churn_kv])
    lifetimes = tenant_lifetimes(cfg)
    out: List[WorkloadTrace] = []
    for t, trace in enumerate(hot):
        is_kv = t < len(cfg.kv) or t >= n_base
        if t < len(cfg.kv):
            seed = cfg.kv[t].seed
        elif t < n_base:
            seed = cfg.ml[t - len(cfg.kv)].seed
        else:
            seed = cfg.churn_kv[t - n_base].seed
        join, leave = lifetimes[t]
        pages_parts, write_parts, bounds, pos = [], [], [], 0
        for ph in range(n_tenants):
            if ph:
                bounds.append(pos)
            if ph == t:
                pages_parts.append(trace.pages)
                write_parts.append(trace.is_write)
                pos += len(trace)
            elif is_kv and cfg.idle_ops > 0 and join <= ph < leave:
                rng = np.random.default_rng((seed + 1) * 1000 + ph)
                idle_span = min(cfg.idle_pages, trace.n_pages)
                pages_parts.append(rng.integers(0, idle_span, cfg.idle_ops,
                                                dtype=np.int64))
                write_parts.append(rng.random(cfg.idle_ops) >= 0.95)
                pos += cfg.idle_ops
            # ML tenants are silent outside their phase, churn tenants
            # outside their lifetime: empty segment
        out.append(WorkloadTrace(
            trace.name, np.concatenate(pages_parts),
            np.concatenate(write_parts), trace.n_pages, tuple(bounds)))
    return out


def phase_segments(trace: WorkloadTrace) -> List[Tuple[int, int]]:
    """(start, end) op ranges of a trace's phase segments, in order."""
    cuts = [0, *trace.phase_bounds, len(trace)]
    return list(zip(cuts[:-1], cuts[1:]))


def interleave_tenants(lengths: Sequence[int], slice_ops: int
                       ) -> List[Tuple[int, int, int]]:
    """Round-robin schedule over per-tenant trace lengths.

    Returns ``(tenant, start, end)`` slices; concatenating a tenant's
    slices reproduces its trace exactly (op conservation — unit-tested),
    while interleaving makes demand overlap in time the way a shared host
    actually sees it.  Tenants that run out simply drop from the rotation.
    """
    if slice_ops < 1:
        raise ValueError("slice_ops must be >= 1")
    cursors = [0] * len(lengths)
    out: List[Tuple[int, int, int]] = []
    live = True
    while live:
        live = False
        for t, n in enumerate(lengths):
            i = cursors[t]
            if i >= n:
                continue
            live = True
            end = min(i + slice_ops, n)
            out.append((t, i, end))
            cursors[t] = end
    return out
