"""Pallas TPU kernels for the framework's compute hot spots.

flash_attention — train/prefill attention (causal, sliding-window, GQA)
paged_attention — decode over the Valet page pool (GPT lookup fused)
ssd_scan        — Mamba-2 SSD chunk scan (state carried in VMEM scratch)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd layout-handling
wrapper in ``ops.py``; tests sweep shapes/dtypes in interpret mode.
"""
from repro.kernels.ops import (flash_attention_op, paged_attention_op,
                               ssd_scan_op)
