"""Flash attention TPU kernel (train / prefill path).

``pl.pallas_call`` with explicit VMEM ``BlockSpec`` tiling:

* grid = (batch*q_heads, Sq/bq, Sk/bk); the KV dimension is the innermost,
  sequential grid axis so the online-softmax state (m, l, acc) lives in VMEM
  scratch across KV steps.
* GQA is folded into the index maps: the KV block index maps query-head
  ``bh`` to its KV head ``bh // group`` — no KV duplication in HBM.
* Causal/sliding-window blocks that are fully masked are skipped with
  ``pl.when`` (no MXU work), and the mask is applied with broadcasted iotas
  for partially-masked diagonal blocks.

Block sizes default to (128, 128): MXU-aligned (128x128 systolic array) and
a VMEM working set of ~bq*D + 2*bk*D + bq*bk floats — far under the ~16 MiB
VMEM budget for D <= 256.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal, window, bq, bk, nk, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # skip blocks that are fully masked (strictly above the causal diagonal
    # or entirely left of the sliding window)
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                 # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q: (BH, Sq, D) query-head-major; k, v: (BKV, Sk, D).

    BH = batch * q_heads, BKV = batch * kv_heads; q head ``i`` reads KV head
    ``i // (BH // BKV)`` within its batch entry (caller lays out heads
    contiguously per batch element).
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh % bkv == 0
    group = bh // bkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, nk=nk, scale=scale)
    grid = (bh, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b // group, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
