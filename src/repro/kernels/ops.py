"""Jit'd model-facing wrappers around the Pallas kernels.

Each op takes the model layout, dispatches to the Pallas kernel (TPU) or the
jnp reference (CPU / dry-run), and hides the layout shuffling.  ``impl`` is
``"pallas"`` (compiled), ``"interpret"`` (Pallas in Python — CPU-correct), or
``"reference"`` (pure jnp oracle).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.ssd_scan import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention_op(q, k, v, *, causal=True, window=0, impl="reference",
                       block_q=128, block_k=128):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D)."""
    if impl == "reference":
        return ref_lib.flash_attention_ref(q, k, v, causal=causal,
                                           window=window)
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    qk = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    out = _flash(qk, kk, vk, causal=causal, window=window, block_q=block_q,
                 block_k=block_k, interpret=(impl == "interpret"))
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_attention_op(q, k_pool, v_pool, block_table, lengths, *,
                       impl="reference"):
    """q: (B, Hq, D) one token/seq; pools: (slots, page, Hkv, D)."""
    if impl == "reference":
        return ref_lib.paged_attention_ref(q, k_pool, v_pool, block_table,
                                           lengths)
    return _paged(q, k_pool, v_pool, block_table, lengths,
                  interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan_op(x, dt, A, B_mat, C_mat, *, chunk=256, impl="reference"):
    """SSD core scan; see ``repro.models.ssm`` for the full mixer."""
    if impl == "reference":
        return ref_lib.ssd_scan_ref(x, dt, A, B_mat, C_mat, chunk)
    return _ssd(x, dt, A, B_mat, C_mat, chunk,
                interpret=(impl == "interpret"))
