"""Paged decode attention TPU kernel — the Valet data plane hot spot.

One query token attends over KV pages scattered through the device page
pool.  The Global Page Table (block table) rides in SMEM via scalar
prefetch (``PrefetchScalarGridSpec``) and drives the HBM->VMEM page DMA per
grid step — i.e. the paper's GPT lookup + one-sided page read are fused into
the attention kernel, so no gathered KV copy is ever materialized in HBM.

This is also where the paper's "small block I/O, large RDMA message"
flexibility (§3.3) shows up on TPU: the *logical* page (tokens) is small for
allocator granularity, while the *physical* DMA per grid step is a full
page x head tile — large, aligned, WQE-cache-miss-free in TPU terms (few,
big DMA descriptors).

Layout:
  q:            (B, Hkv, G, D)   one token per sequence, grouped heads
  k/v pool:     (n_slots, page, Hkv, D)
  block_table:  (B, P) int32 pool slot per logical page (-1 pad)
  lengths:      (B,)   valid token count per sequence
Grid: (B, Hkv, P) with the page axis innermost/sequential; softmax state in
VMEM scratch.

Zero-restore contract (PR 8): because the kernel reads KV *through* the
block table, restoring a preempted sequence needs no bulk KV copy — the
serve engine repoints block-table entries at pool slots whose bytes
survived preemption untouched (validated by the pool's per-slot generation
counter), and only pages whose slot was reused in the meantime are streamed
back one at a time via ``device_ops.stream_page`` before the next decode
step.  The kernel itself is unchanged either way: any (B, P) table whose
live entries index valid pool pages is a correct input.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_table, lengths, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page, n_pages, scale):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    slot = block_table[b, pi]
    length = lengths[b]

    @pl.when(slot >= 0)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # token validity within the page (ragged tail)
        pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q, k_pool, v_pool, block_table, lengths, *,
                    interpret=False):
    """q: (B, Hq, D); pools: (n_slots, page, Hkv, D); block_table: (B, P).

    Returns (B, Hq, D).  Pages with slot -1 are skipped (no DMA issued for
    their compute; the safe slot-0 fetch is masked out).
    """
    b, hq, d = q.shape
    n_slots, page, hkv, _ = k_pool.shape
    n_pages = block_table.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_paged_kernel, page=page, n_pages=n_pages,
                               scale=scale)
    grid = (b, hkv, n_pages)

    def kv_index(bi, hi, pi, block_table, lengths):
        slot = jnp.maximum(block_table[bi, pi], 0)        # pad -> slot 0
        return (slot, 0, hi, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, d),
                             lambda bi, hi, pi, *refs: (bi, hi, 0, 0)),
                pl.BlockSpec((1, page, 1, d), kv_index),
                pl.BlockSpec((1, page, 1, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, group, d),
                                   lambda bi, hi, pi, *refs: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, k_pool, v_pool)
    return out.reshape(b, hq, d)
