"""Pure-jnp oracles for every Pallas kernel (kernel-facing signatures).

These delegate to the validated model-layer implementations
(``models.attention`` / ``models.ssm``) so tests pin the kernels to the same
math the framework executes on the jnp path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import (reference_attention, decode_partial,
                                    combine_partials)
from repro.models.ssm import ssd_chunked


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) -> (B, S, Hq, D)."""
    return reference_attention(q, k, v, causal=causal, window=window)


def paged_attention_ref(q, k_pool, v_pool, block_table, lengths):
    """Decode over a page pool.

    q: (B, Hq, D); k_pool/v_pool: (n_slots, page, Hkv, D);
    block_table: (B, P) int32 slot ids (-1 pad); lengths: (B,) valid tokens.
    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    n_slots, page, hkv, _ = k_pool.shape
    p = block_table.shape[1]
    safe = jnp.maximum(block_table, 0)
    keys = k_pool[safe].reshape(b, p * page, hkv, d)
    values = v_pool[safe].reshape(b, p * page, hkv, d)
    pos = jnp.arange(p * page)[None, :]
    valid = (pos < lengths[:, None]) & jnp.repeat(
        block_table >= 0, page, axis=1)
    m, l, acc = decode_partial(q, keys, values, valid)
    return combine_partials(
        (m[None], l[None], acc[None]), q.dtype)


def ssd_scan_ref(x, dt, A, B_mat, C_mat, chunk):
    """SSD over chunks (no D skip / gating — kernel computes the core scan).

    x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,); B/C: (B,S,G,N).
    Returns y (B,S,H,P), h_final (B,H,P,N).
    """
    return ssd_chunked(x, dt, A, B_mat, C_mat,
                       jnp.zeros((x.shape[2],), jnp.float32), chunk)
