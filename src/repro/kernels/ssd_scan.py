"""Mamba-2 SSD chunk-scan TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the sequence is cut
into chunks; within a chunk everything is dense matmuls (MXU-friendly), and
the inter-chunk recurrence is a scalar-decay state update carried in VMEM
scratch across the innermost (sequential) grid axis — the Pallas analogue of
``lax.scan`` with the state never leaving VMEM.

Grid: (B, H, NC).  Per step the kernel consumes one (chunk x head) tile:
  x  (Q, P)   head inputs           dt (Q, 1)  post-softplus step sizes
  B  (Q, N)   input projections     C  (Q, N)  output projections
  A  ()       per-head decay (negative scalar), via scalar prefetch
and produces y (Q, P), carrying h (P, N) f32 state in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(A_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk, n_chunks):
    hi = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = A_ref[hi]                                        # scalar, negative
    x = x_ref[0, 0, 0].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)             # (Q, 1)
    bmat = b_ref[0, 0, 0].astype(jnp.float32)            # (Q, N)
    cmat = c_ref[0, 0, 0].astype(jnp.float32)            # (Q, N)

    l = dt[:, 0] * a                                     # (Q,) log decays
    lc = jnp.cumsum(l)                                   # within-chunk cumsum
    ltot = lc[chunk - 1]

    # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) exp(lc_t - lc_s) dt_s x_s
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(lc[:, None] - lc[None, :])
    m = jnp.where(ti >= si, cb * decay, 0.0) * dt[None, :, 0]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y[t] += C_t . (exp(lc_t) * h_prev)
    h_prev = h_scr[...]                                  # (P, N)
    y = y + jnp.exp(lc)[:, None] * jax.lax.dot_general(
        cmat, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h = exp(ltot) * h_prev + sum_s exp(ltot - lc_s) dt_s x_s B_s^T
    w = (jnp.exp(ltot - lc) * dt[:, 0])[:, None] * x     # (Q, P)
    h_new = jnp.exp(ltot) * h_prev + jax.lax.dot_general(
        w, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (P, N)
    h_scr[...] = h_new
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x, dt, A, B_mat, C_mat, chunk, *, interpret=False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B/C: (B,S,G,N).

    Returns y (B,S,H,P) f32, h_final (B,H,P,N) f32.  (D-skip and gating are
    applied by the caller; see ``repro.models.ssm``.)
    """
    b, s, h, p = x.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    hpg = h // g

    # head-major chunked layouts
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk, 1)
    br = B_mat.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)
    cr = C_mat.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    grid = (b, h, nc)

    y, hT = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, chunk, p),
                             lambda bi, hi, ci, *r: (bi, hi, ci, 0, 0)),
                pl.BlockSpec((1, 1, 1, chunk, 1),
                             lambda bi, hi, ci, *r: (bi, hi, ci, 0, 0)),
                pl.BlockSpec((1, 1, 1, chunk, n),
                             lambda bi, hi, ci, *r, hpg=hpg: (bi, hi // hpg, ci, 0, 0)),
                pl.BlockSpec((1, 1, 1, chunk, n),
                             lambda bi, hi, ci, *r, hpg=hpg: (bi, hi // hpg, ci, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, chunk, p),
                             lambda bi, hi, ci, *r: (bi, hi, ci, 0, 0)),
                pl.BlockSpec((1, 1, p, n),
                             lambda bi, hi, ci, *r: (bi, hi, 0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(A, xr, dtr, br, cr)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, hT
