import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape decode_32k --mesh single

Artifacts: benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
(incremental: existing artifacts are skipped unless --force).
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, shape_applicable, get_arch, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def _artifact_dir():
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    d = os.path.join(here, "benchmarks", "artifacts", "dryrun")
    os.makedirs(d, exist_ok=True)
    return d


def run_cell(arch_name, shape_name, mesh_name, mesh, out_dir, force=False,
             kv_dtype="bf16"):
    from repro import roofline as RL
    from repro.launch.specs import build_cell

    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    suffix = "" if kv_dtype == "bf16" else f"__{kv_dtype}"
    path = os.path.join(out_dir, mesh_name,
                        f"{arch_name}__{shape_name}{suffix}.json")
    if os.path.exists(path) and not force:
        print(f"[skip] {mesh_name}/{arch_name}/{shape_name} (cached)")
        return json.load(open(path))

    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        json.dump(rec, open(path, "w"), indent=2)
        print(f"[SKIP] {mesh_name}/{arch_name}/{shape_name}: {why}")
        return rec

    t0 = time.time()
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "kv_dtype": kv_dtype}
    try:
        cell = build_cell(arch_name, shape_name, mesh, kv_dtype=kv_dtype)
        with mesh:
            kw = {}
            if cell.out_shardings is not None:
                kw["out_shardings"] = cell.out_shardings
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate, **kw)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
        # loop-aware analysis: cost_analysis counts while bodies once, so
        # scans (layers/attention blocks/microbatches) would be undercounted
        coll = RL.analyze_hlo(hlo)
        n_chips = mesh.devices.size

        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        n_micro = cell.meta.get("microbatches", 1)
        terms = RL.RooflineTerms(
            flops=max(float(cost.get("flops", 0.0)), coll["flops"]),
            bytes_hbm=RL.analytic_bytes_for(
                cfg, shape, mesh_shape, n_micro=n_micro,
                kv_bytes=1.0 if kv_dtype == "int8" else 2.0),
            bytes_coll=float(coll["total_collective"]),
            model_flops=RL.model_flops_for(cfg, shape, n_chips),
        )
        rec.update({
            "status": "ok",
            "n_chips": int(n_chips),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
                "peak_per_device": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            },
            "collectives": {k: v for k, v in coll.items()},
            "cost_analysis_raw": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "hlo_bytes_unfused_upper_bound": coll["bytes"],
            },
            "roofline": terms.to_dict(),
            "meta": {k: str(v) for k, v in cell.meta.items()},
        })
        fits = rec["memory"]["peak_per_device"] < 16 * (1 << 30)
        rec["fits_hbm_16g"] = bool(fits)
        print(f"[ok]   {mesh_name}/{arch_name}/{shape_name}: "
              f"compile={t_compile:.0f}s "
              f"mem/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB "
              f"bottleneck={terms.bottleneck} "
              f"frac={terms.roofline_fraction:.3f}")
    except Exception as e:                                   # noqa: BLE001
        rec.update({"status": "error", "error": repr(e),
                    "trace": traceback.format_exc()[-4000:]})
        print(f"[ERR]  {mesh_name}/{arch_name}/{shape_name}: {e!r}")
    json.dump(rec, open(path, "w"), indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    args = ap.parse_args()

    out_dir = args.out or _artifact_dir()
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        name = "multi" if multi else "single"
        for a in archs:
            for s in shapes:
                results.append(run_cell(a, s, name, mesh, out_dir,
                                        force=args.force,
                                        kv_dtype=args.kv_dtype))
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
