"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_degraded_mesh(n_alive: int, model_parallel: int = 16):
    """Elastic mesh over survivors: keep TP fixed, shed DP replicas."""
    dp = n_alive // model_parallel
    assert dp >= 1, "not enough devices for one model-parallel group"
    devs = jax.devices()[: dp * model_parallel]
    import numpy as np
    arr = np.array(devs).reshape(dp, model_parallel)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


def make_local_mesh(dp: int = 1, mp: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh((dp, mp), ("data", "model"))


def mesh_axes(mesh):
    names = mesh.axis_names
    dp_axes = tuple(n for n in names if n != "model")
    return dp_axes, "model"
