"""Pipeline parallelism over the pod axis (GPipe, 2 stages).

Between pods the interconnect is DCN, not ICI — pipelining is the natural
cross-pod strategy: per microbatch only one (mb, S, d) activation (and its
gradient) crosses the pod boundary, instead of a full-parameter gradient
all-reduce.  SPMD formulation:

* layer parameters are stacked per stage with a leading pod dim sharded over
  ``pod`` — each pod holds only its stage's layers;
* ``shard_map`` is manual over ``pod`` only (``data``/``model`` stay
  auto/GSPMD, so the whole Megatron-TP machinery from ``models.transformer``
  keeps working inside the stage);
* the GPipe schedule is a ``lax.scan`` over M+1 ticks: at tick t stage 0
  runs microbatch t while stage 1 runs microbatch t-1 received via
  ``ppermute``; stage masking is a ``where`` on the pod index (both pods
  execute the same HLO).  Autodiff flows through scan+ppermute, giving the
  backward pipeline for free (the ppermute transpose is the reverse hop).

Uniform dense archs only (stages need identical layer structure).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.layers import rms_norm
from repro.train import trainer


def _stage_forward(p_stage, x, seg, cfg, ctx):
    """Apply one stage's stacked layers (uniform dense segment)."""
    def body(carry, p_layer):
        xc, aux = carry
        xo, a = T.apply_layer(p_layer, xc, seg, cfg, ctx)
        return (xo, aux + jnp.asarray(a, jnp.float32)), None

    if ctx.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               p_stage)
    return x, aux


def make_pp_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                       n_micro: int = 8):
    """2-stage GPipe train step on the (pod, data, model) mesh.

    params layout: embed/unembed/final_ln replicated over pod; layer stack
    (n_layers, ...) viewed as (2, n_layers//2, ...) with dim0 over ``pod``.
    """
    assert "pod" in mesh.axis_names, "PP needs the multi-pod mesh"
    assert cfg.family == "dense" and not cfg.global_every and not cfg.window,\
        "PP demo targets uniform dense archs"
    segs = T.segments(cfg)
    assert len(segs) == 1
    seg = segs[0]
    # inside the pod-manual region, with_sharding_constraint would need a
    # Manual-pod AbstractMesh; we drop explicit constraints there and let
    # GSPMD propagate data/model sharding from the (auto-sharded) weights
    ctx = T.ParallelCtx(mesh=None, dp_axes=("data",), model_axis="model",
                        remat=True, compute_dtype=jnp.bfloat16,
                        loss_chunk=256)

    b, s = shape.global_batch, shape.seq_len
    mb = b // n_micro

    def loss_tail(params, h, labels_mb):
        h = rms_norm(params["final_ln"], h, cfg.norm_eps)
        w = T.unembed_matrix(params, cfg).astype(h.dtype)
        # chunked NLL (dense path to keep the pod-manual region simple)
        chunk = min(ctx.loss_chunk, s)
        nc = s // chunk
        def body(carry, i):
            hs = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
            ls = jax.lax.dynamic_slice_in_dim(labels_mb, i * chunk, chunk, 1)
            logits = jnp.einsum("bcd,dv->bcv", hs, w).astype(jnp.float32)
            logits = T.mask_vocab_pad(logits, cfg)
            lse = jax.nn.logsumexp(logits, -1)
            onehot = jax.nn.one_hot(ls, logits.shape[-1],
                                    dtype=logits.dtype)
            picked = jnp.einsum("bcv,bcv->bc", logits, onehot)
            return carry + (lse - picked).sum(), None
        tot, _ = jax.lax.scan(jax.checkpoint(body),
                              jnp.zeros((), jnp.float32), jnp.arange(nc))
        return tot / (mb * s)

    def pp_loss(params, tokens, labels):
        """tokens/labels: (n_micro, mb, S).  Manual over pod only."""

        def podwise(stage_params, shared, tokens_l, labels_l):
            # local view: the (n_layers,) stack is halved over pod -> my stage
            my = jax.lax.axis_index("pod")
            d = cfg.d_model

            def tick(carry, t):
                x_recv, loss_acc, aux_acc = carry
                # stage 0 consumes microbatch t (clamped on drain tick)
                t0 = jnp.minimum(t, n_micro - 1)
                toks = jax.lax.dynamic_index_in_dim(
                    tokens_l, t0, 0, keepdims=False)
                x0 = shared["embed"][toks].astype(ctx.compute_dtype)
                x = jnp.where(my == 0, x0, x_recv)
                h, aux = _stage_forward(stage_params, x, seg, cfg, ctx)
                # stage 1 finishes microbatch t-1 -> loss
                t1 = jnp.clip(t - 1, 0, n_micro - 1)
                lbls = jax.lax.dynamic_index_in_dim(
                    labels_l, t1, 0, keepdims=False)
                l = loss_tail(shared, h, lbls)
                live1 = (my == 1) & (t >= 1)
                live0 = (my == 0) & (t <= n_micro - 1)
                loss_acc = loss_acc + jnp.where(live1, l, 0.0)
                aux_acc = aux_acc + jnp.where(live0 | live1, aux, 0.0)
                # hop: stage0 output of micro t -> stage1 input for tick t+1
                x_next = jax.lax.ppermute(h, "pod", [(0, 1), (1, 0)])
                return (x_next, loss_acc, aux_acc), None

            x0 = jnp.zeros((mb, s, d), ctx.compute_dtype)
            (xf, loss_sum, aux_sum), _ = jax.lax.scan(
                tick, (x0, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)),
                jnp.arange(n_micro + 1))
            # stage 1 owns the loss; sum over pods (stage 0 contributes 0)
            loss = jax.lax.psum(loss_sum, "pod") / n_micro
            return loss + jax.lax.psum(aux_sum, "pod") / (2 * n_micro)

        stage_stack = params["segments"][0]
        shared = {k: params[k] for k in params if k != "segments"}
        # manual over the pod axis ONLY — data/model stay automatic, so all
        # the Megatron-TP sharding inside the stage keeps working via GSPMD
        return jax.shard_map(
            podwise, mesh=mesh, axis_names={"pod"},
            in_specs=(P("pod"), P(), P(), P()),
            out_specs=P(),
            check_vma=False,
        )(stage_stack, shared, tokens, labels)

    def train_step(params, opt_state, tokens, labels):
        params_c = trainer.cast_for_compute(params, jnp.bfloat16)
        loss, grads = jax.value_and_grad(pp_loss)(params_c, tokens, labels)
        new_p, new_o, metrics = optim.update(optim.AdamWConfig(), params,
                                             grads, opt_state)
        metrics["loss"] = loss
        return new_p, new_o, metrics

    # shardings ------------------------------------------------------------
    pshape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    pspecs = T.param_pspecs(pshape, cfg, model_size=mesh.shape["model"])

    def podded(path_spec_shape):
        spec, shp = path_spec_shape
        return P(*(("pod",) + tuple(spec)))

    # layer stacks: (L, ...) -> leading dim over pod (L = 2 * L/2 views)
    seg_specs = jax.tree.map(
        lambda sp: P(*(["pod"] + list(sp)[1:])), pspecs["segments"][0],
        is_leaf=lambda x: isinstance(x, P))
    pspecs = dict(pspecs)
    pspecs["segments"] = [seg_specs]
    ns = lambda sp: NamedSharding(mesh, sp)
    p_shard = jax.tree.map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))
    m_shard = p_shard
    opt_shard = optim.AdamWState(ns(P()), m_shard, m_shard)
    batch_shard = ns(P(None, "data", None))
    ins = (p_shard, opt_shard, batch_shard, batch_shard)

    pstruct = pshape
    args = (pstruct, jax.eval_shape(optim.init, pstruct),
            jax.ShapeDtypeStruct((n_micro, mb, s), jnp.int32),
            jax.ShapeDtypeStruct((n_micro, mb, s), jnp.int32))
    return train_step, args, ins
