"""Serving launcher: the Valet engine over a batch of requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --local \
        --requests 8 --policy valet --pool-slots 16

``--dryrun`` lowers+compiles the sharded serve_step for the production mesh
(same path the dry-run sweep uses).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default="valet")
    ap.add_argument("--pool-slots", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page", type=int, default=8)
    args = ap.parse_args()

    if args.dryrun:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell, _artifact_dir
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        rec = run_cell(args.arch, args.shape, "single", mesh,
                       _artifact_dir(), force=True)
        return 0 if rec.get("status") == "ok" else 1

    import numpy as np
    import jax
    from repro.configs import get_arch, reduced
    from repro.core.policies import POLICIES
    from repro.models import transformer as T
    from repro.serve import ValetServeEngine

    cfg = reduced(get_arch(args.arch)) if args.local else get_arch(args.arch)
    ctx = T.ParallelCtx(remat=False, q_block=16, kv_block=16)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ValetServeEngine(
        params, cfg, ctx, max_batch=args.max_batch,
        max_seq=args.prompt_len + args.max_new + args.page,
        page=args.page, pool_slots=args.pool_slots,
        policy=POLICIES[args.policy])
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(2, cfg.vocab, size=args.prompt_len),
                   args.max_new)
    reqs = eng.run()
    s = eng.stats
    print(f"policy={args.policy} requests={len(reqs)} "
          f"done={sum(r.status == 'done' for r in reqs)} tokens={s.tokens}")
    print(f"steps={s.steps} pauses={s.pauses} spilled={s.spilled_pages} "
          f"restored={s.restored_pages} recomputes={s.recomputes}")
    print(f"sim_time={s.sim_time_us / 1e3:.2f}ms "
          f"bg_time={s.bg_time_us / 1e3:.2f}ms wall={s.wall_time_s:.2f}s")
    for r in reqs[:4]:
        print(f"  req{r.rid}: {r.tokens_out[:8]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
