"""Sharded serve_step: decode with the Valet page pool distributed across
the production mesh.

Distribution plan (DESIGN.md §5):

* batch over the DP axes; **KV pages round-robin over the KV axes** — each
  device cell is a "peer memory donor" holding a shard of every sequence's
  pages (paper §4.3: spread pages evenly across peers);
* paged attention runs inside ``shard_map``: each peer computes a partial
  softmax over *its* pages (one-sided read: no control-plane work on the
  peer), and an exact flash-decoding combine over the KV axes costs one tiny
  ``psum`` — the TPU translation of Valet's one-sided RDMA READ fan-out;
* appends are masked to the owning peer (sender-driven placement);
* weights stay Megatron-TP over ``model``; per-token activations are
  replicated across ``model`` (decode is memory-bound; the all-gather of one
  token's q is noise against the page-pool reads).

Shapes:
  decode_32k : batch over (pod,)data, pages over model.
  long_500k  : batch=1 -> pure sequence parallelism: pages over ALL axes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import ssm as ssm_lib
from repro.models import transformer as T
from repro.models.attention import (decode_partial, combine_partials,
                                    combine_partials_psum)
from repro.models.layers import apply_rope, rms_norm, swiglu, gelu_mlp
from repro.models.moe import moe_ffn, _shard_map
from repro.models.transformer import ParallelCtx, Segment, segments


@dataclass(frozen=True)
class DecodePlan:
    batch_axes: Tuple[str, ...]
    kv_axes: Tuple[str, ...]
    page: int = 64
    headroom: float = 1.25
    kv_dtype: str = "bf16"        # bf16 | int8 (quantized page pool)

    def batch_spec(self):
        if not self.batch_axes:
            return None
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]

    def kv_spec(self):
        return self.kv_axes if len(self.kv_axes) > 1 else self.kv_axes[0]


def plan_for(shape: ShapeConfig, mesh, kv_dtype: str = "bf16") -> DecodePlan:
    names = mesh.axis_names
    dp = tuple(n for n in names if n != "model")
    if shape.global_batch == 1:
        return DecodePlan(batch_axes=(), kv_axes=tuple(names),
                          kv_dtype=kv_dtype)
    return DecodePlan(batch_axes=dp, kv_axes=("model",), kv_dtype=kv_dtype)


def axis_sizes(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# --------------------------------------------------------------------------
# Cache geometry
# --------------------------------------------------------------------------

def cache_geometry(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   plan: DecodePlan):
    b = shape.global_batch
    dp = axis_sizes(mesh, plan.batch_axes)
    kvr = axis_sizes(mesh, plan.kv_axes)
    b_loc = b // max(dp, 1)
    p_tot = -(-shape.seq_len // plan.page)             # pages per sequence
    p_loc = -(-p_tot // kvr)
    slots_loc = max(int(b_loc * p_loc * plan.headroom), b_loc)
    return dict(b=b, dp=dp, kvr=kvr, b_loc=b_loc, p_tot=p_tot, p_loc=p_loc,
                slots_loc=slots_loc)


def decode_struct(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  plan: DecodePlan, dtype=jnp.bfloat16):
    """ShapeDtypeStructs + PartitionSpecs for caches and step inputs."""
    geo = cache_geometry(cfg, shape, mesh, plan)
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    bsp = plan.batch_spec()
    ksp = plan.kv_spec()
    segs = segments(cfg)

    caches, specs = [], []
    for seg in segs:
        c, s = {}, {}
        n = seg.count
        if seg.kind in ("attn", "dec", "hybrid") and seg.window == 0:
            shp = (n, max(geo["dp"], 1), geo["kvr"], geo["slots_loc"],
                   plan.page, kv, hd)
            pool_dt = jnp.int8 if plan.kv_dtype == "int8" else dtype
            c["pool_k"] = jax.ShapeDtypeStruct(shp, pool_dt)
            c["pool_v"] = jax.ShapeDtypeStruct(shp, pool_dt)
            s["pool_k"] = s["pool_v"] = P(None, bsp, ksp, None, None, None, None)
            if plan.kv_dtype == "int8":
                sshp = shp[:-1]               # per (slot, pos, head) scales
                c["scale_k"] = jax.ShapeDtypeStruct(sshp, dtype)
                c["scale_v"] = jax.ShapeDtypeStruct(sshp, dtype)
                s["scale_k"] = s["scale_v"] = P(None, bsp, ksp, None, None,
                                                None)
        if seg.kind in ("attn", "hybrid") and seg.window > 0:
            shp = (n, geo["b"], seg.window, kv, hd)
            c["ring_k"] = jax.ShapeDtypeStruct(shp, dtype)
            c["ring_v"] = jax.ShapeDtypeStruct(shp, dtype)
            s["ring_k"] = s["ring_v"] = P(None, bsp, None, None, None)
        if seg.kind in ("ssm", "hybrid"):
            d_in, nh, d_bc = ssm_lib.ssm_dims(cfg.d_model, cfg.ssm)
            mp = mesh.shape["model"]
            c["ssm_h"] = jax.ShapeDtypeStruct(
                (n, geo["b"], nh, cfg.ssm.head_dim, cfg.ssm.d_state),
                jnp.float32)
            if nh % mp == 0:           # shard heads, else head_dim, else rep
                s["ssm_h"] = P(None, bsp, "model", None, None)
            elif cfg.ssm.head_dim % mp == 0:
                s["ssm_h"] = P(None, bsp, None, "model", None)
            else:
                s["ssm_h"] = P(None, bsp, None, None, None)
            c["ssm_conv"] = jax.ShapeDtypeStruct(
                (n, geo["b"], cfg.ssm.conv_kernel - 1, d_in + d_bc), dtype)
            s["ssm_conv"] = P(None, bsp, None, None)
        if seg.kind in ("xattn", "dec"):
            ncross = cfg.n_frontend_tokens
            shp = (n, geo["b"], ncross, kv, hd)
            c["cross_k"] = jax.ShapeDtypeStruct(shp, dtype)
            c["cross_v"] = jax.ShapeDtypeStruct(shp, dtype)
            s["cross_k"] = s["cross_v"] = P(None, bsp, None, None, None)
        caches.append(c)
        specs.append(s)

    step = {
        "tokens": jax.ShapeDtypeStruct((geo["b"],), jnp.int32),
        "block_table": jax.ShapeDtypeStruct(
            (max(geo["dp"], 1), geo["kvr"], geo["b_loc"], geo["p_loc"]),
            jnp.int32),
        "app_slot": jax.ShapeDtypeStruct((geo["b"],), jnp.int32),
        "app_off": jax.ShapeDtypeStruct((geo["b"],), jnp.int32),
        "app_rank": jax.ShapeDtypeStruct((geo["b"],), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((geo["b"],), jnp.int32),
    }
    step_specs = {
        "tokens": P(bsp),
        "block_table": P(bsp, ksp, None, None),
        "app_slot": P(bsp),
        "app_off": P(bsp),
        "app_rank": P(bsp),
        "lengths": P(bsp),
    }
    return caches, specs, step, step_specs, geo


# --------------------------------------------------------------------------
# The sharded paged-attention inner (one layer)
# --------------------------------------------------------------------------

def _quantize_token(x, eps=1e-6):
    """(B, kv, hd) bf16 -> int8 values + per-(B, kv) scales."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, eps)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale.astype(x.dtype)


def _paged_attn_sharded(cache, bt, q, k, v, app_slot, app_off,
                        app_rank, lengths, *, mesh, plan: DecodePlan,
                        page: int, out_dtype):
    """shard_map'd append + partial attention + cross-peer combine.

    Global shapes: pool (DP, KVR, slots, page, kv, hd); bt (DP, KVR, B_loc,
    P_loc); q (B, Hq, hd); k/v (B, kv, hd); app_*/lengths (B,).
    kv_dtype="int8": pool stores quantized pages + per-(slot,pos,head)
    scales — halves the per-step HBM stream of the Valet pool (§Perf
    iteration 7, beyond-paper).
    """
    bsp = plan.batch_spec()
    ksp = plan.kv_spec()
    kvr = axis_sizes(mesh, plan.kv_axes)
    quant = plan.kv_dtype == "int8"

    def body(pk, pv, sk, sv, btl, ql, kl, vl, aslot, aoff, arank, lens):
        # local blocks: pk (1, 1, slots, page, kv, hd); btl (1,1,B,P_loc)
        pk, pv = pk[0, 0], pv[0, 0]
        sk, sv = (sk[0, 0], sv[0, 0]) if quant else (sk, sv)
        btl = btl[0, 0]
        # my combined kv-rank index
        my = jnp.zeros((), jnp.int32)
        for a in plan.kv_axes:
            my = my * mesh.shape[a] + jax.lax.axis_index(a)
        own = arank == my
        safe_slot = jnp.where(own, aslot, pk.shape[0])
        if quant:
            kq, ks = _quantize_token(kl)
            vq, vs = _quantize_token(vl)
            pk = pk.at[safe_slot, aoff].set(kq, mode="drop")
            pv = pv.at[safe_slot, aoff].set(vq, mode="drop")
            sk = sk.at[safe_slot, aoff].set(ks, mode="drop")
            sv = sv.at[safe_slot, aoff].set(vs, mode="drop")
        else:
            pk = pk.at[safe_slot, aoff].set(kl, mode="drop")
            pv = pv.at[safe_slot, aoff].set(vl, mode="drop")

        # page-chunked flash accumulation: never materialize the full local
        # KV gather (CPU temps showed ~20 GiB/dev for MHA archs otherwise);
        # this is exactly how the Pallas paged kernel walks the pool
        # (§Perf iteration 8)
        bl, p_loc = btl.shape
        chunk = next(c for c in (8, 4, 2, 1) if p_loc % c == 0)
        n_chunks = p_loc // chunk
        hq_g = ql.shape[1]
        hd_ = ql.shape[2]
        n_kv = pk.shape[2]

        def chunk_step(carry, ci):
            m, l, acc = carry
            btc = jax.lax.dynamic_slice_in_dim(btl, ci * chunk, chunk,
                                               axis=1)
            safe = jnp.maximum(btc, 0)
            keys = pk[safe]                        # (B, C, page, kv, hd)
            values = pv[safe]
            if quant:
                keys = keys.astype(out_dtype) * sk[safe][..., None]
                values = values.astype(out_dtype) * sv[safe][..., None]
            keys = keys.reshape(bl, chunk * page, n_kv, hd_)
            values = values.reshape(bl, chunk * page, n_kv, hd_)
            j = ci * chunk + jnp.arange(chunk)[None, :]
            abs_base = (j * kvr + my) * page
            pos = abs_base[:, :, None] + jnp.arange(page)[None, None, :]
            pos = jnp.broadcast_to(pos, (bl, chunk, page)).reshape(bl, -1)
            valid = (pos <= lens[:, None]) & jnp.repeat(
                btc >= 0, page, axis=1)
            m2, l2, a2 = decode_partial(ql, keys, values, valid)
            mn = jnp.maximum(m, m2)
            c1 = jnp.exp(m - mn)
            c2 = jnp.exp(m2 - mn)
            return (mn, l * c1 + l2 * c2,
                    acc * c1[..., None] + a2 * c2[..., None]), None

        g = hq_g // n_kv
        m0 = jnp.full((bl, n_kv, g), -1e30, jnp.float32)
        l0 = jnp.zeros((bl, n_kv, g), jnp.float32)
        a0 = jnp.zeros((bl, n_kv, g, hd_), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(chunk_step, (m0, l0, a0),
                                      jnp.arange(n_chunks))
        out = combine_partials_psum(m, l, acc, plan.kv_axes, out_dtype)
        if quant:
            return (pk[None, None], pv[None, None], sk[None, None],
                    sv[None, None], out)
        return pk[None, None], pv[None, None], out

    pool_spec = P(bsp, ksp, None, None, None, None)
    scale_spec = P(bsp, ksp, None, None, None)
    vec_spec = P(bsp, None, None)
    scal_spec = P(bsp)
    if quant:
        outs = _shard_map(
            body, mesh,
            (pool_spec, pool_spec, scale_spec, scale_spec,
             P(bsp, ksp, None, None), vec_spec, vec_spec, vec_spec,
             scal_spec, scal_spec, scal_spec, scal_spec),
            (pool_spec, pool_spec, scale_spec, scale_spec, vec_spec),
        )(cache["pool_k"], cache["pool_v"], cache["scale_k"],
          cache["scale_v"], bt, q, k, v, app_slot, app_off, app_rank,
          lengths)
        pk, pv, sk, sv, out = outs
        return {"pool_k": pk, "pool_v": pv, "scale_k": sk,
                "scale_v": sv}, out
    pk, pv, out = _shard_map(
        body, mesh,
        (pool_spec, pool_spec, P(), P(), P(bsp, ksp, None, None), vec_spec,
         vec_spec, vec_spec, scal_spec, scal_spec, scal_spec, scal_spec),
        (pool_spec, pool_spec, vec_spec),
    )(cache["pool_k"], cache["pool_v"], jnp.zeros(()), jnp.zeros(()),
      bt, q, k, v, app_slot, app_off, app_rank, lengths)
    return {"pool_k": pk, "pool_v": pv}, out


# --------------------------------------------------------------------------
# Migration data plane (paper §3.5 at pod scale)
# --------------------------------------------------------------------------

def make_migrate_step(mesh, plan: DecodePlan, pool_struct):
    """Data plane for sender-driven migration between peer shards.

    The control plane (Valet sender) picks victims by Non-Activity-Duration
    and a destination by power-of-two-choices; this jitted step moves the
    selected page payloads one hop along the KV axis ring
    (``collective_permute``) and installs them at the destination slots.
    Reads keep hitting the source slots until the control plane cuts the
    block table over — the data plane never blocks decode.

    pool (n, DP, KVR, slots, page, kv, hd); src/dst slots (DP, KVR, n_mig).
    """
    bsp = plan.batch_spec()
    ksp = plan.kv_spec()
    axis = plan.kv_axes[-1]
    n_ranks = mesh.shape[axis]
    perm = [(i, (i + 1) % n_ranks) for i in range(n_ranks)]

    def body(pk, pv, src, dst):
        pk, pv = pk[:, 0, 0], pv[:, 0, 0]       # (n, slots, page, kv, hd)
        src, dst = src[0, 0], dst[0, 0]          # (n_mig,)
        payload_k = pk[:, src]                   # (n, n_mig, page, kv, hd)
        payload_v = pv[:, src]
        payload_k = jax.lax.ppermute(payload_k, axis, perm)
        payload_v = jax.lax.ppermute(payload_v, axis, perm)
        pk = pk.at[:, dst].set(payload_k)
        pv = pv.at[:, dst].set(payload_v)
        return pk[:, None, None], pv[:, None, None]

    pool_spec = P(None, bsp, ksp, None, None, None, None)
    slot_spec = P(bsp, ksp, None)

    def migrate_step(pool_k, pool_v, src_slots, dst_slots):
        return _shard_map(body, mesh,
                          (pool_spec, pool_spec, slot_spec, slot_spec),
                          (pool_spec, pool_spec))(
            pool_k, pool_v, src_slots, dst_slots)

    return migrate_step


# --------------------------------------------------------------------------
# Full serve step
# --------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    plan: Optional[DecodePlan] = None,
                    compute_dtype=jnp.bfloat16):
    """Build serve_step(params, caches, step) -> (next_tokens, caches)."""
    plan = plan or plan_for(shape, mesh)
    ctx = ParallelCtx(mesh=mesh,
                      dp_axes=plan.batch_axes or ("data",),
                      compute_dtype=compute_dtype)
    segs = segments(cfg)
    hd = cfg.resolved_head_dim
    bsp = plan.batch_spec()

    def qkv_one(p, x, lengths):
        b = x.shape[0]
        q = jnp.einsum("bd,dh->bh", x, p["wq"]).reshape(b, cfg.n_heads, hd)
        k = jnp.einsum("bd,dh->bh", x, p["wk"]).reshape(b, cfg.n_kv_heads, hd)
        v = jnp.einsum("bd,dh->bh", x, p["wv"]).reshape(b, cfg.n_kv_heads, hd)
        if cfg.rope_theta > 0:
            q = apply_rope(q[:, None], lengths[:, None], cfg.rope_theta)[:, 0]
            k = apply_rope(k[:, None], lengths[:, None], cfg.rope_theta)[:, 0]
        # replicate across model for the page-pool read
        q = T.shard(q, ctx, bsp, None, None)
        k = T.shard(k, ctx, bsp, None, None)
        v = T.shard(v, ctx, bsp, None, None)
        return q, k, v

    def ring_attn(p, x, ring_k, ring_v, step):
        """Sliding-window decode, batch-local inside shard_map.

        The ring append is a per-sequence scatter; under plain GSPMD the
        traced indices over the batch-sharded dim forced a full ring
        all-gather per layer (danube baseline: 60.9ms collective per step).
        shard_map makes it a purely local update (§Perf iteration 6)."""
        b = x.shape[0]
        lengths = step["lengths"]
        q, k, v = qkv_one(p, x, lengths)

        def body(rk, rv, ql, kl, vl, lens):
            bl = ql.shape[0]
            w = rk.shape[1]
            idx = lens % w
            rk = rk.at[jnp.arange(bl), idx].set(kl)
            rv = rv.at[jnp.arange(bl), idx].set(vl)
            slot = jnp.arange(w)[None]
            cur = lens[:, None]
            abs_pos = cur - ((cur - slot) % w)
            valid = (abs_pos >= 0) & (abs_pos <= cur)
            m, l, acc = decode_partial(ql, rk, rv, valid)
            out = combine_partials((m[None], l[None], acc[None]), ql.dtype)
            return rk, rv, out

        if mesh is not None:
            rspec = P(bsp, None, None, None)
            vspec = P(bsp, None, None)
            ring_k, ring_v, out = _shard_map(
                body, mesh,
                (rspec, rspec, vspec, vspec, vspec, P(bsp)),
                (rspec, rspec, vspec),
            )(ring_k, ring_v, q, k, v, lengths)
        else:
            ring_k, ring_v, out = body(ring_k, ring_v, q, k, v, lengths)
        return jnp.einsum("bh,hd->bd", out.reshape(b, -1), p["wo"]), \
            ring_k, ring_v

    def paged_attn(p, x, cache, step):
        b = x.shape[0]
        q, k, v = qkv_one(p, x, step["lengths"])
        updates, out = _paged_attn_sharded(
            cache, step["block_table"], q, k, v,
            step["app_slot"], step["app_off"], step["app_rank"],
            step["lengths"], mesh=mesh, plan=plan, page=plan.page,
            out_dtype=x.dtype)
        return jnp.einsum("bh,hd->bd", out.reshape(b, -1), p["wo"]), updates

    def cross_attn(p, x, ck, cv):
        b = x.shape[0]
        q = jnp.einsum("bd,dh->bh", x, p["wq"]).reshape(b, cfg.n_heads, hd)
        q = T.shard(q, ctx, bsp, None, None)
        valid = jnp.ones(ck.shape[:2], bool)
        m, l, acc = decode_partial(q, ck, cv, valid)
        out = combine_partials((m[None], l[None], acc[None]), x.dtype)
        return jnp.einsum("bh,hd->bd", out.reshape(b, -1), p["wo"])

    def ffn(p, x, seg: Segment):
        if seg.ffn == "moe":
            out, _ = moe_ffn(p["moe"], x[:, None, :], cfg.moe, mesh=mesh,
                             model_axis="model",
                             dp_spec=P(bsp, None, None))
            return out[:, 0]
        if seg.ffn == "gelu":
            return gelu_mlp(p["mlp"], x)
        return swiglu(p["mlp"], x)

    def layer(p, x, cache, seg: Segment, step):
        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        new_c = dict(cache)
        if seg.kind in ("attn", "dec"):
            if seg.window == 0:
                a, upd = paged_attn(p["attn"], h, cache, step)
                new_c.update(upd)
            else:
                a, new_c["ring_k"], new_c["ring_v"] = ring_attn(
                    p["attn"], h, cache["ring_k"], cache["ring_v"], step)
            x = x + a
            if seg.kind == "dec":
                hx = rms_norm(p["lnx"], x, cfg.norm_eps)
                x = x + cross_attn(p["xattn"], hx, cache["cross_k"],
                                   cache["cross_v"])
        elif seg.kind == "xattn":
            gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * cross_attn(p["xattn"], h, cache["cross_k"],
                                      cache["cross_v"])
        elif seg.kind in ("ssm", "hybrid"):
            st = {"h": cache["ssm_h"], "conv": cache["ssm_conv"]}
            y, st = ssm_lib.ssm_decode_step(p["ssm"], h, st, cfg.d_model,
                                            cfg.ssm)
            new_c["ssm_h"], new_c["ssm_conv"] = st["h"], st["conv"]
            if seg.kind == "hybrid":
                if seg.window == 0:
                    a, upd = paged_attn(p["attn"], h, cache, step)
                    new_c.update(upd)
                else:
                    a, new_c["ring_k"], new_c["ring_v"] = ring_attn(
                        p["attn"], h, cache["ring_k"], cache["ring_v"], step)
                y = 0.5 * (rms_norm(p["attn_norm"], a, cfg.norm_eps)
                           + rms_norm(p["ssm_norm"], y, cfg.norm_eps))
            x = x + y
        if seg.ffn != "none":
            h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
            x = x + ffn(p, h2, seg)
        return T.shard(x, ctx, bsp, None), new_c

    def serve_step(params, caches, step):
        x = params["embed"][step["tokens"]].astype(compute_dtype)
        x = T.shard(x, ctx, bsp, None)
        new_caches = []
        for p_stack, cache, seg in zip(params["segments"], caches, segs):
            if seg.count == 1:
                p1 = jax.tree.map(lambda a: a[0], p_stack)
                c1 = {k: v[0] for k, v in cache.items()}
                x, c1 = layer(p1, x, c1, seg, step)
                new_caches.append({k: v[None] for k, v in c1.items()})
            else:
                def body(xc, inp, seg=seg):
                    p1, c1 = inp
                    xo, co = layer(p1, xc, c1, seg, step)
                    return xo, co
                x, co = jax.lax.scan(body, x, (p_stack, cache))
                new_caches.append(co)
        x = rms_norm(params["final_ln"], x, cfg.norm_eps)
        w = T.unembed_matrix(params, cfg).astype(x.dtype)
        logits = T.mask_vocab_pad(
            jnp.einsum("bd,dv->bv", x, w).astype(jnp.float32), cfg)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches

    return serve_step, plan, ctx
