"""Cell builders: for every (arch x shape) produce the step function, its
abstract inputs (ShapeDtypeStruct — no allocation), and shardings.

Used by the dry-run (lower+compile only) and by the real launchers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import get_arch, get_shape, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import serve_step as SS
from repro.launch.mesh import mesh_axes
from repro.models import transformer as T
from repro.train import trainer


@dataclass
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    fn: Any                      # callable to jit
    args: tuple                  # ShapeDtypeStruct pytrees
    in_shardings: Any
    donate: Tuple[int, ...]
    meta: Dict[str, Any]
    out_shardings: Any = None    # explicit -> enables donation aliasing


def params_struct(cfg: ArchConfig, dtype):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: T.init_params(key, cfg, dtype=dtype))


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _param_shardings(mesh, cfg, pshape):
    specs = T.param_pspecs(pshape, cfg, model_size=mesh.shape["model"])
    return jax.tree.map(lambda s: _ns(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def microbatches_for(cfg: ArchConfig, shape: ShapeConfig, mesh) -> int:
    """Pick grad-accum so the per-microbatch activation fits HBM."""
    dp_axes, _ = mesh_axes(mesh)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    b_local = max(shape.global_batch // dp, 1)
    if cfg.n_frontend_tokens and cfg.d_model >= 4096:
        micro_local = 1               # vlm: frontend KV inflates activations
    elif cfg.d_model >= 2048:
        micro_local = 2
    else:
        micro_local = 4
    return max(b_local // micro_local, 1)


def build_train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Cell:
    dp_axes, model_axis = mesh_axes(mesh)
    ctx = T.ParallelCtx(mesh=mesh, dp_axes=dp_axes, model_axis=model_axis,
                        remat=True, compute_dtype=jnp.bfloat16,
                        loss_chunk=256, save_collectives=True)
    tcfg = trainer.TrainConfig(
        microbatches=microbatches_for(cfg, shape, mesh),
        zero1=True, compute_dtype=jnp.bfloat16)
    has_fe = cfg.n_frontend_tokens > 0
    fn = trainer.make_train_step(cfg, ctx, tcfg, has_frontend=has_fe)
    pshape = params_struct(cfg, jnp.float32)
    opt_shape = jax.eval_shape(optim.init, pshape)
    b, s = shape.global_batch, shape.seq_len
    nm = tcfg.microbatches
    toks = jax.ShapeDtypeStruct((nm, b // nm, s), jnp.int32)
    args = [pshape, opt_shape, toks, toks]
    if has_fe:
        args.append(jax.ShapeDtypeStruct(
            (nm, b // nm, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16))
    ins, outs = trainer.make_shardings(cfg, ctx, tcfg, pshape,
                                       has_frontend=has_fe)
    return Cell(cfg, shape, fn, tuple(args), ins, donate=(0, 1),
                meta={"kind": "train", "microbatches": tcfg.microbatches},
                out_shardings=outs)


def build_prefill_cell(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Cell:
    dp_axes, model_axis = mesh_axes(mesh)
    # sequence-parallel residuals pay off for prefill (no backward
    # transposes) but only when attention itself shards by heads; MHA archs
    # with heads % TP != 0 shard via transient head padding (whisper), so
    # only GQA archs with unshardable heads (hymba 25H/5kv) keep SP off
    mp = mesh.shape["model"]
    sp = (cfg.n_heads % mp == 0 or cfg.n_heads == cfg.n_kv_heads) \
        if cfg.n_heads else True
    ctx = T.ParallelCtx(mesh=mesh, dp_axes=dp_axes, model_axis=model_axis,
                        remat=False, compute_dtype=jnp.bfloat16,
                        seq_parallel=sp)
    has_fe = cfg.n_frontend_tokens > 0

    def fn(params, tokens, frontend=None):
        return T.prefill_logits(params, tokens, cfg, ctx, frontend=frontend)

    pshape = params_struct(cfg, jnp.bfloat16)
    b, s = shape.global_batch, shape.seq_len
    args = [pshape, jax.ShapeDtypeStruct((b, s), jnp.int32)]
    ins = [_param_shardings(mesh, cfg, pshape),
           _ns(mesh, P(ctx.dp, None))]
    if has_fe:
        args.append(jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16))
        ins.append(_ns(mesh, P(ctx.dp, None, None)))
    return Cell(cfg, shape, fn, tuple(args), tuple(ins), donate=(),
                meta={"kind": "prefill"})


def build_decode_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
                      kv_dtype: str = "bf16") -> Cell:
    plan = SS.plan_for(shape, mesh, kv_dtype=kv_dtype)
    fn, plan, ctx = SS.make_serve_step(cfg, shape, mesh, plan=plan)
    caches, cache_specs, step, step_specs, geo = SS.decode_struct(
        cfg, shape, mesh, plan)
    pshape = params_struct(cfg, jnp.bfloat16)
    p_shard = _param_shardings(mesh, cfg, pshape)
    cache_shards = [
        {k: _ns(mesh, s[k]) for k in c} for c, s in zip(caches, cache_specs)]
    step_shards = {k: _ns(mesh, step_specs[k]) for k in step}
    args = (pshape, caches, step)
    ins = (p_shard, cache_shards, step_shards)
    outs = (step_shards["tokens"], cache_shards)
    return Cell(cfg, shape, fn, args, ins, donate=(1,),
                meta={"kind": "decode", "plan": plan, "geo": geo},
                out_shardings=outs)


def build_cell(arch_name: str, shape_name: str, mesh,
               kv_dtype: str = "bf16") -> Optional[Cell]:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh)
    return build_decode_cell(cfg, shape, mesh, kv_dtype=kv_dtype)
