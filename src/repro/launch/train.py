"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b \
        --steps 200 --local            # CPU-scale smoke run
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --dryrun                       # lower+compile on the production mesh

On a real TPU pod this module is the per-host entry point: jax.distributed
initializes from the TPU environment, every host builds the same mesh and
feeds its deterministic data shard (repro.data), checkpoints flow through
ValetCheckpointer, and recovery uses train.elastic.
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--local", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile the full config on the 16x16 mesh")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    if args.dryrun:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    import jax.numpy as jnp
    from repro import optim
    from repro.configs import get_arch, reduced
    from repro.data import DataConfig, TrainDataset
    from repro.models import transformer as T
    from repro.train import (TrainConfig, ValetCheckpointer, fit)

    if args.dryrun:
        from repro.launch.dryrun import run_cell, _artifact_dir
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        rec = run_cell(args.arch, "train_4k", "single", mesh,
                       _artifact_dir(), force=True)
        return 0 if rec.get("status") == "ok" else 1

    cfg = reduced(get_arch(args.arch)) if args.local else get_arch(args.arch)
    ctx = T.ParallelCtx(remat=False, q_block=32, kv_block=32, loss_chunk=32,
                        compute_dtype=jnp.float32)
    tcfg = TrainConfig(
        microbatches=args.microbatches, compute_dtype=jnp.float32,
        adamw=optim.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ds = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                 global_batch=args.global_batch))
    ckpt = ValetCheckpointer(args.ckpt_dir, replicas=2)

    def cb(step, params, opt_state, metrics):
        if step and step % 50 == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})

    params, opt_state, hist = fit(params, cfg, ctx, tcfg, ds,
                                  n_steps=args.steps, callback=cb)
    ckpt.save(args.steps, {"params": params, "opt": opt_state})
    ckpt.close()
    for h in hist:
        print(h)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
