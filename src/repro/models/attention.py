"""Attention: blockwise (flash-style) train/prefill path + decode paths.

Three implementations:

* ``reference_attention`` — materializes the (Sq, Sk) score matrix.  Oracle
  for tests only.
* ``blockwise_attention`` — flash-style online-softmax over KV blocks, pure
  jnp + ``lax.scan``.  Differentiable; never materializes (Sq, Sk).  Windowed
  attention visits only the statically-known band of KV blocks.  This is the
  path used for dry-runs and CPU execution; the Pallas kernel
  (``repro.kernels.flash_attention``) is the TPU fast path with identical
  semantics.
* ``decode_partial`` / ``combine_partials`` — flash-decoding: per-shard
  partial softmax over a slice of the KV working set (ring buffer or Valet
  page pool) plus an exact cross-shard combine.  This is how KV pages spread
  across "peer" devices (the paper's remote memory donors) are read with a
  single tiny collective.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _fold_gqa(q, n_kv):
    """(B, S, Hq, D) -> (B, S, Hkv, G, D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


# --------------------------------------------------------------------------
# Oracle
# --------------------------------------------------------------------------

def reference_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                        kv_valid=None):
    """Materialized-score attention.  Test oracle; O(Sq*Sk) memory.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D).
    ``q_offset``: global position of q[0] (for decode/chunked prefill).
    ``kv_valid``: optional (B, Sk) bool mask.
    """
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    qf = _fold_gqa(q, n_kv).astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qf, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_valid is not None:
        mask = mask[None] & kv_valid[:, None, :]
        mask = mask[:, None, None]                      # (B,1,1,Sq,Sk)
    else:
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Blockwise flash-style attention (train / prefill)
# --------------------------------------------------------------------------

def _block_mask(qpos, kpos, causal, window, kv_len):
    m = kpos[None, :] < kv_len
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def blockwise_attention(q, k, v, *, causal=True, window=0, q_block=512,
                        kv_block=512, q_offset=0):
    """Flash-style attention.  q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D).

    Windowed + causal attention slices only the statically-reachable KV band
    per q block: FLOPs are O(Sq * (window + q_block)) instead of O(Sq * Sk).
    Non-divisible lengths are padded internally and masked.
    """
    b, sq0, hq, d = q.shape
    sk0 = k.shape[1]
    q_block = min(q_block, sq0)
    kv_block = min(kv_block, sk0)
    qpad = (-sq0) % q_block
    kpad = (-sk0) % kv_block
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    out = _blockwise_padded(q, k, v, causal=causal, window=window,
                            q_block=q_block, kv_block=kv_block,
                            q_offset=q_offset, kv_len=sk0)
    return out[:, :sq0] if qpad else out


def _blockwise_padded(q, k, v, *, causal, window, q_block, kv_block,
                      q_offset, kv_len):
    b, sq, hq, d = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    nq = sq // q_block
    scale = 1.0 / math.sqrt(d)
    qf = _fold_gqa(q, n_kv)                             # (B,Sq,K,G,D)
    g = hq // n_kv

    if window > 0 and causal:
        # Static band: ceil(window / kv_block) blocks behind + the q block.
        band = (window + kv_block - 1) // kv_block * kv_block + q_block
        band = min(band, sk)

        def qblock_body(qi):
            qstart = qi * q_block
            qb = jax.lax.dynamic_slice_in_dim(qf, qstart, q_block, axis=1)
            kstart = jnp.clip(qstart + q_block - band, 0, sk - band)
            kb = jax.lax.dynamic_slice_in_dim(k, kstart, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kstart, band, axis=1)
            qpos = qstart + jnp.arange(q_block) + q_offset
            kpos = kstart + jnp.arange(band)
            mask = _block_mask(qpos, kpos, causal, window, kv_len)
            logits = jnp.einsum("bqkgd,btkd->bkgqt", qb.astype(jnp.float32),
                                kb.astype(jnp.float32)) * scale
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bkgqt,btkd->bqkgd", p, vb.astype(jnp.float32))
            return out.astype(q.dtype)

        outs = jax.lax.map(jax.checkpoint(qblock_body),
                           jnp.arange(nq))                  # (nq,B,qb,K,G,D)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, n_kv, g, d)
        return out.reshape(b, sq, hq, d)

    # Full (causal or bidirectional): online softmax over all KV blocks.
    nk = sk // kv_block

    def qblock_body(qi):
        qstart = qi * q_block
        qb = jax.lax.dynamic_slice_in_dim(qf, qstart, q_block, axis=1)
        qb = qb.astype(jnp.float32)
        qpos = qstart + jnp.arange(q_block) + q_offset

        def kv_body(carry, ki):
            m, l, acc = carry
            kstart = ki * kv_block
            kb = jax.lax.dynamic_slice_in_dim(k, kstart, kv_block, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, kstart, kv_block, axis=1)
            kpos = kstart + jnp.arange(kv_block)
            mask = _block_mask(qpos, kpos, causal, window, kv_len)
            logits = jnp.einsum("bqkgd,btkd->bkgqt", qb,
                                kb.astype(jnp.float32)) * scale
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, n_kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]         # (B,K,G,qb,D)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)       # (B,qb,K,G,D)

    # checkpoint per q block: the backward otherwise stacks the inner KV
    # scan's residuals across BOTH loops (nq x nk x block buffers)
    outs = jax.lax.map(jax.checkpoint(qblock_body), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, n_kv, g, d)
    return out.reshape(b, sq, hq, d)


# --------------------------------------------------------------------------
# Decode: partial softmax + exact combine (flash-decoding across peers)
# --------------------------------------------------------------------------

def decode_partial(q, keys, values, valid):
    """Partial attention of a single query over a local KV slice.

    q: (B, Hq, D); keys/values: (B, T, Hkv, D); valid: (B, T) bool.
    Returns (m, l, acc): (B,K,G), (B,K,G), (B,K,G,D) float32 partials.
    """
    b, hq, d = q.shape
    n_kv = keys.shape[2]
    qf = q.reshape(b, n_kv, hq // n_kv, d).astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bkgd,btkd->bkgt", qf,
                        keys.astype(jnp.float32)) * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(axis=-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p, values.astype(jnp.float32))
    return m, l, acc


def combine_partials(partials, out_dtype):
    """Exact softmax combine of stacked partials.

    partials: tuple of (m, l, acc) stacked on a leading shard axis:
    m,l: (N, B, K, G); acc: (N, B, K, G, D).  Returns (B, Hq, D).
    """
    m, l, acc = partials
    m_glob = m.max(axis=0)
    corr = jnp.exp(m - m_glob[None])
    l_glob = (l * corr).sum(axis=0)
    acc_glob = (acc * corr[..., None]).sum(axis=0)
    out = acc_glob / jnp.maximum(l_glob, 1e-20)[..., None]
    n, b = m.shape[0], m.shape[1]
    return out.reshape(b, -1, acc.shape[-1]).astype(out_dtype)


def combine_partials_psum(m, l, acc, axis_name, out_dtype):
    """Same combine, across a mesh axis inside shard_map (tiny collective)."""
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    acc_glob = jax.lax.psum(acc * corr[..., None], axis_name)
    out = acc_glob / jnp.maximum(l_glob, 1e-20)[..., None]
    b = m.shape[0]
    return out.reshape(b, -1, acc.shape[-1]).astype(out_dtype)
