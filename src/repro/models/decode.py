"""Decode path: per-layer caches (Valet paged pools / rings / SSM states)
plus an exact cache-building prefill.

Layers are unrolled (heterogeneous caches per layer kind), which keeps every
assigned arch on one code path:

  full-attention layer   -> paged KV pool (the Valet-managed working set)
  sliding-window layer   -> ring buffer (bounded; no paging needed)
  ssm layer              -> O(1) SSD + conv state
  hybrid layer           -> attention cache + SSD state
  cross-attn layer (vlm/audio) -> static per-request KV (pinned region)

The control plane (serve/engine.py) owns slot allocation; this module is the
pure data plane: given block tables + append targets it computes one decode
step.  All paged layers share one block table — a logical page allocation
spans every paged layer (slot i of each layer's pool).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import device_ops as dev
from repro.models import ssm as ssm_lib
from repro.models.attention import (blockwise_attention, decode_partial,
                                    combine_partials)
from repro.models.layers import apply_rope, rms_norm, swiglu, gelu_mlp
from repro.models.moe import moe_ffn
from repro.models.transformer import (ParallelCtx,
                                      segments,
                                      encoder_segments,
                                      unembed_matrix,
                                      mask_vocab_pad,
                                      _sinusoidal)


@dataclass(frozen=True)
class LayerInfo:
    kind: str
    window: int
    ffn: str
    d_ff: int
    seg: int
    idx: int

    @property
    def uses_paged(self):
        return self.kind in ("attn", "dec", "hybrid") and self.window == 0

    @property
    def uses_ring(self):
        return self.kind in ("attn", "hybrid") and self.window > 0

    @property
    def uses_ssm(self):
        return self.kind in ("ssm", "hybrid")

    @property
    def uses_cross(self):
        return self.kind in ("xattn", "dec")


def layer_infos(cfg: ArchConfig) -> List[LayerInfo]:
    out = []
    for si, seg in enumerate(segments(cfg)):
        for i in range(seg.count):
            out.append(LayerInfo(seg.kind, seg.window, seg.ffn,
                                 seg.d_ff or cfg.d_ff, si, i))
    return out


def layer_params(params, info: LayerInfo):
    return jax.tree.map(lambda a: a[info.idx], params["segments"][info.seg])


# --------------------------------------------------------------------------
# Cache init
# --------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, *, pool_slots: int, page: int,
                n_cross: int = 0, dtype=jnp.float32) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    layers = []
    for info in layer_infos(cfg):
        c: Dict[str, Any] = {}
        if info.uses_paged:
            c["pool"] = dev.make_kv_pool(pool_slots, page, cfg.n_kv_heads,
                                         hd, dtype)
        if info.uses_ring:
            c["ring"] = dev.make_ring(batch, info.window, cfg.n_kv_heads,
                                      hd, dtype)
        if info.uses_ssm:
            c["ssm"] = ssm_lib.ssm_init_state(batch, cfg.d_model, cfg.ssm,
                                              dtype)
        if info.uses_cross:
            n = n_cross or cfg.n_frontend_tokens
            c["cross_k"] = jnp.zeros((batch, n, cfg.n_kv_heads, hd), dtype)
            c["cross_v"] = jnp.zeros((batch, n, cfg.n_kv_heads, hd), dtype)
        layers.append(c)
    return {"layers": layers, "lengths": jnp.zeros((batch,), jnp.int32)}


# --------------------------------------------------------------------------
# Per-layer decode compute
# --------------------------------------------------------------------------

def _qkv_one(p, x, cfg, positions):
    """x: (B, d) -> q (B,Hq,hd), k,v (B,Hkv,hd), roped at ``positions``."""
    b, d = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bd,dh->bh", x, p["wq"]).reshape(b, cfg.n_heads, hd)
    k = jnp.einsum("bd,dh->bh", x, p["wk"]).reshape(b, cfg.n_kv_heads, hd)
    v = jnp.einsum("bd,dh->bh", x, p["wv"]).reshape(b, cfg.n_kv_heads, hd)
    if cfg.rope_theta > 0:
        q = apply_rope(q[:, None], positions[:, None],
                       cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], positions[:, None],
                       cfg.rope_theta)[:, 0]
    return q, k, v


def _attn_out(p, out, b):
    return jnp.einsum("bh,hd->bd", out.reshape(b, -1), p["wo"])


def _paged_attn_step(p, x, cache, cfg, step_args):
    """Full-attention decode over the Valet page pool."""
    b = x.shape[0]
    lengths = step_args["lengths"]
    q, k, v = _qkv_one(p, x, cfg, lengths)
    pool = dev.append_token_masked(cache["pool"], k, v,
                                   step_args["append_slot"],
                                   step_args["append_off"],
                                   step_args["active"])
    keys, values, pvalid = dev.gather_pages(pool, step_args["block_table"])
    page = keys.shape[2]
    np_ = keys.shape[1]
    keys = keys.reshape(b, np_ * page, cfg.n_kv_heads, -1)
    values = values.reshape(b, np_ * page, cfg.n_kv_heads, -1)
    pos = jnp.arange(np_ * page)[None]
    valid = (pos <= lengths[:, None]) & jnp.repeat(pvalid, page, axis=1)
    m, l, acc = decode_partial(q, keys, values, valid)
    out = combine_partials((m[None], l[None], acc[None]), x.dtype)
    return _attn_out(p, out, b), {**cache, "pool": pool}


def _ring_attn_step(p, x, cache, cfg, step_args, window):
    b = x.shape[0]
    lengths = step_args["lengths"]
    q, k, v = _qkv_one(p, x, cfg, lengths)
    ring = cache["ring"]
    w = ring.k.shape[1]
    idx = lengths % w
    ring = dev.RingKV(ring.k.at[jnp.arange(b), idx].set(k),
                      ring.v.at[jnp.arange(b), idx].set(v))
    # validity: slot j holds absolute position p_j with p_j = j + w*floor(...)
    # valid iff p_j <= length and p_j > length - window
    slot = jnp.arange(w)[None]
    cur = lengths[:, None]
    abs_pos = cur - ((cur - slot) % w)          # latest absolute pos in slot j
    valid = (abs_pos >= 0) & (abs_pos <= cur) & (abs_pos > cur - window)
    m, l, acc = decode_partial(q, ring.k, ring.v, valid)
    out = combine_partials((m[None], l[None], acc[None]), x.dtype)
    return _attn_out(p, out, b), {**cache, "ring": ring}


def _cross_attn_step(p, x, cache, cfg):
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bd,dh->bh", x, p["wq"]).reshape(b, cfg.n_heads, hd)
    valid = jnp.ones(cache["cross_k"].shape[:2], bool)
    m, l, acc = decode_partial(q, cache["cross_k"], cache["cross_v"], valid)
    out = combine_partials((m[None], l[None], acc[None]), x.dtype)
    return _attn_out(p, out, b)


def _ffn_step(p, x, cfg, info: LayerInfo, ctx):
    if info.ffn == "moe":
        out, _ = moe_ffn(p["moe"], x[:, None, :], cfg.moe, mesh=ctx.mesh,
                         model_axis=ctx.model_axis)
        return out[:, 0, :]
    if info.ffn == "gelu":
        return gelu_mlp(p["mlp"], x)
    return swiglu(p["mlp"], x)


def decode_layer(p, x, info: LayerInfo, cache, cfg: ArchConfig,
                 ctx: ParallelCtx, step_args):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)

    if info.kind in ("attn", "dec"):
        if info.uses_paged:
            a, new_cache = _paged_attn_step(p["attn"], h, new_cache, cfg,
                                            step_args)
        else:
            a, new_cache = _ring_attn_step(p["attn"], h, new_cache, cfg,
                                           step_args, info.window)
        x = x + a
        if info.kind == "dec":
            hx = rms_norm(p["lnx"], x, cfg.norm_eps)
            x = x + _cross_attn_step(p["xattn"], hx, new_cache, cfg)
    elif info.kind == "xattn":
        gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
        x = x + gate * _cross_attn_step(p["xattn"], h, new_cache, cfg)
    elif info.kind == "ssm":
        y, st = ssm_lib.ssm_decode_step(p["ssm"], h, cache["ssm"],
                                        cfg.d_model, cfg.ssm)
        new_cache["ssm"] = st
        x = x + y
    elif info.kind == "hybrid":
        if info.uses_paged:
            a, new_cache = _paged_attn_step(p["attn"], h, new_cache, cfg,
                                            step_args)
        else:
            a, new_cache = _ring_attn_step(p["attn"], h, new_cache, cfg,
                                           step_args, info.window)
        y, st = ssm_lib.ssm_decode_step(p["ssm"], h, cache["ssm"],
                                        cfg.d_model, cfg.ssm)
        new_cache["ssm"] = st
        x = x + 0.5 * (rms_norm(p["attn_norm"], a, cfg.norm_eps)
                       + rms_norm(p["ssm_norm"], y, cfg.norm_eps))

    if info.ffn != "none":
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        x = x + _ffn_step(p, h2, cfg, info, ctx)
    return x, new_cache


def decode_step(params, caches, tokens, cfg: ArchConfig, ctx: ParallelCtx,
                block_table, append_slot, append_off, active=None):
    """One decode step.  tokens: (B,) int32.  Returns (logits, caches).

    ``active``: (B,) bool — inactive batch slots neither append KV nor
    advance their length (continuous batching with holes).
    """
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    if cfg.family == "audio":
        # sinusoidal position at each sequence's current length
        d = cfg.d_model
        posf = caches["lengths"].astype(jnp.float32)[:, None]
        i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
        ang = posf / (10_000.0 ** (2 * i / d))
        x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                                axis=-1).astype(x.dtype)

    if active is None:
        active = jnp.ones(tokens.shape, bool)
    step_args = {
        "lengths": caches["lengths"],
        "block_table": block_table,
        "append_slot": append_slot,
        "append_off": append_off,
        "active": active,
    }
    new_layers = []
    infos = layer_infos(cfg)
    for info, cache in zip(infos, caches["layers"]):
        p = layer_params(params, info)
        x, cache = decode_layer(p, x, info, cache, cfg, ctx, step_args)
        new_layers.append(cache)

    x = rms_norm(params["final_ln"], x, cfg.norm_eps)
    w = unembed_matrix(params, cfg).astype(x.dtype)
    logits = mask_vocab_pad(
        jnp.einsum("bd,dv->bv", x, w).astype(jnp.float32), cfg)
    new_len = caches["lengths"] + active.astype(jnp.int32)
    return logits, {"layers": new_layers, "lengths": new_len}


# --------------------------------------------------------------------------
# Cache-building prefill (exact, unrolled)
# --------------------------------------------------------------------------

def prefill(params, tokens, cfg: ArchConfig, ctx: ParallelCtx, caches,
            block_table, frontend=None):
    """Run the prompt through the model, filling every cache.

    tokens: (B, S) — equal prompt lengths per prefill batch (engine pads).
    block_table: (B, P) pre-allocated slots for ceil(S/page) pages (plus the
    current partial page).  Returns (last_logits, caches).
    """
    b, s = tokens.shape
    hd = cfg.resolved_head_dim
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    if cfg.family == "audio":
        x = x + _sinusoidal(s, cfg.d_model).astype(x.dtype)

    enc_out = None
    if cfg.family == "audio":
        assert frontend is not None
        from repro.models.transformer import run_segments
        e = frontend.astype(ctx.compute_dtype)
        e = e + _sinusoidal(e.shape[1], cfg.d_model).astype(e.dtype)
        e, _ = run_segments(params["enc_segments"], encoder_segments(cfg),
                            e, cfg, ctx)
        enc_out = rms_norm(params["enc_ln"], e, cfg.norm_eps)
    elif frontend is not None:
        enc_out = frontend.astype(ctx.compute_dtype)

    positions = jnp.arange(s)[None]
    new_layers = []
    for info, cache in zip(layer_infos(cfg), caches["layers"]):
        p = layer_params(params, info)
        cache = dict(cache)
        h = rms_norm(p["ln1"], x, cfg.norm_eps)

        if info.kind in ("attn", "dec", "hybrid"):
            ap = p["attn"]
            q = jnp.einsum("bsd,dh->bsh", h, ap["wq"]).reshape(
                b, s, cfg.n_heads, hd)
            k = jnp.einsum("bsd,dh->bsh", h, ap["wk"]).reshape(
                b, s, cfg.n_kv_heads, hd)
            v = jnp.einsum("bsd,dh->bsh", h, ap["wv"]).reshape(
                b, s, cfg.n_kv_heads, hd)
            if cfg.rope_theta > 0:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            a = blockwise_attention(q, k, v, causal=True, window=info.window,
                                    q_block=ctx.q_block,
                                    kv_block=ctx.kv_block)
            a = jnp.einsum("bsh,hd->bsd", a.reshape(b, s, -1), ap["wo"])

            if info.uses_paged:
                page = cache["pool"].k.shape[1]
                pad = (-s) % page
                kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                npages = kp.shape[1] // page
                kp = kp.reshape(b, npages, page, cfg.n_kv_heads, hd)
                vp = vp.reshape(b, npages, page, cfg.n_kv_heads, hd)
                cache["pool"] = dev.write_prefill_pages(
                    cache["pool"], kp, vp, block_table[:, :npages])
            if info.uses_ring:
                w = cache["ring"].k.shape[1]
                ring = cache["ring"]
                take = min(w, s)
                tail = jnp.arange(s - take, s)
                ring = dev.RingKV(
                    ring.k.at[:, tail % w].set(k[:, tail]),
                    ring.v.at[:, tail % w].set(v[:, tail]))
                cache["ring"] = ring

        if info.kind in ("attn", "dec"):
            x = x + a
            if info.kind == "dec":
                hx = rms_norm(p["lnx"], x, cfg.norm_eps)
                xp = p["xattn"]
                cache["cross_k"] = jnp.einsum(
                    "bnd,dh->bnh", enc_out, xp["wk"]).reshape(
                        b, -1, cfg.n_kv_heads, hd)
                cache["cross_v"] = jnp.einsum(
                    "bnd,dh->bnh", enc_out, xp["wv"]).reshape(
                        b, -1, cfg.n_kv_heads, hd)
                qx = jnp.einsum("bsd,dh->bsh", hx, xp["wq"]).reshape(
                    b, s, cfg.n_heads, hd)
                ax = blockwise_attention(qx, cache["cross_k"],
                                         cache["cross_v"], causal=False,
                                         q_block=min(256, s))
                x = x + jnp.einsum("bsh,hd->bsd", ax.reshape(b, s, -1),
                                   xp["wo"])
        elif info.kind == "xattn":
            xp = p["xattn"]
            cache["cross_k"] = jnp.einsum(
                "bnd,dh->bnh", enc_out, xp["wk"]).reshape(
                    b, -1, cfg.n_kv_heads, hd)
            cache["cross_v"] = jnp.einsum(
                "bnd,dh->bnh", enc_out, xp["wv"]).reshape(
                    b, -1, cfg.n_kv_heads, hd)
            qx = jnp.einsum("bsd,dh->bsh", h, xp["wq"]).reshape(
                b, s, cfg.n_heads, hd)
            ax = blockwise_attention(qx, cache["cross_k"], cache["cross_v"],
                                     causal=False, q_block=min(256, s))
            gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * jnp.einsum("bsh,hd->bsd", ax.reshape(b, s, -1),
                                      xp["wo"])
        elif info.kind == "ssm":
            y, st = ssm_lib.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm,
                                        return_state=True)
            cache["ssm"] = st
            x = x + y
        elif info.kind == "hybrid":
            y, st = ssm_lib.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm,
                                        return_state=True)
            cache["ssm"] = st
            x = x + 0.5 * (rms_norm(p["attn_norm"], a, cfg.norm_eps)
                           + rms_norm(p["ssm_norm"], y, cfg.norm_eps))

        if info.ffn != "none":
            h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
            x = x + _ffn_step(p, h2.reshape(b * s, -1), cfg, info,
                              ctx).reshape(b, s, -1)
        new_layers.append(cache)

    x = rms_norm(params["final_ln"], x, cfg.norm_eps)
    w = unembed_matrix(params, cfg).astype(x.dtype)
    logits = mask_vocab_pad(
        jnp.einsum("bd,dv->bv", x[:, -1], w).astype(jnp.float32), cfg)
    return logits, {"layers": new_layers,
                    "lengths": jnp.full((b,), s, jnp.int32)}
