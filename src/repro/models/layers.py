"""Primitive layers: init helpers, RMSNorm, RoPE, SwiGLU.

Parameters are plain pytrees (nested dicts of jnp arrays).  All layers are
pure functions ``f(params, x, ...) -> y`` so they compose with ``jax.lax.scan``
over stacked per-layer parameters and with pjit/shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


class KeyGen:
    """Split an rng key on demand: ``kg = KeyGen(key); w = init(kg(), ...)``."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(w, x, eps=1e-5):
    """RMSNorm in fp32, output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x, positions, theta: float):
    """Rotate pairs. x: (..., S, H, D); positions: (..., S) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                    # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def swiglu(params, x):
    """SwiGLU FFN.  params: wgu (d, 2f) fused gate+up, wd (f, d).

    The fused projection means ONE dot (and one backward dx all-reduce under
    tensor parallelism) instead of two — §Perf iteration 5.  The (gate, up)
    halves are interleaved per shard: wgu[:, 0::2]=gate, wgu[:, 1::2]=up so
    a TP shard of the fused dim contains matching gate/up pairs.
    """
    gu = jnp.einsum("...d,df->...f", x, params["wgu"])
    gu = gu.reshape(gu.shape[:-1] + (-1, 2))
    g, u = gu[..., 0], gu[..., 1]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["wd"])


def init_swiglu(kg: KeyGen, d: int, f: int, dtype=jnp.float32):
    return {
        "wgu": normal_init(kg(), (d, 2 * f), dtype=dtype),
        "wd": normal_init(kg(), (f, d), dtype=dtype),
    }


def gelu_mlp(params, x):
    """Plain GELU MLP (whisper-style).  params: wi (d,f), wo (f,d)."""
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


def init_gelu_mlp(kg: KeyGen, d: int, f: int, dtype=jnp.float32):
    return {
        "wi": normal_init(kg(), (d, f), dtype=dtype),
        "wo": normal_init(kg(), (f, d), dtype=dtype),
    }
