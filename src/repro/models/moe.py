"""Mixture-of-experts FFN with expert parallelism.

Two paths:

* ``moe_ffn_reference`` — exact loop-over-experts oracle (no capacity drops).
* ``moe_ffn`` — capacity-bounded sort-based dispatch.  Under a mesh it runs
  inside ``shard_map`` with experts partitioned over the ``model`` axis (EP):
  tokens are TP-replicated, each rank dispatches only the tokens routed to
  *its* experts, and the combine ``psum`` doubles as the Megatron-TP
  all-reduce.  No all-to-all is needed because activations are already
  model-replicated at the FFN boundary.

Shared experts (deepseek/qwen style) run as one fused SwiGLU outside the
shard_map region; GSPMD shards them over d_ff like a dense FFN.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models.layers import KeyGen, normal_init, swiglu, init_swiglu


def padded_experts(moe: MoEConfig, ep_align: int = 16) -> int:
    """Expert-table size padded so EP shards cleanly (qwen: 60 -> 64).

    Padding experts are never routed to (router has n_experts logits)."""
    return -(-moe.n_experts // ep_align) * ep_align


def init_moe(kg: KeyGen, d: int, moe: MoEConfig, dtype=jnp.float32):
    e_pad = padded_experts(moe)
    params = {
        "router": normal_init(kg(), (d, moe.n_experts), scale=0.006, dtype=jnp.float32),
        "experts": {
            "wg": normal_init(kg(), (e_pad, d, moe.d_expert), dtype=dtype),
            "wu": normal_init(kg(), (e_pad, d, moe.d_expert), dtype=dtype),
            "wd": normal_init(kg(), (e_pad, moe.d_expert, d), dtype=dtype),
        },
    }
    if moe.n_shared:
        params["shared"] = init_swiglu(kg, d, moe.n_shared * moe.d_expert, dtype)
    return params


def router_topk(params, x, moe: MoEConfig):
    """Router probabilities + top-k selection + aux losses.

    x: (T, d).  Returns (eids (T,k) int32, gates (T,k) f32, aux_loss scalar).
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, moe.top_k)
    if moe.renorm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux: E * sum_e (frac tokens to e) * (mean prob of e)
    e = moe.n_experts
    ind = jax.nn.one_hot(eids, e, dtype=jnp.float32).sum(1)          # (T,E)
    f_e = ind.mean(0) / moe.top_k
    p_e = probs.mean(0)
    aux = e * jnp.sum(f_e * p_e) * moe.router_aux_coef
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * moe.router_z_coef
    return eids, gates, aux + zloss


def moe_ffn_reference(params, x, moe: MoEConfig):
    """Exact oracle: every expert applied to every token, masked combine."""
    t, d = x.shape
    eids, gates, aux = router_topk(params, x, moe)
    out = jnp.zeros((t, d), jnp.float32)
    for e in range(moe.n_experts):
        g = jnp.einsum("td,df->tf", x, params["experts"]["wg"][e])
        u = jnp.einsum("td,df->tf", x, params["experts"]["wu"][e])
        he = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ye = jnp.einsum("tf,fd->td", he,
                        params["experts"]["wd"][e]).astype(jnp.float32)
        w = jnp.where(eids == e, gates, 0.0).sum(-1)                 # (T,)
        out = out + w[:, None] * ye
    if moe.n_shared:
        out = out + swiglu(params["shared"], x).astype(jnp.float32)
    return out.astype(x.dtype), aux


def _dispatch_local(x, eids, gates, wg, wu, wd, *, e_base, e_local, cap):
    """Capacity-bounded dispatch of tokens to the local expert shard.

    x: (T, d); eids/gates: (T, k); w*: (E_loc, ...); returns (T, d) partial.
    """
    t, d = x.shape
    k = eids.shape[1]
    flat_e = eids.reshape(-1) - e_base                               # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gates.reshape(-1)
    valid = (flat_e >= 0) & (flat_e < e_local)

    # stable sort by local expert; invalid entries pushed to the end
    order = jnp.argsort(jnp.where(valid, flat_e, e_local), stable=True)
    sel = order[: e_local * cap]
    e_sel = jnp.where(valid[sel], flat_e[sel], e_local)              # pad bin
    t_sel = flat_t[sel]
    g_sel = jnp.where(valid[sel], flat_g[sel], 0.0)

    # position within each expert group (entries already grouped)
    onehot = jax.nn.one_hot(e_sel, e_local, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                        # (C, E_loc)
    pos_sel = jnp.take_along_axis(
        pos, jnp.minimum(e_sel, e_local - 1)[:, None], axis=1)[:, 0]
    keep = (e_sel < e_local) & (pos_sel < cap)
    g_sel = jnp.where(keep, g_sel, 0.0)
    slot_e = jnp.where(keep, e_sel, 0)
    slot_p = jnp.where(keep, pos_sel, cap)                           # cap = pad row

    buf = jnp.zeros((e_local, cap + 1, d), x.dtype)
    buf = buf.at[slot_e, slot_p].set(x[t_sel])

    h = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd)                            # (E_loc,C+1,d)

    out = jnp.zeros((t, d), jnp.float32)
    vals = y[slot_e, slot_p].astype(jnp.float32) * g_sel[:, None]
    out = out.at[t_sel].add(jnp.where(keep[:, None], vals, 0.0))
    return out


def _shard_map(fn, mesh, in_specs, out_specs):
    """Version-tolerant shard_map wrapper (location + check_vma/check_rep)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:                         # jax < 0.5: experimental namespace
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, check_vma=False)
    except TypeError:
        return sm(fn, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, check_rep=False)


def moe_ffn(params, x, moe: MoEConfig, *, mesh=None, model_axis="model",
            dp_spec=P()):
    """Routed + shared expert FFN.  x: (B, S, d) (or (T, d)).

    With ``mesh``: experts are sharded over ``model_axis`` inside shard_map
    (EP).  Router + aux loss run *outside* under GSPMD (data-sharded, tiny);
    dispatch indices enter the shard_map region data-sharded.
    Without mesh: single-shard capacity-bounded dispatch (same code path).
    """
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    b, s, d = x.shape

    # Router (replicated weights, data-sharded activations) + aux loss.
    eids, gates, aux = router_topk(params, x.reshape(-1, d), moe)
    eids = eids.reshape(b, s, moe.top_k)
    gates = gates.reshape(b, s, moe.top_k)

    ep = 1 if mesh is None else mesh.shape[model_axis]
    dp = 1
    if mesh is not None and dp_spec and dp_spec[0] is not None:
        axes = dp_spec[0] if isinstance(dp_spec[0], tuple) else (dp_spec[0],)
        for a in axes:
            dp *= mesh.shape[a]
    t_local = (b // dp) * s
    cap = max(int(t_local * moe.top_k / moe.n_experts * moe.capacity_factor), 8)
    e_pad = params["experts"]["wg"].shape[0]
    e_local = e_pad // ep if e_pad % ep == 0 else -(-moe.n_experts // ep)

    def local_fn(xb, eb, gb, wg, wu, wd):
        # xb: (b_loc, s, d) model-replicated; w*: (E_loc, ...) local shard
        xt = xb.reshape(-1, d)
        rank = jax.lax.axis_index(model_axis) if mesh is not None else 0
        out = _dispatch_local(xt, eb.reshape(-1, moe.top_k),
                              gb.reshape(-1, moe.top_k), wg, wu, wd,
                              e_base=rank * e_local, e_local=e_local, cap=cap)
        if mesh is not None:
            out = jax.lax.psum(out, model_axis)
        return out.reshape(xb.shape).astype(xb.dtype)

    wg, wu, wd = (params["experts"][n] for n in ("wg", "wu", "wd"))
    if mesh is not None:
        pad = e_local * ep - wg.shape[0]
        if pad > 0:
            wg = jnp.pad(wg, ((0, pad), (0, 0), (0, 0)))
            wu = jnp.pad(wu, ((0, pad), (0, 0), (0, 0)))
            wd = jnp.pad(wd, ((0, pad), (0, 0), (0, 0)))
        kspec = P(dp_spec[0] if dp_spec else None, None, None)
        out = _shard_map(
            local_fn, mesh,
            (kspec, kspec, kspec, P(model_axis), P(model_axis), P(model_axis)),
            kspec,
        )(x, eids, gates, wg, wu, wd)
    else:
        out = local_fn(x, eids, gates, wg, wu, wd)

    if moe.n_shared:
        out = out + swiglu(params["shared"], x)
    if squeeze:
        out = out[0]
    return out, aux
