"""Mamba-2 (SSD, state-space duality) block — TPU-friendly chunked form.

The SSD recurrence  h_t = a_t * h_{t-1} + dt_t * B_t x_t^T ,
                    y_t = C_t h_t + D x_t
(with per-head scalar decay a_t = exp(dt_t * A_h)) is computed chunk-wise:
quadratic *within* a chunk (MXU-friendly matmuls) and a tiny per-chunk state
recurrence *across* chunks (``lax.scan``).  This is the hardware adaptation of
SSD for TPUs: the intra-chunk part is the Pallas kernel target
(``repro.kernels.ssd_scan``); this file is the jnp implementation used as the
oracle and the dry-run path.

Projections are stored split (wz / wx / wbc / wdt) so each shards cleanly
over the TP (`model`) axis: z/x/dt by heads, B/C replicated (tiny).

Decode keeps O(1) state per layer: (H, P, N) SSD state + a (K-1)-deep conv
ring — the reason `long_500k` is runnable for SSM archs (DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import KeyGen, normal_init, rms_norm


def ssm_dims(d_model: int, ssm: SSMConfig):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    d_bc = 2 * ssm.n_groups * ssm.d_state
    return d_inner, n_heads, d_bc


def init_ssm(kg: KeyGen, d_model: int, ssm: SSMConfig, dtype=jnp.float32):
    d_inner, n_heads, d_bc = ssm_dims(d_model, ssm)
    return {
        "wz": normal_init(kg(), (d_model, d_inner), dtype=dtype),
        "wx": normal_init(kg(), (d_model, d_inner), dtype=dtype),
        "wbc": normal_init(kg(), (d_model, d_bc), dtype=dtype),
        "wdt": normal_init(kg(), (d_model, n_heads), dtype=dtype),
        "conv_x": normal_init(kg(), (ssm.conv_kernel, d_inner), scale=0.5,
                              dtype=dtype),
        "conv_bc": normal_init(kg(), (ssm.conv_kernel, d_bc), scale=0.5,
                               dtype=dtype),
        "conv_b": jnp.zeros((d_inner + d_bc,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, n_heads))).astype(jnp.float32),
        "gate_norm": jnp.zeros((d_inner,), dtype),
        "out_proj": normal_init(kg(), (d_inner, d_model), dtype=dtype),
    }


def _project(params, x):
    z = jnp.einsum("...d,di->...i", x, params["wz"])
    xs = jnp.einsum("...d,di->...i", x, params["wx"])
    bc = jnp.einsum("...d,di->...i", x, params["wbc"])
    dt = jnp.einsum("...d,dh->...h", x, params["wdt"])
    return z, xs, bc, dt


def _causal_conv(w, b, x, kernel):
    """Depthwise causal conv over (B, S, C)."""
    pad = jnp.pad(x, ((0, 0), (kernel - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(kernel))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, A, B_mat, C_mat, D, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    B_mat/C_mat: (B,S,G,N); D: (H,).  Returns y (B,S,H,P), h_final (B,H,P,N).
    """
    b, s, h, p = x.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    heads_per_group = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B_mat.reshape(b, nc, chunk, g, n)
    Cc = C_mat.reshape(b, nc, chunk, g, n)

    # per-token log decay and within-chunk cumulative decay
    l = dtc * A[None, None, None, :]                       # (B,NC,Q,H) <= 0
    Lc = jnp.cumsum(l, axis=2)                             # (B,NC,Q,H)
    Ltot = Lc[:, :, -1, :]                                 # (B,NC,H)

    # ---- intra-chunk (diagonal blocks), batched over chunks ---------------
    cb = jnp.einsum("bcqgn,bcsgn->bcgqs", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                # (B,NC,G,Q,Q)
    cb = jnp.repeat(cb, heads_per_group, axis=2)           # (B,NC,H,Q,Q)
    lt = jnp.moveaxis(Lc, 3, 2)                            # (B,NC,H,Q)
    decay = jnp.exp(lt[..., :, None] - lt[..., None, :])   # exp(L[t]-L[s])
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(mask[None, None, None], cb * decay, 0.0)
    m = m * jnp.moveaxis(dtc, 3, 2)[..., None, :]          # * dt_s
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", m, xc.astype(jnp.float32))

    # ---- chunk input states ----------------------------------------------
    dstate = jnp.exp(Ltot[:, :, None, :] - Lc)             # (B,NC,Q,H)
    Bh = jnp.repeat(Bc, heads_per_group, axis=3)           # (B,NC,Q,H,N)
    s_in = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn",
                      Bh.astype(jnp.float32), xc.astype(jnp.float32),
                      dtc * dstate)                        # (B,NC,H,P,N)

    # ---- inter-chunk recurrence (tiny scan over chunks) -------------------
    def body(hprev, inp):
        s_c, ltot = inp                                    # (B,H,P,N), (B,H)
        hnew = hprev * jnp.exp(ltot)[:, :, None, None] + s_c
        return hnew, hprev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hT, hprevs = jax.lax.scan(
        body,
        h0,
        (jnp.moveaxis(s_in, 1, 0), jnp.moveaxis(Ltot, 1, 0)),
    )
    hprevs = jnp.moveaxis(hprevs, 0, 1)                    # (B,NC,H,P,N)

    # ---- inter-chunk contribution -----------------------------------------
    Ch = jnp.repeat(Cc, heads_per_group, axis=3)           # (B,NC,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Ch.astype(jnp.float32), hprevs, jnp.exp(Lc))

    y = y_intra + y_inter + D[None, None, None, :, None] * xc.astype(jnp.float32)
    return y.reshape(b, s, h, p), hT


def ssm_forward(params, x, d_model, ssm: SSMConfig, return_state=False):
    """Full SSD mixer over a sequence.  x: (B,S,d_model)."""
    b, s, _ = x.shape
    d_inner, n_heads, d_bc = ssm_dims(d_model, ssm)
    g, n = ssm.n_groups, ssm.d_state

    z, xs, bc, dt = _project(params, x)
    xbc_raw = jnp.concatenate([xs, bc], axis=-1)
    xbc = xbc_raw
    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]], axis=-1)
    xbc = _causal_conv(conv_w, params["conv_b"], xbc, ssm.conv_kernel)
    xs = xbc[..., :d_inner].reshape(b, s, n_heads, ssm.head_dim)
    B_mat = xbc[..., d_inner:d_inner + g * n].reshape(b, s, g, n)
    C_mat = xbc[..., d_inner + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    chunk = min(ssm.chunk_size, s)
    pad = (-s) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    y, hT = ssd_chunked(xs, dt, A, B_mat, C_mat, params["D"], chunk)
    y = y[:, :s].reshape(b, s, d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(params["gate_norm"], y)
    out = jnp.einsum("...i,id->...d", y, params["out_proj"])
    if not return_state:
        return out
    # decode-ready state: SSD state (padding tokens contribute ~0 via dt=0
    # only if pad==0; callers prefill with exact chunk multiples or accept
    # the tail) + conv ring of the last (K-1) raw xBC inputs
    k = ssm.conv_kernel
    conv_state = jnp.zeros((b, k - 1, d_inner + d_bc), x.dtype)
    take = min(k - 1, s)
    conv_state = conv_state.at[:, k - 1 - take:].set(xbc_raw[:, s - take:])
    return out, {"h": hT, "conv": conv_state}


# --------------------------------------------------------------------------
# Decode: O(1) state per layer
# --------------------------------------------------------------------------

def ssm_init_state(batch, d_model, ssm: SSMConfig, dtype=jnp.float32):
    d_inner, n_heads, d_bc = ssm_dims(d_model, ssm)
    return {
        "h": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, ssm.conv_kernel - 1, d_inner + d_bc), dtype),
    }


def ssm_decode_step(params, x, state, d_model, ssm: SSMConfig):
    """One-token step.  x: (B, d_model).  Returns (y, new_state)."""
    b = x.shape[0]
    d_inner, n_heads, d_bc = ssm_dims(d_model, ssm)
    g, n = ssm.n_groups, ssm.d_state

    z, xs, bc, dt = _project(params, x)
    xbc = jnp.concatenate([xs, bc], axis=-1)
    hist = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    conv_w = jnp.concatenate([params["conv_x"], params["conv_bc"]], axis=-1)
    conv = jnp.einsum("bkc,kc->bc", hist, conv_w) + params["conv_b"]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    new_conv = hist[:, 1:, :]

    xs = conv[..., :d_inner].reshape(b, n_heads, ssm.head_dim)
    B_mat = conv[..., d_inner:d_inner + g * n].reshape(b, g, n)
    C_mat = conv[..., d_inner + g * n:].reshape(b, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])

    heads_per_group = n_heads // g
    Bh = jnp.repeat(B_mat, heads_per_group, axis=1)        # (B,H,N)
    Ch = jnp.repeat(C_mat, heads_per_group, axis=1)

    a = jnp.exp(dt * A[None, :])                           # (B,H)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32), Bh.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(params["gate_norm"], y)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"])
    return out, {"h": h, "conv": new_conv}
