"""Model assembly: segment-based layer stacks for all 10 assigned archs.

An architecture is a list of ``Segment``s — homogeneous runs of layers that
are scanned with ``lax.scan`` over stacked parameters.  Heterogeneous
patterns (gemma3's 5:1 local:global, hymba's 3 global layers, llama-vision's
every-5th cross-attention layer, whisper's enc/dec) become short segment
lists, so the compiled HLO stays O(#segments), not O(#layers).

Everything is a pure function of a parameter pytree; sharding is expressed
with ``PartitionSpec`` rules keyed on parameter paths (``param_pspecs``) plus
activation constraints at segment boundaries.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    KeyGen, normal_init, rms_norm, apply_rope, swiglu, init_swiglu,
    gelu_mlp, init_gelu_mlp,
)


# --------------------------------------------------------------------------
# Parallel context
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelCtx:
    """Mesh + axis names + model-execution knobs."""
    mesh: Any = None
    dp_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    remat: bool = True
    q_block: int = 512
    kv_block: int = 512
    loss_chunk: int = 256
    compute_dtype: Any = jnp.float32
    attn_impl: str = "reference"          # reference | pallas
    seq_parallel: bool = False            # shard residuals on S over model
                                          # (refuted for train: §Perf iter 2)
    save_collectives: bool = False        # remat policy: save attn/mlp
                                          # outputs so backward skips
                                          # re-running their collectives

    def residual_spec(self):
        """Layer-boundary activation sharding (B, S, d)."""
        return (self.dp, self.model_axis if self.seq_parallel else None,
                None)

    @property
    def dp(self):
        """Leading batch mesh axes as a PartitionSpec entry."""
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def dp_size(self):
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n


def shard(x, ctx: ParallelCtx, *spec):
    if ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


# --------------------------------------------------------------------------
# Segments
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    kind: str            # attn | ssm | hybrid | xattn | enc | dec
    count: int
    window: int = 0      # 0 = full attention
    ffn: str = "swiglu"  # swiglu | moe | gelu | none
    d_ff: int = 0        # 0 -> cfg.d_ff


def segments(cfg: ArchConfig) -> List[Segment]:
    return [s for s in _segments(cfg) if s.count > 0]


def _segments(cfg: ArchConfig) -> List[Segment]:
    if cfg.family == "ssm":
        return [Segment("ssm", cfg.n_layers, ffn="none")]

    if cfg.family == "moe":
        segs = []
        if cfg.n_dense_layers:
            segs.append(Segment("attn", cfg.n_dense_layers, ffn="swiglu",
                                d_ff=cfg.dense_d_ff))
        segs.append(Segment("attn", cfg.n_layers - cfg.n_dense_layers,
                            ffn="moe"))
        return segs

    if cfg.family == "hybrid":
        # hymba: global full attention at layers {0, mid, last}, SWA elsewhere
        l = cfg.n_layers
        mid = l // 2 - 1
        segs = [Segment("hybrid", 1, window=0)]
        segs.append(Segment("hybrid", mid - 1, window=cfg.window))
        segs.append(Segment("hybrid", 1, window=0))
        segs.append(Segment("hybrid", l - mid - 2, window=cfg.window))
        segs.append(Segment("hybrid", 1, window=0))
        return segs

    if cfg.family == "vlm":
        # every 5th layer is a gated cross-attention layer
        segs = []
        n_groups = cfg.n_layers // cfg.xattn_every
        for _ in range(n_groups):
            segs.append(Segment("attn", cfg.xattn_every - 1))
            segs.append(Segment("xattn", 1))
        rem = cfg.n_layers - n_groups * cfg.xattn_every
        if rem:
            segs.append(Segment("attn", rem))
        return segs

    if cfg.family == "audio":
        return [Segment("dec", cfg.n_layers, ffn="gelu")]

    # dense: uniform or local:global interleave
    if cfg.global_every:
        per = cfg.global_every
        segs = []
        full_groups = cfg.n_layers // per
        for _ in range(full_groups):
            segs.append(Segment("attn", per - 1, window=cfg.window))
            segs.append(Segment("attn", 1, window=0))
        rem = cfg.n_layers - full_groups * per
        if rem > 1:
            segs.append(Segment("attn", rem - 1, window=cfg.window))
        if rem >= 1:
            segs.append(Segment("attn", 1, window=0))
        return segs
    return [Segment("attn", cfg.n_layers, window=cfg.window)]


def encoder_segments(cfg: ArchConfig) -> List[Segment]:
    assert cfg.family == "audio"
    return [Segment("enc", cfg.encoder_layers, ffn="gelu")]


# --------------------------------------------------------------------------
# Init (one layer), then stacked per segment
# --------------------------------------------------------------------------

def _init_attn_proj(kg, cfg: ArchConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": normal_init(kg(), (d, cfg.n_heads * hd), dtype=dtype),
        "wk": normal_init(kg(), (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": normal_init(kg(), (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": normal_init(kg(), (cfg.n_heads * hd, d),
                          scale=0.02 / math.sqrt(2 * cfg.n_layers), dtype=dtype),
    }


def _init_ffn(kg, cfg: ArchConfig, seg: Segment, dtype):
    d = cfg.d_model
    if seg.ffn == "moe":
        return {"moe": moe_lib.init_moe(kg, d, cfg.moe, dtype)}
    if seg.ffn == "gelu":
        return {"mlp": init_gelu_mlp(kg, d, seg.d_ff or cfg.d_ff, dtype)}
    if seg.ffn == "none":
        return {}
    return {"mlp": init_swiglu(kg, d, seg.d_ff or cfg.d_ff, dtype)}


def init_layer(kg, cfg: ArchConfig, seg: Segment, dtype=jnp.float32):
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), dtype)}
    if seg.kind in ("attn", "enc", "dec", "hybrid"):
        p["attn"] = _init_attn_proj(kg, cfg, dtype)
    if seg.kind == "dec":
        p["lnx"] = jnp.zeros((d,), dtype)
        p["xattn"] = _init_attn_proj(kg, cfg, dtype)
    if seg.kind == "xattn":
        p["xattn"] = _init_attn_proj(kg, cfg, dtype)
        p["xgate"] = jnp.zeros((), jnp.float32)
    if seg.kind in ("ssm", "hybrid"):
        p["ssm"] = ssm_lib.init_ssm(kg, d, cfg.ssm, dtype)
    if seg.kind == "hybrid":
        p["attn_norm"] = jnp.zeros((d,), dtype)
        p["ssm_norm"] = jnp.zeros((d,), dtype)
    if seg.ffn != "none":
        p["ln2"] = jnp.zeros((d,), dtype)
        p.update(_init_ffn(kg, cfg, seg, dtype))
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    kg = KeyGen(key)
    d = cfg.d_model
    params = {
        "embed": normal_init(kg(), (cfg.padded_vocab, d), dtype=dtype),
        "final_ln": jnp.zeros((d,), dtype),
        "segments": [
            _stack([init_layer(kg, cfg, seg, dtype) for _ in range(seg.count)])
            for seg in segments(cfg)
        ],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = normal_init(kg(), (d, cfg.padded_vocab),
                                        dtype=dtype)
    if cfg.family == "audio":
        params["enc_segments"] = [
            _stack([init_layer(kg, cfg, seg, dtype) for _ in range(seg.count)])
            for seg in encoder_segments(cfg)
        ]
        params["enc_ln"] = jnp.zeros((d,), dtype)
    return params


# --------------------------------------------------------------------------
# PartitionSpec rules (keyed on parameter path)
# --------------------------------------------------------------------------

_SPEC_RULES = [
    # (path fragment, spec for trailing dims)
    ("embed", P("model", None)),
    ("unembed", P(None, "model")),
    ("experts/wg", P("model", None, None)),
    ("experts/wu", P("model", None, None)),
    ("experts/wd", P("model", None, None)),
    ("router", P(None, None)),
    ("attn/wq", P(None, "model")),
    ("attn/wk", P(None, "model")),
    ("attn/wv", P(None, "model")),
    ("attn/wo", P("model", None)),
    ("xattn/wq", P(None, "model")),
    ("xattn/wk", P(None, "model")),
    ("xattn/wv", P(None, "model")),
    ("xattn/wo", P("model", None)),
    ("mlp/wgu", P(None, "model")),
    ("mlp/wd", P("model", None)),
    ("mlp/wi", P(None, "model")),
    ("mlp/wo", P("model", None)),
    ("shared/wgu", P(None, "model")),
    ("shared/wd", P("model", None)),
    ("ssm/wz", P(None, "model")),
    ("ssm/wx", P(None, "model")),
    ("ssm/wdt", P(None, "model")),
    ("ssm/wbc", P(None, None)),
    ("ssm/conv_x", P(None, "model")),
    ("ssm/out_proj", P("model", None)),
    ("ssm/gate_norm", P("model")),
    ("ssm/A_log", P("model")),
    ("ssm/D", P("model")),
    ("ssm/dt_bias", P("model")),
]


def _path_str(path):
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params_shape, cfg: ArchConfig, model_size: int = 16):
    """PartitionSpec tree matching a params (shape-)tree.

    Dimensions that don't divide the model-axis size fall back to
    replication (e.g. hymba's 50 SSD heads, 25 attention heads)."""

    kv_shardable = cfg.n_kv_heads % model_size == 0 if cfg.n_kv_heads else True

    def spec_for(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        # K/V projections: replicate when kv heads don't divide TP — a
        # flat-sharded wk/wv costs a full (B,S,d) all-reduce in the backward
        # (dx contraction over the sharded kv dim); replicated weights make
        # fwd AND bwd collective-free (§Perf iteration 4)
        if not kv_shardable and (ps.endswith("attn/wk")
                                 or ps.endswith("attn/wv")
                                 or ps.endswith("xattn/wk")
                                 or ps.endswith("xattn/wv")):
            return P(*([None] * ndim))
        for frag, spec in _SPEC_RULES:
            if frag in ps:
                pad = ndim - len(spec)
                parts = [None] * pad + list(spec)
                for i, ax in enumerate(parts):
                    if ax == "model" and leaf.shape[i] % model_size != 0:
                        parts[i] = None
                return P(*parts)
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# --------------------------------------------------------------------------
# Layer application (train / prefill)
# --------------------------------------------------------------------------

def _attend(p, x, cfg: ArchConfig, ctx: ParallelCtx, *, window, causal=True,
            kv=None, positions=None, q_block=None):
    """Projections + RoPE + blockwise attention + output proj.

    TP strategy: shard attention by query heads when ``n_heads`` divides the
    model axis.  When ``n_kv_heads`` does NOT divide it (granite 8, vlm 8,
    danube 8, gemma3 4), KV is computed replicated (tiny) and repeated to
    the query-head count before attention — a sharded-friendly MHA view.
    A KV-head sharding constraint there would trigger GSPMD's involuntary
    full-rematerialization (full replication of every attention tensor per
    layer) — the dominant collective cost in the baseline dry-run (§Perf
    iteration 1).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    src = kv if kv is not None else x
    tp = ctx.mesh.shape[ctx.model_axis] if ctx.mesh is not None else 1
    q_shardable = cfg.n_heads % tp == 0
    kv_shardable = cfg.n_kv_heads % tp == 0

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("btd,dh->bth", src, p["wk"]).reshape(
        b, src.shape[1], cfg.n_kv_heads, hd)
    v = jnp.einsum("btd,dh->bth", src, p["wv"]).reshape(
        b, src.shape[1], cfg.n_kv_heads, hd)

    q_spec = "model" if q_shardable else None
    q = shard(q, ctx, ctx.dp, None, q_spec, None)
    if kv_shardable:
        k = shard(k, ctx, ctx.dp, None, "model", None)
        v = shard(v, ctx, ctx.dp, None, "model", None)
    else:
        k = shard(k, ctx, ctx.dp, None, None, None)
        v = shard(v, ctx, ctx.dp, None, None, None)

    if kv is None and cfg.rope_theta > 0:
        pos = positions if positions is not None else jnp.arange(s)[None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    if not kv_shardable and q_shardable and cfg.n_kv_heads < cfg.n_heads:
        group = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
        k = shard(k, ctx, ctx.dp, None, "model", None)
        v = shard(v, ctx, ctx.dp, None, "model", None)

    n_pad = 0
    if (not q_shardable and tp > 1 and cfg.n_heads == cfg.n_kv_heads):
        # MHA with heads ∤ TP (whisper 20H): transient zero-pad to the next
        # TP multiple so attention shards by heads.  Exact: padded q rows
        # are sliced off before the output projection (§Perf iteration 9).
        hpad = -(-cfg.n_heads // tp) * tp
        n_pad = hpad - cfg.n_heads
        padw = ((0, 0), (0, 0), (0, n_pad), (0, 0))
        q = shard(jnp.pad(q, padw), ctx, ctx.dp, None, "model", None)
        k = shard(jnp.pad(k, padw), ctx, ctx.dp, None, "model", None)
        v = shard(jnp.pad(v, padw), ctx, ctx.dp, None, "model", None)

    out = attn_lib.blockwise_attention(
        q, k, v, causal=causal, window=window,
        q_block=q_block or ctx.q_block, kv_block=ctx.kv_block)
    if n_pad:
        out = out[:, :, : cfg.n_heads]
    out = out.reshape(b, s, cfg.n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def _apply_ffn(p, x, cfg: ArchConfig, ctx: ParallelCtx, seg: Segment):
    if seg.ffn == "moe":
        out, aux = moe_lib.moe_ffn(
            p["moe"], x, cfg.moe, mesh=ctx.mesh,
            model_axis=ctx.model_axis, dp_spec=P(ctx.dp, None, None))
        return out, aux
    if seg.ffn == "gelu":
        return gelu_mlp(p["mlp"], x), 0.0
    return swiglu(p["mlp"], x), 0.0


def apply_layer(p, x, seg: Segment, cfg: ArchConfig, ctx: ParallelCtx,
                frontend=None, positions=None):
    """One layer.  x: (B, S, d).  Returns (x, aux_loss)."""
    aux = 0.0
    h = rms_norm(p["ln1"], x, cfg.norm_eps)

    if seg.kind in ("attn", "enc", "dec"):
        causal = seg.kind != "enc"
        a_out = _attend(p["attn"], h, cfg, ctx, window=seg.window,
                        causal=causal, positions=positions)
        x = x + jax.ad_checkpoint.checkpoint_name(a_out, "attn_out")
        if seg.kind == "dec":
            hx = rms_norm(p["lnx"], x, cfg.norm_eps)
            x = x + _attend(p["xattn"], hx, cfg, ctx, window=0, causal=False,
                            kv=frontend, q_block=256)
    elif seg.kind == "xattn":
        gate = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
        x = x + gate * _attend(p["xattn"], h, cfg, ctx, window=0,
                               causal=False, kv=frontend, q_block=256)
    elif seg.kind == "ssm":
        x = x + ssm_lib.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm)
    elif seg.kind == "hybrid":
        a = _attend(p["attn"], h, cfg, ctx, window=seg.window,
                    positions=positions)
        m = ssm_lib.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm)
        x = x + 0.5 * (rms_norm(p["attn_norm"], a, cfg.norm_eps)
                       + rms_norm(p["ssm_norm"], m, cfg.norm_eps))
    else:
        raise ValueError(seg.kind)

    if seg.ffn != "none":
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        out, a = _apply_ffn(p, h2, cfg, ctx, seg)
        x = x + jax.ad_checkpoint.checkpoint_name(out, "mlp_out")
        aux = aux + a
    return shard(x, ctx, *ctx.residual_spec()), aux


def run_segments(seg_params, segs, x, cfg, ctx, frontend=None, positions=None):
    """Apply all segments; scan over stacked layers within each."""
    aux_total = jnp.zeros((), jnp.float32)
    for p_stack, seg in zip(seg_params, segs):
        def body(carry, p_layer, seg=seg):
            xc, auxc = carry
            xo, a = apply_layer(p_layer, xc, seg, cfg, ctx,
                                frontend=frontend, positions=positions)
            return (xo, auxc + jnp.asarray(a, jnp.float32)), None

        if ctx.remat:
            if ctx.save_collectives:
                policy = jax.checkpoint_policies.save_only_these_names(
                    "attn_out", "mlp_out")
                body = jax.checkpoint(body, policy=policy)
            else:
                body = jax.checkpoint(body)
        if seg.count == 1:
            p_layer = jax.tree.map(lambda a: a[0], p_stack)
            (x, aux_total), _ = body((x, aux_total), p_layer)
        else:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), p_stack)
    return x, aux_total


# --------------------------------------------------------------------------
# Forward + loss
# --------------------------------------------------------------------------

def _sinusoidal(s, d):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def forward_hidden(params, tokens, cfg: ArchConfig, ctx: ParallelCtx,
                   frontend=None):
    """Token ids -> final hidden states (B, S, d)."""
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    if cfg.family == "audio":
        x = x + _sinusoidal(tokens.shape[1], cfg.d_model).astype(x.dtype)
    x = shard(x, ctx, *ctx.residual_spec())

    enc_out = None
    if cfg.family == "audio":
        assert frontend is not None, "audio arch needs frame embeddings"
        e = frontend.astype(ctx.compute_dtype)
        e = e + _sinusoidal(e.shape[1], cfg.d_model).astype(e.dtype)
        e = shard(e, ctx, ctx.dp, None, None)
        e, _ = run_segments(params["enc_segments"], encoder_segments(cfg),
                            e, cfg, ctx)
        enc_out = rms_norm(params["enc_ln"], e, cfg.norm_eps)
    elif frontend is not None:
        enc_out = shard(frontend.astype(ctx.compute_dtype), ctx,
                        ctx.dp, None, None)

    x, aux = run_segments(params["segments"], segments(cfg), x, cfg, ctx,
                          frontend=enc_out)
    x = rms_norm(params["final_ln"], x, cfg.norm_eps)
    return x, aux


def unembed_matrix(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def mask_vocab_pad(logits, cfg: ArchConfig):
    """-inf the padded vocab tail (see ArchConfig.padded_vocab)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    ids = jnp.arange(logits.shape[-1])
    return jnp.where(ids < cfg.vocab, logits, -1e30)


def lm_loss(params, tokens, labels, cfg: ArchConfig, ctx: ParallelCtx,
            frontend=None):
    """Mean next-token cross-entropy, vocab-chunked over the sequence.

    Never materializes (B, S, V) logits: the sequence is processed in
    ``ctx.loss_chunk`` slices with the chunk body rematerialized.
    """
    h, aux = forward_hidden(params, tokens, cfg, ctx, frontend=frontend)
    # one explicit gather of h per microbatch (instead of per loss chunk)
    h = shard(h, ctx, ctx.dp, None, None)
    w = unembed_matrix(params, cfg).astype(h.dtype)
    b, s, d = h.shape
    chunk = min(ctx.loss_chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def dense_chunk_nll(hs, ls):
        """Single-shard chunk NLL (no mesh)."""
        logits = jnp.einsum("bcd,dv->bcv", hs, w).astype(jnp.float32)
        logits = mask_vocab_pad(logits, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(ls, 0), logits.shape[-1],
                                dtype=logits.dtype)
        picked = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return lse - picked

    def sharded_chunk_nll(hs, ls):
        """Explicit vocab-sharded chunk NLL inside shard_map — GSPMD never
        materializes full-vocab logits (§Perf iteration 4)."""
        v_pad = w.shape[1]
        mp = ctx.mesh.shape[ctx.model_axis]
        v_loc = v_pad // mp

        def body(hs_l, w_l, ls_l):
            rank = jax.lax.axis_index(ctx.model_axis)
            logits = jnp.einsum("bcd,dv->bcv", hs_l,
                                w_l).astype(jnp.float32)
            ids = rank * v_loc + jnp.arange(v_loc)
            logits = jnp.where(ids[None, None, :] < cfg.vocab, logits, -1e30)
            m_loc = jax.lax.stop_gradient(logits.max(axis=-1))
            # all_gather of the tiny per-shard maxes (pmax lacks a JVP rule)
            m = jax.lax.all_gather(m_loc, ctx.model_axis).max(axis=0)
            sumexp = jax.lax.psum(
                jnp.exp(logits - m[..., None]).sum(-1), ctx.model_axis)
            lse = jnp.log(sumexp) + m
            onehot = jax.nn.one_hot(ls_l - rank * v_loc, v_loc,
                                    dtype=logits.dtype)   # OOB -> zeros
            picked = jax.lax.psum(
                jnp.einsum("bcv,bcv->bc", logits, onehot), ctx.model_axis)
            return lse - picked

        from repro.models.moe import _shard_map
        bspec = P(ctx.dp, None, None)
        return _shard_map(
            body, ctx.mesh,
            (bspec, P(None, ctx.model_axis), P(ctx.dp, None)),
            P(ctx.dp, None),
        )(hs, w, jnp.maximum(ls, 0))

    def chunk_body(carry, i):
        hs = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        if ctx.mesh is not None:
            nll = sharded_chunk_nll(hs, ls)
        else:
            nll = dense_chunk_nll(hs, ls)
        valid = ls >= 0
        nll = jnp.where(valid, nll, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    body = jax.checkpoint(chunk_body) if ctx.remat else chunk_body
    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    (total, count), _ = jax.lax.scan(body, init, jnp.arange(nc))
    return total / jnp.maximum(count, 1) + aux


def prefill_logits(params, tokens, cfg: ArchConfig, ctx: ParallelCtx,
                   frontend=None):
    """Prefill forward returning last-position logits (B, V)."""
    h, _ = forward_hidden(params, tokens, cfg, ctx, frontend=frontend)
    w = unembed_matrix(params, cfg).astype(h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], w).astype(jnp.float32)
    return mask_vocab_pad(logits, cfg)
