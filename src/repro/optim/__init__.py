from repro.optim.adamw import (AdamWConfig, AdamWState, init, update,
                               schedule, global_norm, clip_by_global_norm,
                               zero1_specs)
