"""AdamW + schedule + global-norm clipping, pure JAX over pytrees.

ZeRO-1 support: optimizer moments can be sharded over the data-parallel mesh
axes (``zero1_specs``) — GSPMD then emits reduce-scatter/all-gather around
the update instead of keeping replicated moments, cutting optimizer memory
by the DP degree (a distributed-optimization feature for scale; see
DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_fraction: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_fraction."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_fraction + (1 - cfg.min_lr_fraction) * cos
    return cfg.lr * warm * frac


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * (g * g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    res = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    newp = treedef.unflatten([r[0] for r in res])
    mu = treedef.unflatten([r[1] for r in res])
    nu = treedef.unflatten([r[2] for r in res])
    return newp, AdamWState(step, mu, nu), {"lr": lr, "grad_norm": gnorm}


def zero1_specs(param_specs, params_shape, dp_axes=("data",), dp_size=1):
    """Moment PartitionSpecs: ZeRO-1 — shard moments over DP on top of TP.

    For each parameter, shard the first TP-unsharded dimension whose size is
    divisible by the DP degree; parameters with no such dim keep the TP spec
    (replicated moments — only tiny norms/biases in practice).
    """
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def moment_spec(spec, shape):
        parts = list(spec)
        for i, p_ in enumerate(parts):
            if p_ is None and shape.shape[i] % dp_size == 0 \
                    and shape.shape[i] > 0:
                parts[i] = dp
                return P(*parts)
        return P(*parts)

    return jax.tree.map(moment_spec, param_specs, params_shape,
                        is_leaf=lambda s: isinstance(s, P))
