"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms, in seconds, per (arch x shape x mesh):

  compute    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_chip / HBM_bandwidth
  collective = collective_bytes_per_chip / ICI_link_bandwidth

``compiled.cost_analysis()`` (post-SPMD, per-device program) supplies FLOPs
and bytes.  Collective bytes are NOT in cost_analysis: we parse the
partitioned HLO (``compiled.as_text()``) and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Per-device numerators over per-chip peaks are identical to the brief's
global/(chips x peak) form.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?)\[([0-9,]*)\]")

# ops whose output (+operands) we count as HBM traffic; everything else is
# assumed fused / metadata (bitcast, tuple, gte, parameter, constant, iota)
_TRAFFIC_OPS = {
    "dot", "fusion", "copy", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "reduce", "reduce-window", "convert", "transpose",
    "reshape", "concatenate", "pad", "slice", "select", "custom-call",
    "convolution", "broadcast", "add", "multiply", "subtract", "divide",
    "maximum", "minimum", "exponential", "rsqrt", "tanh", "compare",
    "select-and-scatter", "clamp", "negate", "and", "or", "iota",
} | set(_COLLECTIVES)


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+?))\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(%[\w.\-]+|ENTRY\s+%[\w.\-]+)\s*\((.*)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:body|calls)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")


class HloAnalysis:
    """Loop-aware FLOP / traffic / collective analysis of partitioned HLO.

    ``cost_analysis`` counts while-loop bodies once; scans over layers,
    attention blocks, microbatches and loss chunks would be undercounted by
    their trip counts.  This walker multiplies every called computation by
    its ``known_trip_count`` (recorded by XLA in backend_config), giving
    per-device totals:

      flops       — 2 * prod(out_dims) * prod(contracted_dims) per dot
      bytes       — operand+output bytes of non-fused traffic ops (an
                    *unfused upper bound* on HBM traffic; fusion bodies are
                    counted once via their fusion op's operands/output)
      collectives — output bytes per collective kind
    """

    def __init__(self, hlo_text: str):
        self.comps: Dict[str, list] = {}
        self.headers: Dict[str, str] = {}
        cur = None
        for line in hlo_text.splitlines():
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                name = m.group(1).replace("ENTRY", "").strip()
                cur = name
                self.comps[cur] = []
                self.headers[cur] = m.group(2)
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                elif line.strip():
                    self.comps[cur].append(line)
        self._memo: Dict[str, Dict] = {}
        self.unknown_loops = 0

    def _local_types(self, comp: str) -> Dict[str, str]:
        table: Dict[str, str] = {}
        for pdecl in re.findall(r"([\w.\-]+):\s*((?:\([^)]*\)|[^,)]+))",
                                self.headers.get(comp, "")):
            table["%" + pdecl[0]] = pdecl[1]
        for line in self.comps.get(comp, []):
            m = _INSTR_RE.match(line)
            if m:
                table[m.group(1)] = m.group(2)
        return table

    def analyze(self, comp: Optional[str] = None) -> Dict:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        res = {"flops": 0.0, "bytes": 0.0, "f32_collective": 0.0,
               **{k: 0.0 for k in _COLLECTIVES}}
        types = self._local_types(comp)
        for line in self.comps.get(comp, []):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, out_type, op = m.groups()
            out_b = _type_bytes(out_type)
            if op == "dot":
                ops_m = re.search(r"dot\((%[\w.\-]+),\s*(%[\w.\-]+)\)", line)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                flops = 0.0
                if ops_m and cdims is not None:
                    lhs_t = types.get(ops_m.group(1), "")
                    sm = _SHAPE_RE.search(lhs_t)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        contracted = 1
                        for i in (int(x) for x in cdims.group(1).split(",")
                                  if x):
                            contracted *= dims[i]
                        out_elems = out_b / _DTYPE_BYTES.get(
                            _SHAPE_RE.search(out_type).group(1), 4)
                        flops = 2.0 * out_elems * contracted
                res["flops"] += flops
            if op in _TRAFFIC_OPS:
                operand_b = 0
                for opr in re.findall(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)\)",
                                      line[:line.find("metadata")
                                           if "metadata" in line else None]):
                    for nm in re.findall(r"%[\w.\-]+", opr):
                        operand_b += _type_bytes(types.get(nm, ""))
                res["bytes"] += out_b + operand_b
                for k in _COLLECTIVES:
                    if op == k or op == k + "-start":
                        res[k] += out_b
                        if out_type.count("f32"):
                            # CPU backend upcasts bf16 dots to f32; on TPU
                            # these collectives run in bf16 (half the bytes)
                            res["f32_collective"] += out_b
            # recurse into called computations
            mult = 1.0
            if op == "while":
                tm = _TRIP_RE.search(line)
                if tm:
                    mult = float(tm.group(1))
                else:
                    self.unknown_loops += 1
                cm = _COND_RE.search(line)
                if cm and cm.group(1) in self.comps:
                    sub = self.analyze(cm.group(1))
                    for k in res:
                        res[k] += mult * sub[k]
            if op in ("while", "call", "conditional", "async-start"):
                for callee in _CALLS_RE.findall(line):
                    if callee in self.comps:
                        sub = self.analyze(callee)
                        for k in res:
                            res[k] += mult * sub[k]
            # fusion bodies: count their dots (flops) but not their bytes
            if op == "fusion":
                cm = _CALLS_RE.search(line)
                if cm and cm.group(1) in self.comps:
                    sub = self.analyze(cm.group(1))
                    res["flops"] += sub["flops"]
                    for k in list(_COLLECTIVES) + ["f32_collective"]:
                        res[k] += sub[k]
        self._memo[comp] = res
        return res


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    an = HloAnalysis(hlo_text)
    res = an.analyze()
    out = dict(res)
    raw = sum(res[k] for k in _COLLECTIVES)
    out["total_collective_raw"] = raw
    # bf16 normalization: f32 collectives would run in bf16 on the TPU path
    out["total_collective"] = raw - 0.5 * res["f32_collective"]
    out["unknown_loops"] = an.unknown_loops
    return out


# --------------------------------------------------------------------------
# Analytic TPU memory-traffic model (the memory-term numerator)
# --------------------------------------------------------------------------

def analytic_bytes_for(cfg, shape, mesh_shape: Dict[str, int],
                       n_micro: int = 1, zero1: bool = True,
                       kv_bytes: float = 2.0) -> float:
    """Per-chip HBM bytes per step, at TPU kernel (fusion) granularity.

    The CPU dry-run's HLO byte counts reflect XLA-CPU fusion boundaries
    (f32 logits blocks spilled between loop fusions), not the TPU kernels
    (flash attention keeps them in VMEM), so the memory term uses this
    analytic model instead; HLO bytes are kept as an unfused upper bound.

    Streams counted (all per device):
      weights      fwd (+ remat re-fwd + bwd) reads, grad accum r/w,
                   optimizer moments/master r/w (ZeRO-1 sharded over DP)
      activations  layer-boundary residual r/w per microbatch
      attention    Q/K/V + flash KV re-streaming (band-limited for SWA)
      mlp/moe/ssm  intermediate streams at kernel granularity
      kv cache     decode: full local page-pool shard read + one append
    """
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    tp = mesh_shape.get("model", 1)
    dp = chips // tp
    b_loc = max(shape.global_batch // dp, 1)
    s = shape.seq_len
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kv_loc = max(cfg.n_kv_heads / tp, 1.0) if cfg.n_kv_heads else 0
    hq_loc = max(cfg.n_heads / tp, 1.0) if cfg.n_heads else 0
    p_loc = cfg.param_count() / tp
    dt = 2.0                              # bf16

    from repro.models.transformer import segments, encoder_segments
    segs = [(g.kind, g.count, g.window, g.ffn, g.d_ff or cfg.d_ff)
            for g in segments(cfg)]
    if cfg.family == "audio":
        segs += [(g.kind, g.count, g.window, g.ffn, g.d_ff or cfg.d_ff)
                 for g in encoder_segments(cfg)]

    kind = shape.kind
    passes = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[kind]

    if kind == "decode":
        tokens = b_loc                     # one token per sequence
        weights = p_loc * dt               # stream all local weights once
        cache = 0.0
        for seg_kind, count, window, ffn, dff in segs:
            if seg_kind in ("attn", "dec", "hybrid") and cfg.n_kv_heads:
                eff = min(window or s, s)
                if window == 0:
                    # paged pool shard: seq dim split over the KV axes
                    eff = s / (chips // max(dp, 1))
                    eff = eff * b_loc
                else:
                    eff = eff * b_loc
                per_tok = kv_bytes * hd + (2 if kv_bytes < 2 else 0)
                cache += count * eff * cfg.n_kv_heads * per_tok * 2
            if seg_kind in ("ssm", "hybrid") and cfg.ssm:
                d_in = cfg.ssm.expand * d
                nh = d_in // cfg.ssm.head_dim
                cache += count * b_loc * (nh / tp) * cfg.ssm.head_dim \
                    * cfg.ssm.d_state * 4 * 2
        act = tokens * d * dt * 4 * cfg.n_layers
        return weights + cache + act

    # train / prefill
    toks_loc = b_loc * s
    weights = passes * p_loc * dt * n_micro
    if kind == "train":
        opt_div = chips if zero1 else tp
        weights += n_micro * 12.0 * p_loc          # fp32 grad accum r/w+add
        weights += (cfg.param_count() / opt_div) * 4.0 * (2 + 2 + 2 + 2)
    act = 0.0
    for seg_kind, count, window, ffn, dff in segs:
        per_layer = 0.0
        # residual + norms r/w
        per_layer += 4 * toks_loc * d * dt
        if seg_kind in ("attn", "dec", "hybrid", "enc", "xattn") and cfg.n_heads:
            qkv = toks_loc * (hq_loc + 2 * kv_loc) * hd * dt * 2
            nq = max(s // 512, 1)
            band = min((window or s), s)
            kv_stream = nq * min(band + 512, s) * b_loc * kv_loc * hd * 2 * dt
            per_layer += qkv + kv_stream + toks_loc * hq_loc * hd * dt * 2
        if seg_kind in ("ssm", "hybrid") and cfg.ssm:
            d_in = cfg.ssm.expand * d
            per_layer += toks_loc * (d_in / tp) * dt * 6
        if ffn == "moe" and cfg.moe:
            cap_tokens = toks_loc * cfg.moe.top_k * cfg.moe.capacity_factor
            per_layer += cap_tokens * d * dt * 4 \
                + cap_tokens * (cfg.moe.d_expert) * dt * 2
            per_layer += toks_loc * (cfg.moe.n_shared * cfg.moe.d_expert / tp) * dt * 3
        elif ffn in ("swiglu", "gelu"):
            per_layer += toks_loc * (dff / tp) * dt * 3
        act += count * per_layer
    act *= passes * 0.9                   # bwd streams ~ fwd; remat re-fwd
    if kind == "train":
        act /= 1.0
    # embeddings / logits (vocab-chunked loss)
    logits = toks_loc * (cfg.vocab / tp) * (4.0 if kind == "train" else 0.0)
    if kind == "prefill":
        logits = b_loc * (cfg.vocab / tp) * 4.0
    return weights + act + logits


@dataclass
class RooflineTerms:
    flops: float                 # per chip
    bytes_hbm: float             # per chip
    bytes_coll: float            # per chip
    model_flops: float = 0.0     # analytic useful FLOPs per chip

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self):
        return self.bytes_coll / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self):
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self):
        """Fraction of the bound-time budget doing useful model FLOPs."""
        if self.bound_time <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.bound_time

    def to_dict(self):
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.bytes_hbm,
            "collective_bytes_per_chip": self.bytes_coll,
            "model_flops_per_chip": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape, n_chips: int) -> float:
    """Analytic useful FLOPs per chip for the cell.

    train: 6·N_active·tokens; prefill: 2·N_active·tokens (+causal attention
    2·L·H·hd·S²/2·2(QK,AV)·B); decode: 2·N_active·B + full KV attention
    reads (counted as FLOPs: 4·L·kv·hd·S·B... attention decode is
    memory-bound; we count its MACs too).
    """
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    # attention score+value FLOPs (causal halves the square)
    attn = 0.0
    if cfg.n_heads:
        full_layers = 0
        win_layers = 0
        for seg_kind, count, window in _seg_summary(cfg):
            if seg_kind in ("attn", "dec", "hybrid", "enc"):
                if window:
                    win_layers += count
                else:
                    full_layers += count
        if shape.kind == "train" or shape.kind == "prefill":
            attn += full_layers * 4 * cfg.n_heads * hd * (s ** 2) / 2 * b
            w = cfg.window or s
            attn += win_layers * 4 * cfg.n_heads * hd * s * min(w, s) * b
            mult = 6.0 if shape.kind == "train" else 2.0
            attn *= mult / 2.0       # bwd recomputes ~2x fwd attention
            return (mult * n_active * b * s + attn) / n_chips
        # decode: one token per seq
        attn += full_layers * 4 * cfg.n_heads * hd * s * b
        attn += win_layers * 4 * cfg.n_heads * hd * min(cfg.window or s, s) * b
    if shape.kind == "train":
        return (6 * n_active * b * s) / n_chips
    if shape.kind == "prefill":
        return (2 * n_active * b * s) / n_chips
    return (2 * n_active * b + attn) / n_chips


def _seg_summary(cfg):
    from repro.models.transformer import segments, encoder_segments
    out = [(s.kind, s.count, s.window) for s in segments(cfg)]
    if cfg.family == "audio":
        out += [(s.kind, s.count, s.window) for s in encoder_segments(cfg)]
    return out
