from repro.serve.engine import ValetServeEngine, Request, EngineStats
