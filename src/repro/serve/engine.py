"""ValetServeEngine — continuous-batching LM serving with Valet-orchestrated
KV memory.

The engine is the paper's sender node in serving clothes:

* the **HBM page pool** (``ValetMempool``) holds the KV pages of *resident*
  sequences (the paper's local mempool; exact attention requires residency);
* when admission/growth needs pages that aren't free, the policy acts:
    - ``valet``: pause the least-active sequence (Non-Activity-Duration over
      its pages) and *demote* its pages (the migration-not-deletion
      principle).  Demotion is a metadata move: the slots return to the free
      list but the KV bytes stay in place, tracked by the **device tier**;
      a background flush secures host copies off the critical path.
    - ``infiniswap``: *delete* a random victim's pages; resuming must
      re-prefill from the prompt (the cold/disk path).
    - ``os-swap``: synchronous spill AND restore in the critical path.
* every page write/read updates activity tags; hit-ratio and latency
  accounting mirror the paper's Stats.

**Zero-restore (PR 8).**  Because the decode kernel reads KV *through* the
block table (``kernels/paged_attention.py``), restore needs no bulk copy:
``_restore`` repoints block-table entries at pool slots whose bytes survived
preemption untouched (validated against the pool's per-slot generation
counter) and streams only the pages whose slot was reused in the meantime,
one ``device_ops.stream_page`` host read each.  The legacy bulk per-layer
``local_write_batch`` scatter and the ad-hoc ``host_store`` dict are gone
from the restore critical path; the host blobs live in a first-class
``HostTier`` fed by the background flush.  ``zero_restore=False`` keeps the
legacy bulk spill/restore as the comparison baseline (and ``os-swap`` /
``infiniswap`` keep their defining eager/delete behavior either way).

The data plane stays exact: demoted pages come back bit-identically
(repointed bytes never moved; streamed ones round-trip through host), and
deleted pages are recomputed by a real re-prefill.  Tests pin engine output
to the no-pressure reference decode in both restore modes.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import device_ops as dev
from repro.core.activity import ActivityTracker
from repro.core.async_engine import DaemonClock
from repro.core.config import (OrchestrationConfig, config_from_legacy_kwargs,
                               LEGACY_SERVE_KWARGS)
from repro.core.page_table import GlobalPageTable, Tier
from repro.core.policies import Policy, CostModel, VALET, TPU_COSTS
from repro.core.pool import ValetMempool
from repro.core.reservoir import LatencyStatsMixin
from repro.core.tiers import DeviceTier, HostTier
from repro.models import decode as D
from repro.models.transformer import ParallelCtx


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    # runtime
    status: str = "waiting"          # waiting | active | paused | done
    slot: int = -1                   # batch slot
    pages: List[int] = field(default_factory=list)   # logical page ids
    tokens_out: List[int] = field(default_factory=list)
    last_active_step: int = 0
    n_recomputes: int = 0
    # admission-to-first-token bookkeeping (simulated us; -1 = not yet).
    # ``submit_us`` is stamped at submit() (or the caller's arrival time),
    # ``first_token_us`` when the prefill emits the first generated token —
    # their difference is the ATTFT the serve_qps benchmark reports.
    submit_us: float = -1.0
    first_token_us: float = -1.0


@dataclass
class EngineStats(LatencyStatsMixin):
    """Serving counters.  The per-step latency and fence-wait reservoirs and
    their percentile accessors come from the shared ``LatencyStatsMixin``
    (same one the trace store's ``Stats`` inherits)."""
    steps: int = 0
    tokens: int = 0
    spilled_pages: int = 0           # pages pushed out of the pool (any mode)
    restored_pages: int = 0          # pages brought back (repoint + stream)
    deleted_pages: int = 0
    recomputes: int = 0
    pauses: int = 0
    sim_time_us: float = 0.0         # critical-path simulated time
    bg_time_us: float = 0.0          # overlapped background traffic
    wall_time_s: float = 0.0
    # async orchestration (all zero in synchronous mode)
    fences: int = 0                  # restores that waited on the daemon
    fence_wait_us: float = 0.0       # simulated wait absorbed by fences
    daemon_us: float = 0.0           # spill traffic charged to the daemon
    # zero-restore breakdown (all zero with zero_restore=False)
    demoted_pages: int = 0           # metadata-only preemptions
    repointed_pages: int = 0         # restores that were pure repoints
    streamed_pages: int = 0          # restores that paid a per-page host read
    flushed_pages: int = 0           # background write-backs to the host tier


class ValetServeEngine:
    def __init__(self, params, cfg: ArchConfig, ctx: ParallelCtx, *,
                 max_batch: int, max_seq: int, page: int = 16,
                 pool_slots: int, min_pool: Optional[int] = None,
                 policy: Policy = VALET, costs: CostModel = TPU_COSTS,
                 step_cost_us: float = 0.0, seed: int = 0,
                 coordinator=None, container_name: Optional[str] = None,
                 container_weight: Optional[float] = None,
                 weight: Optional[float] = None,
                 async_mode: bool = False,
                 zero_restore: bool = True, flush_batch: int = 64):
        if container_weight is not None:
            warnings.warn(
                "ValetServeEngine(container_weight=...) is deprecated; use "
                "weight=... (or OrchestrationConfig(weight=...) with "
                "ValetServeEngine.from_config())", DeprecationWarning,
                stacklevel=2)
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.page = page
        self.max_batch = max_batch
        self.max_pages = (max_seq + page - 1) // page
        self.policy = policy
        self.costs = costs
        self.step_cost_us = step_cost_us
        self.rng = np.random.default_rng(seed)

        self.infos = D.layer_infos(cfg)
        self.paged_layers = [i for i, inf in enumerate(self.infos)
                             if inf.uses_paged]
        self.caches = D.init_caches(cfg, max_batch, pool_slots=pool_slots,
                                    page=page)
        # multi-tenant serving (§3.4): K engines register with one
        # HostMemoryCoordinator, each leasing KV-pool pages on demand and
        # donating FREE slots back when a co-located engine is under
        # pressure.  The slot array (HBM reservation) stays ``pool_slots``;
        # the *effective* pool size is what gets coordinated.
        self.coordinator = coordinator
        self._lease = None
        # per-container QoS weight (§3.4): a heavier engine claims a larger
        # weighted-fair share of the slab surplus, so coordinator-driven
        # reclamation sheds lighter co-tenants toward their (smaller) fair
        # shares first.  ``weight=`` is the serve-API spelling;
        # ``container_weight`` remains as a deprecated alias.
        if weight is not None:
            self.weight = weight
        elif container_weight is not None:
            self.weight = container_weight
        else:
            self.weight = 1.0
        if coordinator is not None:
            self._lease = coordinator.register(
                min_pages=min_pool or pool_slots, max_pages=pool_slots,
                weight=self.weight, name=container_name)
        self.pool = ValetMempool(
            pool_slots,
            min_pages=min_pool or pool_slots,
            max_pages=pool_slots,
            lease=self._lease)
        if coordinator is not None:
            coordinator.set_donor(self._lease.cid, self._host_donate,
                                  size_fn=lambda: self.pool.size)
        self.gpt = GlobalPageTable()
        self.tracker = ActivityTracker()
        # first-class tiers of the KV page store (PR 8): the device tier
        # tracks demoted-but-resident pages (bytes still in their released
        # pool slot, validated lazily against the pool's generation
        # counter); the host tier holds the spilled blobs the background
        # flush writes back.  Both replace the old private ``host_store``.
        self.device = DeviceTier()
        self.host = HostTier()
        self._flush_q: deque = deque()   # demoted pages awaiting write-back
        self.flush_batch = flush_batch
        self.stats = EngineStats()
        # zero-restore applies to lazy migrate policies (valet/valet-mass);
        # os-swap's eager synchronous spill/restore and infiniswap's delete
        # are those baselines' defining behavior and stay untouched
        self.zero_restore = zero_restore
        self._zero = (bool(zero_restore) and policy.lazy_send
                      and policy.evict_action == "migrate")
        # async orchestration (engine side): the engine owns its own pool
        # (no TieredPageStore), so it carries its own light daemon clock —
        # lazy spill/flush traffic advances it instead of ``bg_time_us``,
        # and a restore that needs those bytes FENCES on it (waits out the
        # daemon's in-flight work) rather than pretending the overlap was
        # free.  Synchronous mode (default) is bitwise unchanged.
        self.async_mode = async_mode
        self.daemon = DaemonClock()
        self.step_counter = 0
        self._next_page_id = 0
        self._slots_free = list(range(max_batch))
        self._requests: Dict[int, Request] = {}
        self._seq_blobs: Dict[int, Any] = {}

        self._decode_jit = jax.jit(self._decode_fn)
        self._prefill_jit = {}

    @property
    def host_store(self) -> Dict[int, dict]:
        """Deprecated spelling of the host tier's blob map (pre-PR 8)."""
        return self.host.blobs

    @classmethod
    def from_config(cls, params, cfg: ArchConfig, ctx: ParallelCtx,
                    config: Optional[OrchestrationConfig] = None,
                    **legacy) -> "ValetServeEngine":
        """Build an engine from the unified ``OrchestrationConfig``.

        Every orchestration knob — including the serving geometry
        (``page``/``max_batch``/``max_seq``/``pool_slots``/``step_cost_us``)
        that used to ride as loose keywords — comes from the config:
        ``pool_slots`` (``pool_capacity`` when unset) sizes the KV pool,
        ``min_pool`` its floor; policy/costs/seed/coordinator/weight/
        async_mode/zero_restore/flush_batch carry over directly.  The old
        loose keywords still work as *deprecated aliases*: each emits a
        ``DeprecationWarning`` naming the config field (the same CI gate as
        the store's legacy kwargs).  Model-plumbing arguments (params, arch,
        parallel ctx) stay explicit — they are not orchestration."""
        base = config if config is not None else OrchestrationConfig()
        c = config_from_legacy_kwargs(base, legacy, owner="ValetServeEngine",
                                      alias_map=LEGACY_SERVE_KWARGS)
        pool_slots = c.pool_slots if c.pool_slots is not None \
            else c.pool_capacity
        return cls(params, cfg, ctx,
                   max_batch=c.max_batch, max_seq=c.max_seq, page=c.page,
                   pool_slots=pool_slots,
                   min_pool=c.min_pool,
                   policy=c.policy, costs=c.costs,
                   step_cost_us=c.step_cost_us, seed=c.seed,
                   coordinator=c.coordinator,
                   container_name=c.container_name,
                   weight=c.weight,
                   async_mode=c.async_mode,
                   zero_restore=c.zero_restore,
                   flush_batch=c.flush_batch)

    # ------------------------------------------------------------------ jit

    def _decode_fn(self, params, caches, tokens, bt, app_slot, app_off,
                   active):
        return D.decode_step(params, caches, tokens, self.cfg, self.ctx,
                             bt, app_slot, app_off, active=active)

    def _prefill_one(self, prompt_tokens: np.ndarray, slot: int,
                     bt_row: np.ndarray):
        """Prefill one request (B=1) and scatter results into batch caches."""
        s = len(prompt_tokens)
        key = s
        if key not in self._prefill_jit:
            def fn(params, caches, toks, bt):
                one = D.init_caches(self.cfg, 1,
                                    pool_slots=1, page=self.page)
                # share the batched pools: prefill writes pages directly
                for li, c in enumerate(one["layers"]):
                    if "pool" in c:
                        c["pool"] = caches["layers"][li]["pool"]
                logits, one = D.prefill(params, toks, self.cfg, self.ctx,
                                        one, bt)
                return logits, one
            self._prefill_jit[key] = jax.jit(fn)
        bt_j = jnp.asarray(bt_row)[None]
        logits, one = self._prefill_jit[key](
            self.params, self.caches, jnp.asarray(prompt_tokens)[None], bt_j)

        # scatter per-seq cache entries into the batch slot
        for li, (bc, oc) in enumerate(zip(self.caches["layers"],
                                          one["layers"])):
            for k in bc:
                if k == "pool":
                    bc[k] = oc[k]                      # shared pool, updated
                elif isinstance(bc[k], dev.RingKV):
                    bc[k] = dev.RingKV(bc[k].k.at[slot].set(oc[k].k[0]),
                                       bc[k].v.at[slot].set(oc[k].v[0]))
                elif isinstance(bc[k], dict):          # ssm state
                    bc[k] = jax.tree.map(
                        lambda full, onev: full.at[slot].set(onev[0]),
                        bc[k], oc[k])
                else:                                   # cross_k / cross_v
                    bc[k] = bc[k].at[slot].set(oc[k][0])
        self.caches["lengths"] = self.caches["lengths"].at[slot].set(s)
        return logits

    # --------------------------------------------------------------- paging

    def _note_allocated(self, slots) -> None:
        """Fresh data is about to land in ``slots``: evict any demoted page
        still shadowed there.  Clean pages (host copy already flushed) just
        lose device residency; dirty ones are extracted to the host tier
        NOW — a forced synchronous copy charged to the critical path,
        because the overwrite cannot wait for the lazy flush."""
        if not self.device.shadow:
            return
        pairs = self.device.evict_slots(slots)
        if not pairs:
            return
        dirty = [(pg, sl) for pg, sl in pairs if pg not in self.host]
        if dirty:
            idx = jnp.asarray(np.asarray([sl for _, sl in dirty], np.int32))
            layer_kv = {}
            for li in self.paged_layers:
                pool = self.caches["layers"][li]["pool"]
                layer_kv[li] = (dev.to_host_tier(pool.k[idx]),
                                dev.to_host_tier(pool.v[idx]))
            for i, (pg, _) in enumerate(dirty):
                self.host.put(pg, {li: (kv[0][i], kv[1][i])
                                   for li, kv in layer_kv.items()})
            self.stats.sim_time_us += self.costs.host_write * len(dirty)
            self.stats.flushed_pages += len(dirty)
        # every evicted page is host-resident now: retier DEVICE -> HOST
        parr = np.asarray([pg for pg, _ in pairs], np.int64)
        m = int(parr.size)
        self.gpt.map_remote_batch(parr, [int(Tier.HOST)] * m,
                                  [-1] * m, [-1] * m, None)

    def _flush_demoted(self, budget: Optional[int] = None) -> int:
        """Background write-back daemon: secure host copies for up to
        ``budget`` demoted pages (all of them when ``None``).  A flushed
        page becomes *clean* — it keeps device residency (still repointable
        for free) and gains a host blob, so a later slot reuse costs
        nothing.  Charged off the critical path: ``bg_time_us`` in sync
        mode, the daemon clock (+ ``daemon_us``) in async mode."""
        q = self._flush_q
        if not q:
            return 0
        n = len(q) if budget is None else min(int(budget), len(q))
        todo, slots = [], []
        for _ in range(n):
            pg = q.popleft()
            # skip pages that left the device tier (evicted / repointed /
            # freed) or were already flushed by an earlier queue entry
            sl = self.device.slot_of(pg)
            if sl is not None and pg not in self.host:
                todo.append(pg)
                slots.append(sl)
        if not todo:
            return 0
        idx = jnp.asarray(np.asarray(slots, np.int32))
        layer_kv = {}
        for li in self.paged_layers:
            pool = self.caches["layers"][li]["pool"]
            layer_kv[li] = (dev.to_host_tier(pool.k[idx]),
                            dev.to_host_tier(pool.v[idx]))
        for i, pg in enumerate(todo):
            self.host.put(pg, {li: (kv[0][i], kv[1][i])
                               for li, kv in layer_kv.items()})
        m = len(todo)
        self.stats.flushed_pages += m
        cost = self.costs.host_write * m
        if self.async_mode:
            self.daemon.charge(cost, self.stats.sim_time_us)
            self.stats.daemon_us += cost
        else:
            self.stats.bg_time_us += cost
        return m

    def _fence(self) -> float:
        """Wait out the daemon's in-flight write-backs (true data
        dependency before reading host bytes back)."""
        st = self.stats
        wait = self.daemon.wait_for(st.sim_time_us)
        if wait > 0.0:
            st.sim_time_us += wait
            st.fence_wait_us += wait
        st.fences += 1
        st.fence_lat.record(wait)
        return wait

    def _alloc_page(self, req: Request) -> Optional[int]:
        """Allocate one logical page backed by a pool slot (all layers)."""
        pg = self._next_page_id
        slot = self.pool.alloc(pg, self.step_counter)
        if slot is None and self.policy.use_local_pool:
            if self._make_room(1):
                slot = self.pool.alloc(pg, self.step_counter)
        if slot is None:
            return None
        self._note_allocated((slot,))
        self._next_page_id += 1
        self.gpt.map_local(pg, slot)
        self.tracker.on_write([pg], self.step_counter)
        req.pages.append(pg)
        return pg

    def _reserve(self, n: int) -> bool:
        """Secure ``n`` FREE pool slots: grow first (leasing from the
        coordinator when attached — possibly pulling idle co-tenants'
        memory), and only preempt residents when growth is exhausted."""
        return self.pool.ensure_free(n) or self._make_room(n)

    def _host_donate(self, n_pages: int) -> int:
        """Coordinator-requested donation: shed FREE slots back to the
        shared slab (an idle engine's drained sequences are exactly the
        unused memory §3.4 wants to hand to a busy co-tenant).  The shrink
        unbacks FREE slots — exactly where demoted pages keep their bytes —
        so every dirty demoted page is flushed to the host tier first."""
        self._flush_demoted(None)
        return self.pool.shrink_by(n_pages)

    def _alloc_pages(self, req: Request, n: int) -> bool:
        """Allocate ``n`` logical pages backed by pool slots, in bulk (one
        ``alloc_batch`` + one local-map scatter instead of a per-page loop)."""
        if n <= 0:
            return True
        if self.pool.free_count() < n and not self._reserve(n):
            return False
        pgs = list(range(self._next_page_id, self._next_page_id + n))
        slots = self.pool.alloc_batch(pgs, [self.step_counter] * n)
        if slots is None:           # cannot happen: free_count checked above
            raise RuntimeError(f"pool refused batch of {n} pages")
        self._note_allocated(slots)
        self._next_page_id += n
        self.gpt.map_local_batch(np.asarray(pgs, np.int64),
                                 np.asarray(slots, np.int64))
        self.tracker.on_write(pgs, self.step_counter)
        req.pages.extend(pgs)
        return True

    def _free_pages(self, req: Request, delete_host=True):
        if req.pages:
            parr = np.asarray(req.pages, np.int64)
            lslots = self.gpt.local_slots_batch(parr)
            mask = lslots >= 0
            if mask.any():
                self.pool.release_batch(lslots[mask].tolist())
                self.gpt.unmap_local_batch(parr[mask])
            self.device.drop(req.pages)
            if delete_host:
                self.host.drop(req.pages)
            self.gpt.drop_remote_batch(parr)
        req.pages = []

    def _make_room(self, n_pages: int) -> bool:
        """Policy-driven preemption to free >= n_pages pool slots."""
        victims_order = sorted(
            [r for r in self._requests.values() if r.status == "active"],
            key=lambda r: r.last_active_step)
        freed = 0
        while self.pool.free_count() < n_pages and victims_order:
            if self.policy.evict_action == "migrate":
                victim = victims_order.pop(0)      # NAD: least recently active
            elif self.policy.victim == "random":
                victim = victims_order.pop(
                    int(self.rng.integers(len(victims_order))))
            else:
                victim = victims_order.pop(0)
            freed += self._preempt(victim)
        if self._zero and freed:
            # the freed slots are about to be handed out: flush the newly
            # demoted pages now so the reuse finds them clean (the write-
            # back overlaps the admit/prefill compute — still off the
            # critical path, like the paper's lazy sender)
            self._flush_demoted(None)
        return self.pool.free_count() >= n_pages

    def _restore(self, req: Request) -> bool:
        """Bring a paused sequence's pages back into the pool.

        Zero-restore mode: one ``local_slots_batch`` gather finds the
        missing pages, then every page whose old slot is still untouched
        (device tier hit, validated by the pool's generation counter) is
        *repointed* — ``claim_batch`` + a block-table remap, zero bytes
        moved — and only pages whose slot was reused stream back from the
        host tier one ``device_ops.stream_page`` read each.  Legacy mode
        keeps the bulk per-layer ``local_write_batch`` scatter over the
        whole sequence.  Either way the restored bytes are bit-identical."""
        if not req.pages:
            return True
        parr = np.asarray(req.pages, np.int64)
        needed = parr[self.gpt.local_slots_batch(parr) < 0]
        n = int(needed.size)
        if n == 0:
            return True
        if self.pool.free_count() < n:
            if not self._reserve(n):
                return False
        needed_l = needed.tolist()
        if self._zero:
            return self._restore_zero(needed, needed_l, n)
        if self.async_mode:
            # the spill daemon may still be writing these bytes out: a
            # restore is a true data dependency, so it fences — waits out
            # the daemon's in-flight work — before reading them back
            self._fence()
        slots = self.pool.alloc_batch(needed_l, [self.step_counter] * n)
        if slots is None:           # cannot happen: free_count checked above
            raise RuntimeError(f"pool refused batch of {n} restore pages")
        blobs = [self.host.pop(pg) for pg in needed_l]
        idx = jnp.asarray(np.asarray(slots, np.int32))
        for li in self.paged_layers:
            ks = jnp.asarray(np.stack([np.asarray(b[li][0]) for b in blobs]))
            vs = jnp.asarray(np.stack([np.asarray(b[li][1]) for b in blobs]))
            # one whole-page scatter per paged layer via the shared bulk
            # data-plane primitive (the same one fill/write allocs ride)
            self.caches["layers"][li]["pool"] = dev.local_write_batch(
                self.caches["layers"][li]["pool"], ks, vs, idx)
        self.gpt.map_local_batch(needed, np.asarray(slots, np.int64))
        self.gpt.drop_remote_batch(needed)
        self.tracker.on_write(needed_l, self.step_counter)
        self.stats.restored_pages += n
        self.stats.sim_time_us += self.costs.host_read * n
        return True

    def _restore_zero(self, needed: np.ndarray, needed_l: List[int],
                      n: int) -> bool:
        """Repoint-first restore (the caller verified ``n`` free slots)."""
        in_dev = [pg for pg in needed_l if pg in self.device]
        rp_pages, rp_slots, missed = self.device.split(in_dev,
                                                       self.pool.free_gen)
        dset = set(in_dev)
        stream = missed + [pg for pg in needed_l if pg not in dset]
        if rp_pages:
            # zero-copy path: claim the exact old slots back and repoint
            # the block table at them — no data movement, no sim cost
            self.pool.claim_batch(rp_slots, rp_pages, self.step_counter)
            self.gpt.map_local_batch(np.asarray(rp_pages, np.int64),
                                     np.asarray(rp_slots, np.int64))
            # a clean flushed copy goes stale the moment the sequence
            # appends into its partial page again, so drop it; the next
            # preemption re-flushes
            self.host.drop(rp_pages)
            self.stats.repointed_pages += len(rp_pages)
        if stream:
            if self.async_mode:
                # streamed bytes come from the host tier the flush daemon
                # writes — a true data dependency, so fence on it.  Pure
                # repoints never fence: the bytes never left the device.
                self._fence()
            k = len(stream)
            slots = self.pool.alloc_batch(stream, [self.step_counter] * k)
            if slots is None:       # cannot happen: free_count checked above
                raise RuntimeError(f"pool refused batch of {k} stream pages")
            self._note_allocated(slots)
            for pg, sl in zip(stream, slots):
                blob = self.host.pop(pg)
                for li in self.paged_layers:
                    self.caches["layers"][li]["pool"] = dev.stream_page(
                        self.caches["layers"][li]["pool"],
                        blob[li][0], blob[li][1], sl)
            self.gpt.map_local_batch(np.asarray(stream, np.int64),
                                     np.asarray(slots, np.int64))
            self.stats.streamed_pages += k
            self.stats.sim_time_us += self.costs.host_read * k
        self.gpt.drop_remote_batch(needed)
        self.tracker.on_write(needed_l, self.step_counter)
        self.stats.restored_pages += n
        return True

    # ------------------------------------------------------------ scheduling

    def submit(self, prompt: np.ndarray, max_new: int, *,
               submit_us: Optional[float] = None) -> int:
        """Queue a request.  ``submit_us`` overrides the arrival timestamp
        (simulated us; defaults to the current simulated clock) — the
        serve_qps benchmark stamps Poisson arrivals through it."""
        rid = len(self._requests)
        req = Request(rid, np.asarray(prompt), max_new)
        req.submit_us = (self.stats.sim_time_us if submit_us is None
                         else float(submit_us))
        self._requests[rid] = req
        return rid

    def _pages_for(self, n_tokens: int) -> int:
        return (n_tokens + self.page - 1) // self.page

    def _admit(self, req: Request) -> bool:
        if not self._slots_free:
            return False
        need = self._pages_for(len(req.prompt) + 1)
        if self.pool.free_count() < need and not self._reserve(need):
            return False
        req.slot = self._slots_free.pop()
        if not self._alloc_pages(req, need):
            raise RuntimeError(f"admit: failed to allocate {need} pages")
        bt = self._block_table_row(req)
        logits = self._prefill_one(req.prompt, req.slot, bt)
        # the prompt's last position yields the first generated token
        req.tokens_out.append(int(jnp.argmax(logits[0])))
        self.stats.tokens += 1
        self.stats.sim_time_us += self.costs.local_write * need
        if req.first_token_us < 0:
            req.first_token_us = self.stats.sim_time_us
        req.status = "active"
        req.last_active_step = self.step_counter
        if len(req.tokens_out) >= req.max_new:
            req.status = "done"
            self._slots_free.append(req.slot)
            self._free_pages(req)
            req.slot = -1
        return True

    def _resume(self, req: Request) -> bool:
        if not self._slots_free:
            return False
        if self.policy.evict_action == "delete" or not req.pages:
            # pages were deleted: re-prefill prompt + generated tokens,
            # EXCLUDING the newest one — the next decode step consumes it
            full = np.concatenate([req.prompt,
                                   np.asarray(req.tokens_out[:-1], np.int64)])
            need = self._pages_for(len(full) + 1)
            if self.pool.free_count() < need and not self._reserve(need):
                return False
            req.slot = self._slots_free.pop()
            if not self._alloc_pages(req, need):
                raise RuntimeError(f"resume: failed to allocate {need} pages")
            self._prefill_one(full, req.slot, self._block_table_row(req))
            self.stats.recomputes += 1
            self.stats.sim_time_us += self.costs.cold_read * need
            req.status = "active"
            req.last_active_step = self.step_counter
            return True
        if not self._restore(req):
            return False
        req.slot = self._slots_free.pop()
        # ring/ssm/cross caches still hold this slot's data only if the seq
        # kept its batch slot; after pause we must re-own a slot.  For exact
        # state we spill/restore those too via host blobs keyed by rid.
        blob = self._seq_blobs.pop(req.rid, None)
        if blob is not None:
            self._write_seq_blob(req.slot, blob)
        req.status = "active"
        req.last_active_step = self.step_counter
        return True

    # per-sequence (non-paged) cache spill helpers
    def _read_seq_blob(self, slot: int):
        out = []
        for c in self.caches["layers"]:
            e = {}
            for k, vv in c.items():
                if k == "pool":
                    continue
                e[k] = jax.tree.map(lambda a: np.asarray(a[slot]), vv)
            out.append(e)
        out.append(int(self.caches["lengths"][slot]))
        return out

    def _write_seq_blob(self, slot: int, blob):
        *layers, length = blob
        for c, e in zip(self.caches["layers"], layers):
            for k, vv in e.items():
                if isinstance(c[k], dev.RingKV):
                    c_k = c[k]
                    c[k] = dev.RingKV(c_k.k.at[slot].set(jnp.asarray(vv[0])),
                                      c_k.v.at[slot].set(jnp.asarray(vv[1])))
                elif isinstance(c[k], dict):
                    c[k] = jax.tree.map(
                        lambda full, onev: full.at[slot].set(jnp.asarray(onev)),
                        c[k], vv)
                else:
                    c[k] = c[k].at[slot].set(jnp.asarray(vv))
        self.caches["lengths"] = self.caches["lengths"].at[slot].set(length)

    def _block_table_row(self, req: Request) -> np.ndarray:
        row = np.full((self.max_pages,), -1, np.int32)
        pgs = req.pages[: self.max_pages]
        if pgs:
            row[:len(pgs)] = self.gpt.local_slots_batch(
                np.asarray(pgs, np.int64)).astype(np.int32)
        return row

    # ----------------------------------------------------------------- run

    def step(self, greedy: bool = True) -> bool:
        """One scheduler iteration: admissions + resumes, one background
        flush slice, one batched decode step over the active set.  Returns
        ``False`` once nothing is waiting, paused, or active — the
        serve_qps benchmark drives this directly, interleaving arrivals
        between iterations; ``run()`` just loops it."""
        sim_before = self.stats.sim_time_us
        pending = [r for r in self._requests.values()
                   if r.status in ("waiting", "paused")]
        for r in pending:
            if r.status == "waiting":
                self._admit(r)
            else:
                self._resume(r)
        # background write-back slice: secure host copies for recently
        # demoted pages while the foreground decodes
        self._flush_demoted(self.flush_batch)
        active = [r for r in self._requests.values() if r.status == "active"]
        if not active:
            # True while something is still pending (deadlock guard: the
            # caller retries, admissions force room next iteration)
            return any(r.status in ("waiting", "paused")
                       for r in self._requests.values())
        self._step_active(active, greedy)
        # one scheduler iteration = one critical-path latency sample
        # (admit + resume/fence + decode); the reservoir backs
        # EngineStats.latency_p50/p99
        self.stats.lat.record(self.stats.sim_time_us - sim_before)
        return True

    def run(self, max_steps: int = 10_000, greedy: bool = True):
        """Drive until all requests are done (or max_steps)."""
        t0 = time.monotonic()
        while max_steps > 0 and self.step(greedy):
            max_steps -= 1
        # write back whatever is still demoted (paused survivors) so no
        # spilled byte ever goes uncharged
        self._flush_demoted(None)
        self.stats.wall_time_s += time.monotonic() - t0
        return [r for r in self._requests.values()]

    def _step_active(self, active: List[Request], greedy: bool):
        self.step_counter += 1
        if self._lease is not None:
            # demand signal: busy engines are reclaimed from last (§3.4)
            self.coordinator.note_activity(self._lease.cid, len(active))
        # one device->host transfer for every sequence length this step
        # (instead of one blocking scalar read per request)
        lengths = np.asarray(self.caches["lengths"])
        # grow pages where the next token crosses a page boundary
        for r in active:
            pos = int(lengths[r.slot])
            if pos % self.page == 0 and self._pages_for(pos + 1) > len(r.pages):
                if self._alloc_page(r) is None:
                    self._preempt(r)
        active = [r for r in active if r.status == "active"]
        if not active:
            return

        bt = np.full((self.max_batch, self.max_pages), -1, np.int32)
        app_slot = np.zeros((self.max_batch,), np.int32)
        app_off = np.zeros((self.max_batch,), np.int32)
        toks = np.zeros((self.max_batch,), np.int64)
        act = np.zeros((self.max_batch,), bool)
        # one batched KV-page table resolution for the whole decode step:
        # every active request's pages through a single vectorized gather
        flat_pages = np.concatenate(
            [np.asarray(r.pages[: self.max_pages], np.int64)
             for r in active]) if active else np.empty(0, np.int64)
        flat_slots = self.gpt.local_slots_batch(flat_pages)
        step_pages = []
        off = 0
        for r in active:
            b = r.slot
            npg = min(len(r.pages), self.max_pages)
            bt[b, :npg] = flat_slots[off:off + npg]
            pos = int(lengths[b])
            pidx = pos // self.page
            pg = r.pages[pidx]
            # pidx can pass max_pages when a sequence outgrows the block
            # table (nothing caps submit length); resolve those the scalar
            # way instead of reading past this request's gather window
            app_slot[b] = flat_slots[off + pidx] if pidx < npg \
                else self.gpt.local_slot(pg)
            app_off[b] = pos % self.page
            toks[b] = (r.tokens_out[-1] if r.tokens_out
                       else r.prompt[-1])
            act[b] = True
            step_pages.append(pg)
            r.last_active_step = self.step_counter
            off += npg
        self.tracker.on_write(step_pages, self.step_counter)

        logits, self.caches = self._decode_jit(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(bt),
            jnp.asarray(app_slot), jnp.asarray(app_off), jnp.asarray(act))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.stats.steps += 1
        self.stats.sim_time_us += self.step_cost_us \
            + self.costs.local_write * len(active)
        for r in active:
            r.tokens_out.append(int(nxt[r.slot]))
            self.stats.tokens += 1
            if len(r.tokens_out) >= r.max_new:
                r.status = "done"
                self._slots_free.append(r.slot)
                self._free_pages(r)
                r.slot = -1

    def _preempt(self, req: Request) -> int:
        """Pause a sequence: demote (zero-restore), spill (legacy valet /
        os-swap) or delete (infiniswap) its pool pages + save its per-slot
        (ring/ssm/cross) caches."""
        n = len(req.pages)
        self.stats.pauses += 1
        if req.slot >= 0:
            self._seq_blobs[req.rid] = self._read_seq_blob(req.slot)
            self._slots_free.append(req.slot)
            req.slot = -1
        if self.policy.evict_action == "delete":
            self._free_pages(req)
            req.status = "paused"
            req.n_recomputes += 1
            self.stats.deleted_pages += n
            self._seq_blobs.pop(req.rid, None)
            return n
        live = np.empty(0, np.int64)
        if req.pages:
            parr = np.asarray(req.pages, np.int64)
            lslots = self.gpt.local_slots_batch(parr)
            mask = lslots >= 0
            live = parr[mask]
            live_slots = lslots[mask]
        if live.size and self._zero:
            # zero-restore demote: a pure metadata move.  The slots return
            # to the free list but the KV bytes stay put, registered with
            # the device tier under the pool's current generation; the
            # background flush secures host copies before any reuse.  No
            # device traffic, no critical-path cost here.
            m = int(live.size)
            self.device.demote(live.tolist(), live_slots.tolist(),
                               self.pool.gen[live_slots].tolist())
            self.pool.release_batch(live_slots.tolist())
            self.gpt.unmap_local_batch(live)
            self.gpt.map_remote_batch(live, [int(Tier.DEVICE)] * m,
                                      [-1] * m, live_slots.tolist(), None)
            self._flush_q.extend(live.tolist())
            self.stats.demoted_pages += m
            self.stats.spilled_pages += m
        elif live.size:
            # legacy bulk spill: one gather + host transfer per paged layer,
            # then grouped release / unmap / remote-map
            idx = jnp.asarray(live_slots.astype(np.int32))
            layer_kv = {}
            for li in self.paged_layers:
                pool = self.caches["layers"][li]["pool"]
                layer_kv[li] = (dev.to_host_tier(pool.k[idx]),
                                dev.to_host_tier(pool.v[idx]))
            for i, pg in enumerate(live.tolist()):
                self.host.put(pg, {li: (kv[0][i], kv[1][i])
                                   for li, kv in layer_kv.items()})
            self.pool.release_batch(live_slots.tolist())
            self.gpt.unmap_local_batch(live)
            m = int(live.size)
            self.gpt.map_remote_batch(live, [int(Tier.HOST)] * m,
                                      [-1] * m, [-1] * m, None)
            self.stats.spilled_pages += m
            cost = self.costs.host_write * m
            if self.policy.lazy_send:
                if self.async_mode:
                    # charge the daemon clock: the spill overlaps decode,
                    # but a restore of these pages must fence on it
                    self.daemon.charge(cost, self.stats.sim_time_us)
                    self.stats.daemon_us += cost
                else:
                    self.stats.bg_time_us += cost
            else:
                self.stats.sim_time_us += cost
        req.status = "paused"
        return n
