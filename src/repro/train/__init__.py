from repro.train.trainer import TrainConfig, make_train_step, make_shardings, fit, cast_for_compute
from repro.train.checkpoint import ValetCheckpointer
from repro.train.elastic import ClusterSpec, degraded_mesh_shape, make_recovery_plan
