"""ValetCheckpointer — asynchronous, replicated checkpointing with the
paper's write-path semantics (DESIGN.md §3).

``save()`` is the critical path: it only snapshots device arrays into a host
staging buffer (the "local mempool" write) and returns.  A background writer
(the Remote Sender Thread analogue) serializes staged snapshots to N replica
directories (remote peers / disk backup, Table 3), then marks them
reclaimable.  If a newer snapshot is staged before an older one is written,
the older one is *skipped* — the Update-flag rule of §5.2 applied to whole
snapshots (the newest data wins; stale write-sets are never persisted over
newer ones).

Restore validates manifests and falls back across replicas (peer-failure
path).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


@dataclass
class _Staged:
    step: int
    arrays: List[np.ndarray]
    stage_time: float


class ValetCheckpointer:
    """Async replicated checkpointer for (params, opt_state, extras)."""

    def __init__(self, directory: str, replicas: int = 2,
                 keep: int = 3):
        self.dirs = [os.path.join(directory, f"replica{r}")
                     for r in range(max(replicas, 1))]
        for d in self.dirs:
            os.makedirs(d, exist_ok=True)
        self.keep = keep
        self._q: "queue.Queue[Optional[_Staged]]" = queue.Queue()
        self._latest_staged = -1
        self._latest_written = -1
        self._lock = threading.Lock()
        self._treedef = None
        self.n_skipped_stale = 0
        self._writer = threading.Thread(target=self._writer_loop, daemon=True)
        self._writer.start()

    # -- critical path ---------------------------------------------------------

    def save(self, step: int, tree) -> float:
        """Stage a snapshot; returns staging latency in seconds."""
        t0 = time.monotonic()
        leaves, treedef = _flatten(tree)
        self._treedef = treedef
        arrays = [np.asarray(l) for l in leaves]      # device -> host staging
        dt = time.monotonic() - t0
        with self._lock:
            self._latest_staged = max(self._latest_staged, step)
        self._q.put(_Staged(step, arrays, time.monotonic()))
        return dt

    # -- background writer -------------------------------------------------------

    def _writer_loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            with self._lock:
                stale = item.step < self._latest_staged
            if stale:
                # Update-flag semantics: a newer snapshot supersedes this one
                self.n_skipped_stale += 1
                self._q.task_done()
                continue
            for d in self.dirs:
                self._write_one(d, item)
            with self._lock:
                self._latest_written = max(self._latest_written, item.step)
            self._q.task_done()

    def _write_one(self, d: str, item: _Staged):
        tmp = tempfile.mkdtemp(dir=d)
        try:
            path = os.path.join(tmp, "arrays.npz")
            np.savez(path, **{f"a{i}": a for i, a in enumerate(item.arrays)})
            manifest = {
                "step": item.step,
                "n_arrays": len(item.arrays),
                "shapes": [list(a.shape) for a in item.arrays],
                "dtypes": [str(a.dtype) for a in item.arrays],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(d, f"step_{item.step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc(d)

    def _gc(self, d: str):
        steps = sorted(self._list_steps(d))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(d, f"step_{s:08d}"),
                          ignore_errors=True)

    @staticmethod
    def _list_steps(d: str) -> List[int]:
        out = []
        for name in os.listdir(d):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return out

    # -- barrier / shutdown --------------------------------------------------------

    def wait(self):
        """Drain the staging queue (checkpoint barrier)."""
        self._q.join()

    def close(self):
        self.wait()
        self._q.put(None)
        self._writer.join(timeout=10)

    # -- restore -------------------------------------------------------------------

    def restore(self, tree_like=None) -> Optional[Tuple[int, Any]]:
        """Load the newest valid snapshot across replicas.

        Returns (step, tree) or None.  Corrupt/partial replicas are skipped —
        the Table-3 'access replica first' read path.
        """
        candidates: List[Tuple[int, str]] = []
        for d in self.dirs:
            for s in self._list_steps(d):
                candidates.append((s, os.path.join(d, f"step_{s:08d}")))
        for step, path in sorted(candidates, reverse=True):
            try:
                with open(os.path.join(path, "manifest.json")) as f:
                    manifest = json.load(f)
                data = np.load(os.path.join(path, "arrays.npz"))
                arrays = [data[f"a{i}"] for i in range(manifest["n_arrays"])]
                for a, shape in zip(arrays, manifest["shapes"]):
                    assert list(a.shape) == shape
            except Exception:
                continue                                  # replica failed
            treedef = self._treedef
            if treedef is None and tree_like is not None:
                treedef = jax.tree.structure(tree_like)
            if treedef is None:
                return step, arrays
            return step, jax.tree.unflatten(treedef, arrays)
        return None
