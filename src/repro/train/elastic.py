"""Elastic scaling & failure handling for the distributed runtime.

Strategy (synchronous SPMD training):

* every N steps the trainer checkpoints asynchronously (ValetCheckpointer);
* on a device/host failure the launcher rebuilds a smaller mesh from the
  survivors (``degraded_mesh``), the data pipeline reshards deterministically
  (``TrainDataset.reshard``), and training resumes from the last snapshot;
* on scale-up the same path runs in reverse.

Straggler mitigation lives at two levels: (a) serving — the Valet control
plane migrates pages *off* pressured peers (activity-based, §3.5), bounding
p99 added latency; (b) training — deterministic data sharding means a
restarted/replaced host recomputes exactly its shard, so the step barrier
never waits on stale state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple




@dataclass(frozen=True)
class ClusterSpec:
    n_pods: int
    data_parallel: int
    model_parallel: int

    @property
    def n_devices(self):
        return self.n_pods * self.data_parallel * self.model_parallel


def degraded_mesh_shape(spec: ClusterSpec, n_alive: int
                        ) -> Optional[ClusterSpec]:
    """Largest valid mesh after failures.

    Model-parallel degree is fixed (weights are TP-sharded); we shed DP
    replicas (and whole pods) until the mesh fits the surviving devices.
    Returns None if not even one model-parallel group survives.
    """
    mp = spec.model_parallel
    groups_alive = n_alive // mp
    if groups_alive < 1:
        return None
    # prefer keeping pods balanced: shrink dp first, then pods
    for pods in range(spec.n_pods, 0, -1):
        dp = min(spec.data_parallel, groups_alive // pods)
        if dp >= 1:
            return ClusterSpec(pods, dp, mp)
    return None


def reshard_plan(old_shards: int, new_shards: int, step: int
                 ) -> List[Tuple[int, int]]:
    """(new_shard, start_step) assignments after elastic change.

    Data is a pure function of (step, shard, n_shards) so the plan is just
    the new numbering starting at the restore step.
    """
    return [(s, step) for s in range(new_shards)]


def make_recovery_plan(spec: ClusterSpec, alive_devices: Sequence[int],
                       restore_step: int):
    """Full recovery description for the launcher (tested in simulation)."""
    new_spec = degraded_mesh_shape(spec, len(alive_devices))
    if new_spec is None:
        return None
    dp_total = new_spec.n_pods * new_spec.data_parallel
    return {
        "mesh": new_spec,
        "devices_used": list(alive_devices)[: new_spec.n_devices],
        "data_shards": reshard_plan(
            spec.n_pods * spec.data_parallel, dp_total, restore_step),
        "restore_step": restore_step,
    }
