"""Training loop: microbatched grad accumulation, mixed precision, ZeRO-1.

``make_train_step`` builds the pure step function used both by the real
trainer (examples/) and by the multi-pod dry-run (launch/dryrun.py).  The
sharding story:

* batch sharded over DP axes ``(pod, data)``; params Megatron-TP over
  ``model`` (see ``models.transformer.param_pspecs``);
* grads are accumulated in ``grad_dtype`` (fp32 default; bf16 halves the
  gradient all-reduce bytes — the gradient-compression knob);
* optimizer moments optionally ZeRO-1-sharded over DP
  (``optim.zero1_specs``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    zero1: bool = True
    grad_dtype: Any = jnp.float32       # bf16 = compressed grad all-reduce
    compute_dtype: Any = jnp.bfloat16
    adamw: optim.AdamWConfig = field(default_factory=optim.AdamWConfig)


def cast_for_compute(params, dtype):
    """Cast >=2D floating params to the compute dtype (norms stay fp32)."""
    def cast(a):
        if a.ndim >= 2 and jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(dtype)
        return a
    return jax.tree.map(cast, params)


def make_train_step(cfg: ArchConfig, ctx: T.ParallelCtx, tcfg: TrainConfig,
                    has_frontend: bool = False):
    """Returns step(params, opt_state, tokens, labels[, frontend])."""

    def loss_fn(params_c, tokens, labels, frontend):
        return T.lm_loss(params_c, tokens, labels, cfg, ctx,
                         frontend=frontend)

    def step(params, opt_state, tokens, labels, frontend=None):
        # batches arrive microbatch-major: (n_micro, mb, ...) so the
        # accumulation scan slices along an UNSHARDED axis (a traced
        # dynamic_slice over the data-sharded batch dim would force GSPMD
        # to all-gather the whole batch — fatal for VLM frontends)
        n_micro = tokens.shape[0]
        assert n_micro == tcfg.microbatches, (n_micro, tcfg.microbatches)

        params_c = cast_for_compute(params, tcfg.compute_dtype)

        def micro(carry, xs):
            gacc, lacc = carry
            if has_frontend:
                t, l, fe = xs
                # stub modality input: block its (unused) cotangent, which
                # would otherwise materialize fp32 at full stacked size
                fe = jax.lax.stop_gradient(fe)
            else:
                (t, l), fe = xs, None
            loss, grads = jax.value_and_grad(loss_fn)(params_c, t, l, fe)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(tcfg.grad_dtype), gacc, grads)
            return (gacc, lacc + loss), None

        gacc0 = jax.tree.map(
            lambda a: jnp.zeros(a.shape, tcfg.grad_dtype), params)
        xs = (tokens, labels, frontend) if has_frontend else (tokens, labels)
        (gacc, loss_sum), _ = jax.lax.scan(
            micro, (gacc0, jnp.zeros((), jnp.float32)), xs)
        grads = jax.tree.map(lambda g: g / n_micro, gacc)
        loss = loss_sum / n_micro

        new_params, new_opt, metrics = optim.update(
            tcfg.adamw, params, grads, opt_state)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return step


def make_shardings(cfg: ArchConfig, ctx: T.ParallelCtx, tcfg: TrainConfig,
                   params_shape, has_frontend: bool = False):
    """(in_shardings, out_shardings) trees for jit(train_step)."""
    mesh = ctx.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    pspecs = T.param_pspecs(params_shape, cfg,
                            model_size=mesh.shape[ctx.model_axis])
    p_shard = jax.tree.map(lambda s: ns(s), pspecs,
                           is_leaf=lambda s: isinstance(s, P))
    if tcfg.zero1:
        mspecs = optim.zero1_specs(pspecs, params_shape, ctx.dp_axes,
                                   ctx.dp_size())
    else:
        mspecs = pspecs
    m_shard = jax.tree.map(lambda s: ns(s), mspecs,
                           is_leaf=lambda s: isinstance(s, P))
    opt_shard = optim.AdamWState(ns(P()), m_shard, m_shard)
    batch_shard = ns(P(None, ctx.dp, None))        # (n_micro, mb, seq)
    ins = [p_shard, opt_shard, batch_shard, batch_shard]
    if has_frontend:
        ins.append(ns(P(None, ctx.dp, None, None)))
    metrics_shard = {"lr": ns(P()), "grad_norm": ns(P()), "loss": ns(P())}
    outs = (p_shard, opt_shard, metrics_shard)
    return tuple(ins), outs


def fit(params, cfg: ArchConfig, ctx: T.ParallelCtx, tcfg: TrainConfig,
        dataset, n_steps: int, log_every: int = 10, callback=None):
    """Simple single-host fit loop (examples / integration tests)."""
    step_fn = jax.jit(make_train_step(cfg, ctx, tcfg))
    opt_state = optim.init(params)
    history = []
    n_micro = tcfg.microbatches
    for i, (tokens, labels) in zip(range(n_steps), dataset):
        tokens = jnp.asarray(tokens).reshape((n_micro, -1) + tokens.shape[1:])
        labels = jnp.asarray(labels).reshape((n_micro, -1) + labels.shape[1:])
        params, opt_state, metrics = step_fn(params, opt_state, tokens,
                                             labels)
        if i % log_every == 0 or i == n_steps - 1:
            history.append({k: float(v) for k, v in metrics.items()})
            history[-1]["step"] = i
        if callback is not None:
            callback(i, params, opt_state, metrics)
    return params, opt_state, history
