import os
import sys

# keep smoke tests on 1 device — only launch/dryrun sets 512 fake devices
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
