"""Batched vs scalar orchestration parity (the access_batch contract).

``TieredPageStore.access_batch`` must be indistinguishable from the scalar
``write()``/``read()`` loop: identical ``Stats`` (counts AND bitwise-equal
accumulated microseconds), identical per-op latencies, identical pool/table
state — across policies, pool pressure, peer pressure, and peer failure.

These are property-style tests over randomized traces; randomness comes
from seeded numpy generators so the suite needs no extra dependencies.
"""
import numpy as np
import pytest

from repro.core import TieredPageStore, POLICIES, PAPER_COSTS
from repro.core.page_table import GlobalPageTable, Location, Tier
from repro.core.pool import SlotState, ValetMempool
from repro.core.queues import WritePipeline
from repro.data.pipeline import TraceConfig, generate_trace

ALL_POLICIES = ("valet", "valet-mass", "infiniswap", "nbdx", "os-swap")


def make_store(policy, pool=128, *, dynamic=False, n_peers=4, blocks=64,
               seed=0):
    return TieredPageStore(
        POLICIES[policy], PAPER_COSTS, pool_capacity=pool,
        min_pool=max(pool // 8, 8) if dynamic else pool, max_pool=pool,
        n_peers=n_peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed)


def random_trace(rng, n_pages, n_ops, write_frac=0.3):
    pages = np.clip(rng.zipf(1.3, n_ops), 1, n_pages) - 1
    is_write = rng.random(n_ops) < write_frac
    return pages.astype(np.int64), is_write


def drive_scalar(store, pages, is_write, tick_every=32, events=None):
    lats = []
    for i in range(len(pages)):
        if is_write[i]:
            lats.append(store.write(int(pages[i])))
        else:
            lats.append(store.read(int(pages[i])))
        if i % tick_every == 0:
            store.background_tick()
        if events and i in events:
            events[i](store)
    return np.asarray(lats)


def drive_batched(store, pages, is_write, tick_every=32, batch=256,
                  events=None):
    """Chunks end exactly at the scalar driver's tick/event boundaries."""
    n = len(pages)
    lats = np.empty(n, np.float64)
    ev = sorted(events) if events else []
    i = 0
    while i < n:
        nxt_tick = i if i % tick_every == 0 \
            else (i // tick_every + 1) * tick_every
        nxt_ev = min([e for e in ev if e >= i], default=n)
        end = min(n, i + batch, nxt_tick + 1, nxt_ev + 1)
        lats[i:end] = store.access_batch(pages[i:end], is_write[i:end])
        if (end - 1) % tick_every == 0:
            store.background_tick()
        if events and (end - 1) in events:
            events[end - 1](store)
        i = end
    return lats


def assert_parity(a, b, la, lb):
    assert a.stats == b.stats, f"\nscalar : {a.stats}\nbatched: {b.stats}"
    assert np.array_equal(la, lb), "per-op latencies diverged"
    assert a.step == b.step
    assert len(a.gpt) == len(b.gpt)
    assert a.pool.free_count() == b.pool.free_count()
    assert a.pool.n_alloc_from_pool == b.pool.n_alloc_from_pool
    assert a.pool.n_reclaimed == b.pool.n_reclaimed
    assert len(a.pipeline.staging) == len(b.pipeline.staging)
    a.pipeline.check_invariants()
    b.pipeline.check_invariants()


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("pool", [32, 256])
def test_random_trace_parity(policy, pool):
    rng = np.random.default_rng(pool)
    for seed in range(3):
        pages, is_write = random_trace(np.random.default_rng(seed), 400, 3000)
        a = make_store(policy, pool, seed=seed)
        b = make_store(policy, pool, seed=seed)
        la = drive_scalar(a, pages, is_write)
        lb = drive_batched(b, pages, is_write,
                           batch=int(rng.integers(16, 300)))
        assert_parity(a, b, la, lb)


def test_parity_under_dynamic_pool():
    pages, is_write = random_trace(np.random.default_rng(7), 500, 4000)
    a = make_store("valet", 256, dynamic=True)
    b = make_store("valet", 256, dynamic=True)
    assert_parity(a, b, drive_scalar(a, pages, is_write),
                  drive_batched(b, pages, is_write))


def test_parity_under_eviction_pressure_and_peer_failure():
    """Peer pressure (migrate/delete), hard peer failure, and local pool
    pressure fired at identical op indices in both drivers."""
    for policy in ("valet", "infiniswap"):
        pages, is_write = random_trace(np.random.default_rng(3), 600, 5000,
                                       write_frac=0.4)
        events = {
            1000: lambda s: s.peer_pressure(0, 4),
            2500: lambda s: s.fail_peer(1),
            4000: lambda s: s.local_pressure(64),
        }
        a = make_store(policy, 64, seed=1)
        b = make_store(policy, 64, seed=1)
        la = drive_scalar(a, pages, is_write, events=events)
        lb = drive_batched(b, pages, is_write, events=events)
        assert_parity(a, b, la, lb)


def test_parity_intra_batch_dependencies():
    """Write->read, duplicate reads, and read-then-write of the same page
    inside one batch must match the scalar order of operations."""
    a = make_store("valet", 64)
    b = make_store("valet", 64)
    pages = np.array([5, 5, 5, 9, 5, 9, 9, 5, 2, 2, 2, 9], np.int64)
    is_write = np.array([1, 0, 0, 1, 0, 0, 1, 1, 0, 1, 0, 0], bool)
    la = np.array([a.write(int(p)) if w else a.read(int(p))
                   for p, w in zip(pages, is_write)])
    lb = b.access_batch(pages, is_write)
    assert_parity(a, b, la, lb)


def test_parity_duplicate_reads_after_remote_spill():
    """First read of a spilled page cache-fills; later duplicates hit
    local — in one batch, exactly as the scalar loop."""
    a = make_store("valet", 32)
    b = make_store("valet", 32)
    for s in (a, b):
        for p in range(200):                 # overflow the pool: spills
            s.write(p)
        s.drain()
    pages = np.array([0, 0, 1, 0, 1, 2, 2, 0], np.int64)
    la = np.array([a.read(int(p)) for p in pages])
    lb = b.access_batch(pages, False)
    assert_parity(a, b, la, lb)


def test_access_batch_scalar_is_write_broadcasts():
    a = make_store("valet", 64)
    b = make_store("valet", 64)
    pages = np.arange(40, dtype=np.int64)
    la = np.array([a.write(int(p)) for p in pages])
    lb = b.access_batch(pages, True)
    assert_parity(a, b, la, lb)
    la2 = np.array([a.read(int(p)) for p in pages])
    lb2 = b.access_batch(pages, False)
    assert_parity(a, b, la2, lb2)


# -- extreme pressure (the plan-once engine's home turf) ----------------------

def assert_deep_state_parity(a, b):
    """Beyond Stats: bitwise page-table arrays, pool slot metadata, the
    free-list order (it fixes future allocation order), and host spills."""
    la, lb = a.gpt._l_slot, b.gpt._l_slot
    n = max(la.shape[0], lb.shape[0])

    def pad(x, fill):
        out = np.full(n, fill, x.dtype)
        out[:x.shape[0]] = x
        return out

    assert np.array_equal(pad(a.gpt._l_slot, -1), pad(b.gpt._l_slot, -1))
    assert np.array_equal(pad(a.gpt._r_tier, 0), pad(b.gpt._r_tier, 0))
    assert np.array_equal(pad(a.gpt._r_peer, -1), pad(b.gpt._r_peer, -1))
    assert np.array_equal(pad(a.gpt._r_slot, -1), pad(b.gpt._r_slot, -1))
    assert np.array_equal(pad(a.gpt._r_mapped, False),
                          pad(b.gpt._r_mapped, False))
    assert a.gpt._replicas == b.gpt._replicas
    assert a.pool._free == b.pool._free, "free-list order diverged"
    assert [(m.state, m.logical_page, m.update_flag, m.reclaim_flag)
            for m in a.pool.slots] == \
           [(m.state, m.logical_page, m.update_flag, m.reclaim_flag)
            for m in b.pool.slots]
    assert a.host_pages == b.host_pages


def record_reclaims(store):
    """Instrument ``_reclaim``: every call's requested size and freed count,
    in order — the scalar loop's reclaim schedule that boundary events must
    replay exactly."""
    calls = []
    orig = store._reclaim

    def wrapped(k):
        freed = orig(k)
        calls.append((k, freed))
        return freed

    store._reclaim = wrapped
    return calls


@pytest.mark.parametrize("policy", ("valet", "valet-mass"))
def test_parity_extreme_pressure_tight_pool(policy):
    """pool_capacity == min_pool and batch >> free slots: every batch is
    wall-to-wall reclaim/stall boundary events."""
    for seed in range(3):
        pages, is_write = random_trace(np.random.default_rng(100 + seed),
                                       600, 4000, write_frac=0.5)
        a = make_store(policy, 48, seed=seed)
        b = make_store(policy, 48, seed=seed)
        la = drive_scalar(a, pages, is_write)
        lb = drive_batched(b, pages, is_write, batch=256)
        assert_parity(a, b, la, lb)
        assert_deep_state_parity(a, b)


def test_parity_single_batch_overruns_pool_many_times():
    """One access_batch call whose allocations exceed the free list many
    times over (batch ~40x the pool) — no driver chunking to lean on."""
    a = make_store("valet", 32)
    b = make_store("valet", 32)
    pages, is_write = random_trace(np.random.default_rng(9), 400, 2000,
                                   write_frac=0.6)
    la = np.array([a.write(int(p)) if w else a.read(int(p))
                   for p, w in zip(pages, is_write)])
    lb = b.access_batch(pages, is_write)
    assert_parity(a, b, la, lb)
    assert_deep_state_parity(a, b)


def test_boundary_reclaim_schedule_matches_scalar():
    """The plan-once engine's boundary events must issue the exact reclaim
    call sequence (sizes AND yields) of the scalar loop."""
    a = make_store("valet", 64, seed=2)
    b = make_store("valet", 64, seed=2)
    ra, rb = record_reclaims(a), record_reclaims(b)
    pages, is_write = random_trace(np.random.default_rng(5), 500, 3000,
                                   write_frac=0.5)
    la = drive_scalar(a, pages, is_write)
    lb = drive_batched(b, pages, is_write)
    assert ra == rb, "reclaim schedules diverged"
    assert len(ra) > 0
    assert_parity(a, b, la, lb)


def test_property_pressure_parity_and_reclaim_schedule():
    """Hypothesis property: on arbitrary tight-pool traces, the batched
    engine's reclaim schedule and Stats are bitwise those of the scalar
    loop (hypothesis is a soft dependency, as in test_core_pool)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           pool=st.sampled_from([16, 24, 48]),
           write_frac=st.floats(0.1, 0.9),
           batch=st.integers(16, 300))
    def prop(seed, pool, write_frac, batch):
        pages, is_write = random_trace(np.random.default_rng(seed), 300,
                                       1200, write_frac)
        a = make_store("valet", pool, seed=seed)
        b = make_store("valet", pool, seed=seed)
        ra, rb = record_reclaims(a), record_reclaims(b)
        la = drive_scalar(a, pages, is_write)
        lb = drive_batched(b, pages, is_write, batch=batch)
        assert ra == rb
        assert_parity(a, b, la, lb)
        assert_deep_state_parity(a, b)

    prop()


# -- data plane ---------------------------------------------------------------

class _ScalarPlane:
    """Data plane exposing only the per-page hook."""

    def __init__(self):
        self.writes = []

    def local_write(self, pg, slot):
        self.writes.append((pg, slot))


class _BulkPlane(_ScalarPlane):
    """Data plane additionally exposing the bulk gather/scatter hook."""

    def __init__(self):
        super().__init__()
        self.bulk_calls = 0

    def local_write_batch(self, pages, slots):
        self.bulk_calls += 1
        self.writes.extend(zip(pages, slots))


def _plane_store(plane, seed=0):
    return TieredPageStore(POLICIES["valet"], PAPER_COSTS, pool_capacity=64,
                           min_pool=64, max_pool=64, n_peers=4,
                           peer_capacity_blocks=64, pages_per_block=16,
                           seed=seed, data_plane=plane)


def test_data_plane_bulk_writes_match_scalar_sequence():
    """``local_write_batch`` (one call per alloc run, fills and write allocs
    alike) must produce the exact (page, slot) sequence of the per-page
    hook, which in turn matches the scalar loop."""
    pages, is_write = random_trace(np.random.default_rng(2), 200, 1500,
                                   write_frac=0.5)
    ref = _ScalarPlane()
    a = _plane_store(ref)
    la = drive_scalar(a, pages, is_write)
    perpage = _ScalarPlane()
    b = _plane_store(perpage)
    lb = drive_batched(b, pages, is_write)
    bulk = _BulkPlane()
    c = _plane_store(bulk)
    lc = drive_batched(c, pages, is_write)
    assert_parity(a, b, la, lb)
    assert_parity(a, c, la, lc)
    assert bulk.bulk_calls > 0
    assert ref.writes == perpage.writes == bulk.writes


# -- building blocks ---------------------------------------------------------

def test_alloc_batch_matches_sequential_allocs():
    for free_mem in (1 << 20, 100):
        p1 = ValetMempool(256, min_pages=32, max_pages=256,
                          free_memory_fn=lambda: free_mem)
        p2 = ValetMempool(256, min_pages=32, max_pages=256,
                          free_memory_fn=lambda: free_mem)
        seq = [p1.alloc(pg, step=pg) for pg in range(30)]
        bat = p2.alloc_batch(list(range(30)), steps=range(30))
        assert seq == bat
        assert p1.size == p2.size and p1.n_grow == p2.n_grow
        assert p1.used() == p2.used()
        assert p1.free_count() == p2.free_count()
        p1.check_invariants()
        p2.check_invariants()


def test_alloc_batch_refuses_overcommit():
    pool = ValetMempool(16, min_pages=16, max_pages=16)
    before = pool.free_count()
    assert pool.alloc_batch(list(range(17)), steps=range(17)) is None
    assert pool.free_count() == before       # no partial effects


def test_alloc_prefix_capacity_predicts_sequential_allocs():
    """The overrun predictor must equal the number of back-to-back scalar
    allocs that actually succeed (growth included), for clean pools."""
    for free_mem in (1 << 20, 100, 40):
        p1 = ValetMempool(256, min_pages=32, max_pages=256,
                          free_memory_fn=lambda fm=free_mem: fm)
        cap = p1.alloc_prefix_capacity(200)
        p2 = ValetMempool(256, min_pages=32, max_pages=256,
                          free_memory_fn=lambda fm=free_mem: fm)
        got = 0
        for i in range(200):
            if p2.alloc(i, step=i) is None:
                break
            got += 1
        assert cap == got, (free_mem, cap, got)
    # static pool: capacity is exactly the free count
    p3 = ValetMempool(16, min_pages=16, max_pages=16)
    assert p3.alloc_prefix_capacity(100) == 16
    assert p3.alloc_prefix_capacity(5) == 5


def test_alloc_prefix_capacity_conservative_with_stranded_tail():
    """A shrink that strands live slots beyond the effective size makes
    growth bookkeeping state-dependent: the predictor must fall back to the
    plain free count (a guaranteed lower bound)."""
    host_free = [1 << 20]
    pool = ValetMempool(64, min_pages=8, max_pages=64,
                        free_memory_fn=lambda: host_free[0])
    for i in range(20):                      # grow past min_pages
        pool.alloc(i, step=i)
    host_free[0] = 0                         # host pressure: shrink
    pool.shrink_for_pressure()
    pool.check_invariants()
    assert any(m.state not in (SlotState.UNBACKED, SlotState.FREE)
               for m in pool.slots[pool.size:])   # tail actually stranded
    host_free[0] = 1 << 20
    assert pool.alloc_prefix_capacity(64) == pool.free_count()


def test_alloc_batch_deficit_grows_like_sequential_allocs():
    """allow_deficit=True: the batch may exceed the current free list; the
    loop then replicates the scalar alloc's growth, slot for slot."""
    p1 = ValetMempool(256, min_pages=32, max_pages=256,
                      free_memory_fn=lambda: 1 << 20)
    p2 = ValetMempool(256, min_pages=32, max_pages=256,
                      free_memory_fn=lambda: 1 << 20)
    n = p1.alloc_prefix_capacity(120)
    assert n > p1.free_count()               # growth genuinely needed
    seq = [p1.alloc(pg, step=pg) for pg in range(n)]
    bat = p2.alloc_batch(list(range(n)), steps=range(n), allow_deficit=True)
    assert seq == bat
    assert (p1.size, p1.n_grow, p1.used(), p1.free_count()) == \
        (p2.size, p2.n_grow, p2.used(), p2.free_count())
    p1.check_invariants()
    p2.check_invariants()


def test_used_counter_stays_exact_through_resizes():
    pool = ValetMempool(64, min_pages=8, max_pages=64,
                        free_memory_fn=lambda: 64)
    slots = [pool.alloc(p, 0) for p in range(6)]
    pool.maybe_grow()
    pool.check_invariants()
    for s in slots[:3]:
        pool.release(s)
    pool.shrink_for_pressure()
    pool.check_invariants()
    assert pool.used() == 3


def test_pipeline_write_rolls_back_on_staging_overrun():
    """A write refused by a full staging queue must leave NO residue: no
    IN_USE slot leak, no stale pending-slot entry, no spurious §5.2 flag
    (the boundary-write replay retries through this exact condition)."""
    pool = ValetMempool(16, min_pages=16, max_pages=16)
    wp = WritePipeline(pool, queue_len=2)
    ws1 = wp.write((7,), step=1)
    ws2 = wp.write((8,), step=2)
    assert ws1 is not None and ws2 is not None
    free_before = pool.free_count()
    pend_before = dict(wp._pending_slot)
    seq_before = wp._seq
    assert wp.write((7,), step=3) is None        # queue full -> refused
    assert pool.free_count() == free_before, "leaked an IN_USE slot"
    assert wp._pending_slot == pend_before
    assert wp._seq == seq_before
    assert not pool.slots[ws1.slots[0]].update_flag   # §5.2 flag restored
    wp.check_invariants()
    # duplicate pages inside one refused transaction unwind exactly too
    assert wp.write((9, 9), step=4) is None
    assert pool.free_count() == free_before
    assert 9 not in wp._pending_slot
    wp.check_invariants()


def test_stage_batch_sets_update_flags_on_duplicates():
    pool = ValetMempool(64, min_pages=64, max_pages=64)
    wp = WritePipeline(pool, queue_len=128)
    slots = pool.alloc_batch([1, 2, 1], steps=range(3))
    wss = wp.stage_batch([1, 2, 1], slots)
    assert [ws.seq for ws in wss] == [0, 1, 2]
    assert pool.slots[slots[0]].update_flag      # superseded by the 3rd
    assert not pool.slots[slots[2]].update_flag
    wp.check_invariants()


def test_flush_releases_superseded_slots():
    """§5.2 both halves: the older slot survives until the newer write-set
    is sent, then becomes reclaimable (no leak)."""
    pool = ValetMempool(64, min_pages=64, max_pages=64)
    wp = WritePipeline(pool, queue_len=128)
    ws1 = wp.write((7,), step=1)
    ws2 = wp.write((7,), step=2)
    wp.flush(1, lambda ws: None)                 # sends ws1 only
    assert pool.slots[ws1.slots[0]].state == SlotState.IN_USE   # deferred
    wp.flush(1, lambda ws: None)                 # sends ws2
    assert pool.slots[ws1.slots[0]].state == SlotState.RECLAIMABLE
    assert pool.slots[ws2.slots[0]].state == SlotState.RECLAIMABLE
    freed = wp.reclaim(4)
    assert sorted(s for s, _ in freed) == sorted(ws1.slots + ws2.slots)
    wp.check_invariants()


def test_page_table_batch_matches_scalar():
    gpt = GlobalPageTable(initial_pages=4)       # force growth
    gpt.map_local(3, 30)
    gpt.map_remote(5, Location(Tier.PEER, peer=1, slot=11))
    gpt.map_remote(9, Location(Tier.HOST))
    gpt.map_local(9, 90)                         # local overrides remote
    gpt.map_remote(700, Location(Tier.COLD))
    pages = np.array([3, 5, 9, 700, 12345], np.int64)
    tier, peer, slot = gpt.lookup_batch(pages)
    for i, pg in enumerate(pages):
        loc = gpt.lookup(int(pg))
        assert tier[i] == int(loc.tier)
        if loc.tier == Tier.PEER:
            assert peer[i] == loc.peer
        assert slot[i] == loc.slot
    assert np.array_equal(gpt.local_slots_batch(pages),
                          [30, -1, 90, -1, -1])
    gpt.unmap_local_batch(np.array([3, 9]))
    assert gpt.local_slot(3) is None and gpt.local_slot(9) is None
    assert len(gpt) == 3                         # 5, 9(host), 700


def test_map_remote_batch_last_writer_wins():
    g1, g2 = GlobalPageTable(), GlobalPageTable()
    updates = [(4, Tier.PEER, 0, 1, ((2, 5),)),
               (4, Tier.PEER, 3, 7, ()),
               (6, Tier.HOST, -1, -1, ())]
    for pg, t, pe, sl, reps in updates:
        g1.map_remote(pg, Location(t, peer=pe, slot=sl, replicas=reps))
    g2.map_remote_batch([u[0] for u in updates],
                        [int(u[1]) for u in updates],
                        [u[2] for u in updates],
                        [u[3] for u in updates],
                        [u[4] for u in updates])
    for pg in (4, 6):
        assert g1.remote_location(pg) == g2.remote_location(pg)
    assert g2.remote_location(4).peer == 3
    assert g2.remote_location(4).replicas == ()


def test_benchmark_drive_helpers_match_scalar_reference():
    """The batched benchmark driver reproduces the old per-op loop bit for
    bit (same tick cadence)."""
    from benchmarks.paper_tables import _drive, _populate
    trace = list(generate_trace(TraceConfig(300, 2000, 0.75, seed=11)))
    a = make_store("valet", 64)
    b = make_store("valet", 64)
    for p in range(300):
        a.write(p)
        if p % 32 == 0:
            a.background_tick()
    _populate(b, 300)
    assert a.stats == b.stats
    la = []
    for i, (op, page) in enumerate(trace):
        la.append(a.write(page) if op == "write" else a.read(page))
        if i % 32 == 0:
            a.background_tick()
    a.background_tick()
    lb = _drive(b, trace)
    assert a.stats == b.stats
    assert np.array_equal(np.asarray(la), lb)
