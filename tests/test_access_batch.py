"""Batched vs scalar orchestration parity (the access_batch contract).

``TieredPageStore.access_batch`` must be indistinguishable from the scalar
``write()``/``read()`` loop: identical ``Stats`` (counts AND bitwise-equal
accumulated microseconds), identical per-op latencies, identical pool/table
state — across policies, pool pressure, peer pressure, and peer failure.

These are property-style tests over randomized traces; randomness comes
from seeded numpy generators so the suite needs no extra dependencies.
"""
import numpy as np
import pytest

from repro.core import TieredPageStore, POLICIES, PAPER_COSTS
from repro.core.page_table import GlobalPageTable, Location, Tier
from repro.core.pool import SlotState, ValetMempool
from repro.core.queues import WritePipeline
from repro.data.pipeline import TraceConfig, generate_trace

ALL_POLICIES = ("valet", "valet-mass", "infiniswap", "nbdx", "os-swap")


def make_store(policy, pool=128, *, dynamic=False, n_peers=4, blocks=64,
               seed=0):
    return TieredPageStore(
        POLICIES[policy], PAPER_COSTS, pool_capacity=pool,
        min_pool=max(pool // 8, 8) if dynamic else pool, max_pool=pool,
        n_peers=n_peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed)


def random_trace(rng, n_pages, n_ops, write_frac=0.3):
    pages = np.clip(rng.zipf(1.3, n_ops), 1, n_pages) - 1
    is_write = rng.random(n_ops) < write_frac
    return pages.astype(np.int64), is_write


def drive_scalar(store, pages, is_write, tick_every=32, events=None):
    lats = []
    for i in range(len(pages)):
        if is_write[i]:
            lats.append(store.write(int(pages[i])))
        else:
            lats.append(store.read(int(pages[i])))
        if i % tick_every == 0:
            store.background_tick()
        if events and i in events:
            events[i](store)
    return np.asarray(lats)


def drive_batched(store, pages, is_write, tick_every=32, batch=256,
                  events=None):
    """Chunks end exactly at the scalar driver's tick/event boundaries."""
    n = len(pages)
    lats = np.empty(n, np.float64)
    ev = sorted(events) if events else []
    i = 0
    while i < n:
        nxt_tick = i if i % tick_every == 0 \
            else (i // tick_every + 1) * tick_every
        nxt_ev = min([e for e in ev if e >= i], default=n)
        end = min(n, i + batch, nxt_tick + 1, nxt_ev + 1)
        lats[i:end] = store.access_batch(pages[i:end], is_write[i:end])
        if (end - 1) % tick_every == 0:
            store.background_tick()
        if events and (end - 1) in events:
            events[end - 1](store)
        i = end
    return lats


def assert_parity(a, b, la, lb):
    assert a.stats == b.stats, f"\nscalar : {a.stats}\nbatched: {b.stats}"
    assert np.array_equal(la, lb), "per-op latencies diverged"
    assert a.step == b.step
    assert len(a.gpt) == len(b.gpt)
    assert a.pool.free_count() == b.pool.free_count()
    assert a.pool.n_alloc_from_pool == b.pool.n_alloc_from_pool
    assert a.pool.n_reclaimed == b.pool.n_reclaimed
    assert len(a.pipeline.staging) == len(b.pipeline.staging)
    a.pipeline.check_invariants()
    b.pipeline.check_invariants()


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("pool", [32, 256])
def test_random_trace_parity(policy, pool):
    rng = np.random.default_rng(pool)
    for seed in range(3):
        pages, is_write = random_trace(np.random.default_rng(seed), 400, 3000)
        a = make_store(policy, pool, seed=seed)
        b = make_store(policy, pool, seed=seed)
        la = drive_scalar(a, pages, is_write)
        lb = drive_batched(b, pages, is_write,
                           batch=int(rng.integers(16, 300)))
        assert_parity(a, b, la, lb)


def test_parity_under_dynamic_pool():
    pages, is_write = random_trace(np.random.default_rng(7), 500, 4000)
    a = make_store("valet", 256, dynamic=True)
    b = make_store("valet", 256, dynamic=True)
    assert_parity(a, b, drive_scalar(a, pages, is_write),
                  drive_batched(b, pages, is_write))


def test_parity_under_eviction_pressure_and_peer_failure():
    """Peer pressure (migrate/delete), hard peer failure, and local pool
    pressure fired at identical op indices in both drivers."""
    for policy in ("valet", "infiniswap"):
        pages, is_write = random_trace(np.random.default_rng(3), 600, 5000,
                                       write_frac=0.4)
        events = {
            1000: lambda s: s.peer_pressure(0, 4),
            2500: lambda s: s.fail_peer(1),
            4000: lambda s: s.local_pressure(64),
        }
        a = make_store(policy, 64, seed=1)
        b = make_store(policy, 64, seed=1)
        la = drive_scalar(a, pages, is_write, events=events)
        lb = drive_batched(b, pages, is_write, events=events)
        assert_parity(a, b, la, lb)


def test_parity_intra_batch_dependencies():
    """Write->read, duplicate reads, and read-then-write of the same page
    inside one batch must match the scalar order of operations."""
    a = make_store("valet", 64)
    b = make_store("valet", 64)
    pages = np.array([5, 5, 5, 9, 5, 9, 9, 5, 2, 2, 2, 9], np.int64)
    is_write = np.array([1, 0, 0, 1, 0, 0, 1, 1, 0, 1, 0, 0], bool)
    la = np.array([a.write(int(p)) if w else a.read(int(p))
                   for p, w in zip(pages, is_write)])
    lb = b.access_batch(pages, is_write)
    assert_parity(a, b, la, lb)


def test_parity_duplicate_reads_after_remote_spill():
    """First read of a spilled page cache-fills; later duplicates hit
    local — in one batch, exactly as the scalar loop."""
    a = make_store("valet", 32)
    b = make_store("valet", 32)
    for s in (a, b):
        for p in range(200):                 # overflow the pool: spills
            s.write(p)
        s.drain()
    pages = np.array([0, 0, 1, 0, 1, 2, 2, 0], np.int64)
    la = np.array([a.read(int(p)) for p in pages])
    lb = b.access_batch(pages, False)
    assert_parity(a, b, la, lb)


def test_access_batch_scalar_is_write_broadcasts():
    a = make_store("valet", 64)
    b = make_store("valet", 64)
    pages = np.arange(40, dtype=np.int64)
    la = np.array([a.write(int(p)) for p in pages])
    lb = b.access_batch(pages, True)
    assert_parity(a, b, la, lb)
    la2 = np.array([a.read(int(p)) for p in pages])
    lb2 = b.access_batch(pages, False)
    assert_parity(a, b, la2, lb2)


# -- building blocks ---------------------------------------------------------

def test_alloc_batch_matches_sequential_allocs():
    for free_mem in (1 << 20, 100):
        p1 = ValetMempool(256, min_pages=32, max_pages=256,
                          free_memory_fn=lambda: free_mem)
        p2 = ValetMempool(256, min_pages=32, max_pages=256,
                          free_memory_fn=lambda: free_mem)
        seq = [p1.alloc(pg, step=pg) for pg in range(30)]
        bat = p2.alloc_batch(list(range(30)), steps=range(30))
        assert seq == bat
        assert p1.size == p2.size and p1.n_grow == p2.n_grow
        assert p1.used() == p2.used()
        assert p1.free_count() == p2.free_count()
        p1.check_invariants()
        p2.check_invariants()


def test_alloc_batch_refuses_overcommit():
    pool = ValetMempool(16, min_pages=16, max_pages=16)
    before = pool.free_count()
    assert pool.alloc_batch(list(range(17)), steps=range(17)) is None
    assert pool.free_count() == before       # no partial effects


def test_used_counter_stays_exact_through_resizes():
    pool = ValetMempool(64, min_pages=8, max_pages=64,
                        free_memory_fn=lambda: 64)
    slots = [pool.alloc(p, 0) for p in range(6)]
    pool.maybe_grow()
    pool.check_invariants()
    for s in slots[:3]:
        pool.release(s)
    pool.shrink_for_pressure()
    pool.check_invariants()
    assert pool.used() == 3


def test_stage_batch_sets_update_flags_on_duplicates():
    pool = ValetMempool(64, min_pages=64, max_pages=64)
    wp = WritePipeline(pool, queue_len=128)
    slots = pool.alloc_batch([1, 2, 1], steps=range(3))
    wss = wp.stage_batch([1, 2, 1], slots)
    assert [ws.seq for ws in wss] == [0, 1, 2]
    assert pool.slots[slots[0]].update_flag      # superseded by the 3rd
    assert not pool.slots[slots[2]].update_flag
    wp.check_invariants()


def test_flush_releases_superseded_slots():
    """§5.2 both halves: the older slot survives until the newer write-set
    is sent, then becomes reclaimable (no leak)."""
    pool = ValetMempool(64, min_pages=64, max_pages=64)
    wp = WritePipeline(pool, queue_len=128)
    ws1 = wp.write((7,), step=1)
    ws2 = wp.write((7,), step=2)
    wp.flush(1, lambda ws: None)                 # sends ws1 only
    assert pool.slots[ws1.slots[0]].state == SlotState.IN_USE   # deferred
    wp.flush(1, lambda ws: None)                 # sends ws2
    assert pool.slots[ws1.slots[0]].state == SlotState.RECLAIMABLE
    assert pool.slots[ws2.slots[0]].state == SlotState.RECLAIMABLE
    freed = wp.reclaim(4)
    assert sorted(s for s, _ in freed) == sorted(ws1.slots + ws2.slots)
    wp.check_invariants()


def test_page_table_batch_matches_scalar():
    gpt = GlobalPageTable(initial_pages=4)       # force growth
    gpt.map_local(3, 30)
    gpt.map_remote(5, Location(Tier.PEER, peer=1, slot=11))
    gpt.map_remote(9, Location(Tier.HOST))
    gpt.map_local(9, 90)                         # local overrides remote
    gpt.map_remote(700, Location(Tier.COLD))
    pages = np.array([3, 5, 9, 700, 12345], np.int64)
    tier, peer, slot = gpt.lookup_batch(pages)
    for i, pg in enumerate(pages):
        loc = gpt.lookup(int(pg))
        assert tier[i] == int(loc.tier)
        if loc.tier == Tier.PEER:
            assert peer[i] == loc.peer
        assert slot[i] == loc.slot
    assert np.array_equal(gpt.local_slots_batch(pages),
                          [30, -1, 90, -1, -1])
    gpt.unmap_local_batch(np.array([3, 9]))
    assert gpt.local_slot(3) is None and gpt.local_slot(9) is None
    assert len(gpt) == 3                         # 5, 9(host), 700


def test_map_remote_batch_last_writer_wins():
    g1, g2 = GlobalPageTable(), GlobalPageTable()
    updates = [(4, Tier.PEER, 0, 1, ((2, 5),)),
               (4, Tier.PEER, 3, 7, ()),
               (6, Tier.HOST, -1, -1, ())]
    for pg, t, pe, sl, reps in updates:
        g1.map_remote(pg, Location(t, peer=pe, slot=sl, replicas=reps))
    g2.map_remote_batch([u[0] for u in updates],
                        [int(u[1]) for u in updates],
                        [u[2] for u in updates],
                        [u[3] for u in updates],
                        [u[4] for u in updates])
    for pg in (4, 6):
        assert g1.remote_location(pg) == g2.remote_location(pg)
    assert g2.remote_location(4).peer == 3
    assert g2.remote_location(4).replicas == ()


def test_benchmark_drive_helpers_match_scalar_reference():
    """The batched benchmark driver reproduces the old per-op loop bit for
    bit (same tick cadence)."""
    from benchmarks.paper_tables import _drive, _populate
    trace = list(generate_trace(TraceConfig(300, 2000, 0.75, seed=11)))
    a = make_store("valet", 64)
    b = make_store("valet", 64)
    for p in range(300):
        a.write(p)
        if p % 32 == 0:
            a.background_tick()
    _populate(b, 300)
    assert a.stats == b.stats
    la = []
    for i, (op, page) in enumerate(trace):
        la.append(a.write(page) if op == "write" else a.read(page))
        if i % 32 == 0:
            a.background_tick()
    a.background_tick()
    lb = _drive(b, trace)
    assert a.stats == b.stats
    assert np.array_equal(np.asarray(la), lb)
