"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and no NaNs (assignment requirement f)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.train import TrainConfig, make_train_step

CTX = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8,
                    compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def smoke_state():
    return {}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke(name):
    cfg = reduced(ARCHS[name])
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fe = None
    if cfg.n_frontend_tokens:
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))

    # forward: hidden states + last-position logits
    h, aux = T.forward_hidden(params, toks, cfg, CTX, frontend=fe)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    logits = T.prefill_logits(params, toks, cfg, CTX, frontend=fe)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits[:, : cfg.vocab]).any())

    # one train step decreases nothing but must be finite + right shapes
    tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32,
                       adamw=optim.AdamWConfig(lr=1e-3))
    step = make_train_step(cfg, CTX, tcfg, has_frontend=fe is not None)
    opt = optim.init(params)
    args = [params, opt, toks.reshape(2, 1, S), labels.reshape(2, 1, S)]
    if fe is not None:
        args.append(fe.reshape(2, 1, *fe.shape[1:]))
    new_params, new_opt, metrics = step(*args)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda acc, ab: acc + float(jnp.abs(ab).sum()),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                     new_params, params), 0.0)
    assert delta > 0
