"""AsyncOrchestrator: the epoch/fence protocol and its verification tier.

The async engine deliberately breaks bitwise parity with the synchronous
store (flush cadence and victim order shift once daemon work overlaps the
critical path), so these tests pin what the design actually promises:

* **Safety** — the full ``InvariantChecker`` (no lost writes, §5.2
  write-set safety, slab/page conservation, replica-index consistency)
  holds after every epoch on randomized pressure/failure traces, in both
  orchestration modes (it must pass *trivially* on the sync store).
* **Statistical equivalence** — sync and async runs of one trace tell the
  same workload story (``stats_close`` over hits/evictions/migrations).
* **The point of the exercise** — on the oversubscribed pressure trace the
  async p99 beats the sync p99 (the inline flush stall leaves the
  foreground distribution), and fences fire exactly when the daemon is
  genuinely behind.
* **Epoch holds** — ``hold_from_free``/``commit_holds`` bound when the
  daemon's reclaimed slots become allocatable.
"""
import numpy as np
import pytest

from repro.core import (AsyncOrchestrator, InvariantChecker, InvariantError,
                        OrchestrationConfig, TieredPageStore, POLICIES,
                        PAPER_COSTS, stats_close, stats_delta)
from repro.core.pool import SlotState, ValetMempool


def make_store(*, pool=128, min_pool=None, n_peers=4, blocks=256, seed=0,
               async_mode=False, policy="valet", **kw):
    cfg = OrchestrationConfig(
        policy=POLICIES[policy], costs=PAPER_COSTS, pool_capacity=pool,
        min_pool=pool if min_pool is None else min_pool, max_pool=pool,
        n_peers=n_peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed, async_mode=async_mode, **kw)
    return TieredPageStore.from_config(cfg)


def random_trace(seed, n_pages, n_ops, write_frac=0.4):
    rng = np.random.default_rng(seed)
    pages = np.clip(rng.zipf(1.3, n_ops), 1, n_pages) - 1
    return pages.astype(np.int64), rng.random(n_ops) < write_frac


def drive_checked(store, pages, is_write, *, chunk=128, check_every=512,
                  events=None):
    """Drive in chunks, ticking each chunk and running the full checker
    every ``check_every`` ops (an epoch multiple in async mode)."""
    chk = InvariantChecker(store)
    for i in range(0, len(pages), chunk):
        store.access_batch(pages[i:i + chunk], is_write[i:i + chunk])
        store.background_tick()
        if events and i in events:
            events[i](store)
        if i % check_every == 0:
            chk.check()
    store.drain()
    chk.check()
    assert chk.n_checks >= 2
    return store


# -- epoch-tagged holds (the daemon <-> foreground hand-off) -------------------

def test_hold_from_free_defers_allocation():
    pool = ValetMempool(16, min_pages=16, max_pages=16)
    free0 = pool.free_count()
    held = pool.hold_from_free(4, epoch=3, finish_us=100.0)
    assert held == 4
    assert pool.free_count() == free0 - 4
    assert pool.held_count() == 4
    pool.check_invariants()
    # neither bound satisfied -> nothing commits
    assert pool.commit_holds(up_to_epoch=2, now_us=50.0) == 0
    # AND semantics: epoch admits, time does not
    assert pool.commit_holds(up_to_epoch=3, now_us=50.0) == 0
    assert pool.commit_holds(up_to_epoch=3, now_us=100.0) == 4
    assert pool.free_count() == free0 and pool.held_count() == 0
    pool.check_invariants()


def test_commit_holds_wildcard_is_the_fence_path():
    pool = ValetMempool(16, min_pages=16, max_pages=16)
    pool.hold_from_free(3, epoch=1, finish_us=10.0)
    pool.hold_from_free(5, epoch=2, finish_us=1e9)
    assert pool.held_count() == 8
    assert pool.commit_holds() == 8          # no bounds: everything commits
    assert pool.held_count() == 0
    pool.check_invariants()


def test_hold_is_capped_by_free_list():
    pool = ValetMempool(8, min_pages=8, max_pages=8)
    for pg in range(6):
        pool.alloc(pg, step=pg)
    assert pool.hold_from_free(100, epoch=0, finish_us=0.0) == 2
    assert pool.free_count() == 0
    pool.check_invariants()


# -- the checker itself --------------------------------------------------------

def test_checker_passes_trivially_on_sync_randomized_traces():
    """The invariant tier must hold on the bitwise-verified synchronous
    store under pool pressure, peer pressure, and peer failure — if it
    can't, the checks (not the store) are wrong."""
    for seed in range(3):
        pages, is_write = random_trace(seed, 500, 4000, write_frac=0.5)
        events = {
            1024: lambda s: s.peer_pressure(0, 4),
            2048: lambda s: s.fail_peer(1),
            3072: lambda s: s.local_pressure(32),
        }
        drive_checked(make_store(pool=48, seed=seed), pages, is_write,
                      events=events)


def test_checker_detects_a_planted_violation():
    """Negative control: corrupt one protocol fact and the checker fires."""
    st = make_store(pool=32)
    st.access_batch(np.arange(64, dtype=np.int64), True)
    chk = InvariantChecker(st)
    chk.check()
    slot = int(np.flatnonzero(st.pool.state == int(SlotState.IN_USE))[0])
    st.pool.owner[slot] = 9999                # break mapping coherence
    with pytest.raises(InvariantError):
        chk.check()


def test_stats_close_bounds():
    a = make_store(pool=64)
    pages, is_write = random_trace(1, 200, 1500)
    a.access_batch(pages, is_write)
    assert stats_close(a.stats, a.stats)      # identity
    b = make_store(pool=64)
    b.access_batch(pages, is_write)
    b.stats.local_hits += int(0.5 * max(b.stats.local_hits, 1)) + 200
    assert not stats_close(a.stats, b.stats)
    assert "local_hits" in stats_delta(a.stats, b.stats)


# -- async mode: safety on randomized pressure/failure traces ------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_async_invariants_under_pressure_and_failure(seed):
    pages, is_write = random_trace(100 + seed, 600, 5000, write_frac=0.5)
    events = {
        1024: lambda s: s.peer_pressure(0, 4),
        2560: lambda s: s.fail_peer(1),
        3968: lambda s: s.local_pressure(24),
    }
    st = drive_checked(make_store(pool=64, seed=seed, async_mode=True),
                       pages, is_write, events=events)
    assert st.orchestrator is not None
    assert st.orchestrator.n_boundaries > 0
    assert st.stats.daemon_us > 0             # work actually moved off-path


@pytest.mark.parametrize("seed", [0, 1])
def test_sync_async_statistical_equivalence(seed):
    """Same trace, both modes: the workload-visible counters agree within
    the documented bounds even though interleavings differ.  The async
    daemon's proactive restock drops some local mappings earlier than the
    sync store would, so the tolerance is looser than the default."""
    pages, is_write = random_trace(200 + seed, 500, 6000, write_frac=0.4)
    s = drive_checked(make_store(pool=96, seed=seed), pages, is_write)
    a = drive_checked(make_store(pool=96, seed=seed, async_mode=True),
                      pages, is_write)
    assert s.stats.ops == a.stats.ops == len(pages)
    assert stats_close(s.stats, a.stats, rtol=0.35, atol=256), \
        stats_delta(s.stats, a.stats)


# -- fences: the foreground pays only when the daemon is behind ----------------

def test_fences_fire_when_writes_outpace_the_daemon():
    """All-distinct writes exhaust the free list mid-epoch (nothing is
    reclaimable before the staged sets flush), so the write path must run
    its fence ladder — and still lose no writes."""
    st = make_store(pool=32, async_mode=True)
    pages = np.arange(2000, dtype=np.int64)
    st.access_batch(pages, True)
    assert st.stats.fences > 0
    assert st.stats.ops == 2000
    InvariantChecker(st).check()
    st.drain()
    InvariantChecker(st).check()


def test_no_fences_when_daemon_keeps_up():
    """A read-mostly resident workload never exhausts the free list, so the
    foreground should never wait on the daemon."""
    st = make_store(pool=256, async_mode=True)
    st.access_batch(np.arange(128, dtype=np.int64), True)
    st.drain()
    rng = np.random.default_rng(0)
    pages = rng.integers(0, 128, size=4000).astype(np.int64)
    fences0 = st.stats.fences
    for i in range(0, 4000, 256):
        st.access_batch(pages[i:i + 256], False)
        st.background_tick()
    assert st.stats.fences == fences0
    InvariantChecker(st).check()


# -- the tail: what the tentpole buys ------------------------------------------

def test_async_p99_beats_sync_on_pressure_trace():
    """Mini tail_latency: oversubscribed pool, populated working set.  The
    sync p99 is the inline flush stall; async moves it to the daemon.  The
    acceptance bound (async p99 <= 0.8x sync p99) must hold here too."""
    rng = np.random.default_rng(5)
    n_pages, n_ops = 2048, 20_000
    pages = rng.integers(0, n_pages, size=n_ops).astype(np.int64)
    is_write = rng.random(n_ops) < 0.6

    def run(async_mode):
        st = make_store(pool=128, n_peers=6, blocks=1024,
                        async_mode=async_mode)
        st.access_batch(np.arange(n_pages, dtype=np.int64), True)
        st.drain()
        st.stats.lat.reset()
        for i in range(0, n_ops, 256):
            st.access_batch(pages[i:i + 256], is_write[i:i + 256])
            if i % 1024 == 0:
                st.background_tick()
        if async_mode:
            InvariantChecker(st).check()
        return st.stats

    sync, asy = run(False), run(True)
    assert sync.latency_p99() > 0 and asy.latency_p99() > 0
    assert asy.latency_p99() <= 0.8 * sync.latency_p99(), \
        (sync.latency_p99(), asy.latency_p99())
    assert asy.daemon_us > 0
    assert sync.daemon_us == 0 and sync.fences == 0   # sync stays sync


def test_latency_reservoir_percentiles_are_exact_until_cap():
    from repro.core.reservoir import LatencyReservoir
    r = LatencyReservoir(cap=1 << 12)
    vals = np.random.default_rng(3).exponential(50.0, size=3000)
    r.record_many(vals)
    assert r.count == 3000
    assert r.p99() == pytest.approx(float(np.percentile(vals, 99.0)))
    r.reset()
    assert len(r) == 0 and r.count == 0 and r.p99() == 0.0


# -- real-thread mode ----------------------------------------------------------

def test_real_thread_smoke():
    """The optional real daemon thread: same safety story (invariants,
    statistical equivalence vs the simulated-clock daemon), clean close."""
    pages, is_write = random_trace(7, 400, 3000, write_frac=0.5)
    sim = drive_checked(make_store(pool=64, async_mode=True),
                        pages, is_write)
    st = make_store(pool=64, async_mode=True, real_thread=True)
    try:
        for i in range(0, len(pages), 128):
            st.access_batch(pages[i:i + 128], is_write[i:i + 128])
            st.background_tick()
        st.drain()
        InvariantChecker(st).check()
        assert st.stats.ops == len(pages)
        assert stats_close(sim.stats, st.stats, rtol=0.35, atol=256), \
            stats_delta(sim.stats, st.stats)
    finally:
        st.orchestrator.close()
    st.orchestrator.close()                   # idempotent


# -- direct engine surface -----------------------------------------------------

def test_orchestrator_validates_knobs():
    st = make_store(pool=32)
    with pytest.raises(ValueError):
        AsyncOrchestrator(st, epoch_len=0)
    with pytest.raises(ValueError):
        AsyncOrchestrator(st, daemon_budget=0)


def test_drain_quiesces_the_daemon():
    st = make_store(pool=64, async_mode=True)
    st.access_batch(np.arange(300, dtype=np.int64), True)
    st.drain()
    assert len(st.pipeline.staging) == 0
    assert st.pool.held_count() == 0          # quiesce committed every hold
    InvariantChecker(st).check()
