"""CLI contract of the benchmark regression gate: graceful failures for
missing/malformed inputs, refresh refusal on incomplete results."""
import json
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# must cover every TRACKED (bench, metric) pair, including the workload
# suite's ycsb_a/hit_ratio, ml_trace/speedup and
# mixed_tenant_workload/fairness
FULL = {"batch_speedup": {"speedup": 4.0},
        "pressure_speedup": {"speedup": 1.0},
        "reclaim_speedup": {"speedup": 3.6},
        "reclaim_floor": {"speedup": 2.0},
        "multi_tenant": {"speedup": 1.3},
        "tail_latency": {"speedup": 15.0},
        "ycsb_a": {"hit_ratio": 0.78},
        "ml_trace": {"speedup": 1.35},
        "mixed_tenant_workload": {"fairness": 0.99},
        "serve_qps": {"tokens_per_s": 1.2},
        "fault_recovery": {"durability": 1.0,
                           "degraded_throughput": 0.84},
        "cluster_tenant": {"replica_availability": 1.0,
                           "fairness": 0.99}}


def test_tracked_covers_workload_suite_keys():
    """The gate really tracks the three workload-suite keys (the FULL dict
    above would silently go stale otherwise)."""
    sys.path.insert(0, REPO)
    from benchmarks.check_regression import TRACKED
    assert ("ycsb_a", "hit_ratio") in TRACKED
    assert ("ml_trace", "speedup") in TRACKED
    assert ("mixed_tenant_workload", "fairness") in TRACKED
    for bench, metric in TRACKED:
        assert metric in FULL[bench], f"FULL missing {bench}/{metric}"


def run_gate(tmp_path, results, baseline, *extra):
    tmp_path.mkdir(parents=True, exist_ok=True)
    rp = tmp_path / "results.json"
    bp = tmp_path / "baseline.json"
    if results is not None:
        rp.write_text(results if isinstance(results, str)
                      else json.dumps(results))
    if baseline is not None:
        bp.write_text(json.dumps(baseline))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--results", str(rp), "--baseline", str(bp), *extra],
        cwd=REPO, capture_output=True, text=True)
    return proc, bp


def test_gate_passes_on_matching_results(tmp_path):
    proc, _ = run_gate(tmp_path, FULL, FULL)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "passed" in proc.stdout


def test_gate_fails_on_regression(tmp_path):
    bad = {k: {m: x * 0.5 for m, x in v.items()} for k, v in FULL.items()}
    proc, _ = run_gate(tmp_path, bad, FULL)
    assert proc.returncode == 1
    assert "REGRESSION" in proc.stdout


def test_missing_tracked_key_fails_clearly(tmp_path):
    partial = {k: v for k, v in FULL.items() if k != "multi_tenant"}
    proc, _ = run_gate(tmp_path, partial, FULL)
    assert proc.returncode == 1
    assert "multi_tenant/speedup missing from results" in proc.stdout
    assert "Traceback" not in proc.stderr


def test_missing_workload_suite_keys_fail_clearly(tmp_path):
    """Dropping any of the new workload-suite benches from the results must
    fail with the same clear per-key message, not pass silently."""
    for i, (bench, metric) in enumerate((("ycsb_a", "hit_ratio"),
                                         ("ml_trace", "speedup"),
                                         ("mixed_tenant_workload",
                                          "fairness"))):
        partial = {k: v for k, v in FULL.items() if k != bench}
        proc, _ = run_gate(tmp_path / str(i), partial, FULL)
        assert proc.returncode == 1
        assert f"{bench}/{metric} missing from results" in proc.stdout
        assert "Traceback" not in proc.stderr


def test_missing_fault_recovery_keys_fail_clearly(tmp_path):
    """Both fault_recovery keys share one bench entry: dropping it must
    name each tracked metric, and dropping a single metric from the entry
    must fail on exactly that key."""
    partial = {k: v for k, v in FULL.items() if k != "fault_recovery"}
    proc, _ = run_gate(tmp_path / "bench", partial, FULL)
    assert proc.returncode == 1
    assert "fault_recovery/durability missing from results" in proc.stdout
    assert "fault_recovery/degraded_throughput missing from results" \
        in proc.stdout
    assert "Traceback" not in proc.stderr
    one_short = json.loads(json.dumps(FULL))
    del one_short["fault_recovery"]["degraded_throughput"]
    proc, _ = run_gate(tmp_path / "metric", one_short, FULL)
    assert proc.returncode == 1
    assert "fault_recovery/degraded_throughput missing from results" \
        in proc.stdout
    assert "fault_recovery/durability missing" not in proc.stdout
    assert "Traceback" not in proc.stderr


def test_missing_cluster_tenant_keys_fail_clearly(tmp_path):
    """Both cluster_tenant keys share one bench entry: dropping the entry
    must name each tracked metric, and dropping a single metric must fail
    on exactly that key."""
    partial = {k: v for k, v in FULL.items() if k != "cluster_tenant"}
    proc, _ = run_gate(tmp_path / "bench", partial, FULL)
    assert proc.returncode == 1
    assert "cluster_tenant/replica_availability missing from results" \
        in proc.stdout
    assert "cluster_tenant/fairness missing from results" in proc.stdout
    assert "Traceback" not in proc.stderr
    one_short = json.loads(json.dumps(FULL))
    del one_short["cluster_tenant"]["fairness"]
    proc, _ = run_gate(tmp_path / "metric", one_short, FULL)
    assert proc.returncode == 1
    assert "cluster_tenant/fairness missing from results" in proc.stdout
    assert "cluster_tenant/replica_availability missing" not in proc.stdout
    assert "Traceback" not in proc.stderr


def test_replica_availability_regression_fails(tmp_path):
    """A rack crash losing replicated pages (availability 1.0 -> 0.7)
    trips the gate."""
    bad = json.loads(json.dumps(FULL))
    bad["cluster_tenant"]["replica_availability"] = 0.7
    proc, _ = run_gate(tmp_path, bad, FULL)
    assert proc.returncode == 1
    assert "REGRESSION" in proc.stdout


def test_durability_regression_fails(tmp_path):
    """Lost pages on a replica-covered crash (durability 1.0 -> 0.7) trip
    the gate."""
    bad = json.loads(json.dumps(FULL))
    bad["fault_recovery"]["durability"] = 0.7
    proc, _ = run_gate(tmp_path, bad, FULL)
    assert proc.returncode == 1
    assert "REGRESSION" in proc.stdout


def test_workload_metric_regression_fails(tmp_path):
    """A hit-ratio / fairness drop >20% trips the gate like a speedup."""
    bad = json.loads(json.dumps(FULL))
    bad["ycsb_a"]["hit_ratio"] = 0.5          # 0.78 -> 0.5 is > 20% down
    proc, _ = run_gate(tmp_path, bad, FULL)
    assert proc.returncode == 1
    assert "REGRESSION" in proc.stdout


def test_missing_results_file_fails_clearly(tmp_path):
    proc, _ = run_gate(tmp_path, None, FULL)
    assert proc.returncode == 2
    assert "not found" in proc.stdout
    assert "Traceback" not in proc.stderr


def test_corrupt_results_file_fails_clearly(tmp_path):
    proc, _ = run_gate(tmp_path, "{not json", FULL)
    assert proc.returncode == 2
    assert "not valid JSON" in proc.stdout
    assert "Traceback" not in proc.stderr


def test_malformed_entry_fails_clearly(tmp_path):
    bad = dict(FULL, multi_tenant=[1, 2, 3])     # entry is not an object
    proc, _ = run_gate(tmp_path, bad, FULL)
    assert proc.returncode == 1
    assert "multi_tenant/speedup missing from results" in proc.stdout
    assert "Traceback" not in proc.stderr


def test_non_numeric_metric_fails_clearly(tmp_path):
    bad = dict(FULL, multi_tenant={"speedup": "1.3x"})
    proc, _ = run_gate(tmp_path / "gate", bad, FULL)
    assert proc.returncode == 1
    assert "multi_tenant/speedup missing from results" in proc.stdout
    assert "Traceback" not in proc.stderr
    # and --refresh must refuse to persist it into the baseline
    proc, bp = run_gate(tmp_path / "refresh", bad, None, "--refresh")
    assert proc.returncode == 2
    assert "REFUSED" in proc.stdout
    assert not bp.exists()


def test_refresh_writes_complete_baseline(tmp_path):
    proc, bp = run_gate(tmp_path, FULL, None, "--refresh")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    written = json.loads(bp.read_text())
    assert written == FULL


def test_refresh_refuses_incomplete_results(tmp_path):
    partial = {k: v for k, v in FULL.items() if k != "reclaim_speedup"}
    proc, bp = run_gate(tmp_path, partial, None, "--refresh")
    assert proc.returncode == 2
    assert "REFUSED" in proc.stdout
    assert not bp.exists(), "refused refresh must not write a baseline"
