"""Cluster-scale coordination (core/cluster.py + the pieces it federates).

Pins the layers the ``cluster_tenant`` benchmark stacks:

* seeded heterogeneous peer profiles (deterministic draws, rack striping,
  capacity overrides, per-peer latency pricing — and the all-defaults
  profile set being bitwise invisible),
* the ``ClusterCoordinator`` host lifecycle — floor reservation, slab
  conservation, fail/rejoin reclamation, two-level lease escalation,
* recovery-storm admission — grants shed to floor deficits inside a storm
  window, the staggered exponential ladder charged per gated call,
  degraded hosts pinned to floor until the backlog clears,
* strictly cross-domain replica placement — the placer never co-locates a
  replica with any copy's failure domain, so a whole-rack crash loses
  nothing (the invariant checker's domain-disjointness law),
* ``ClusterInvariantChecker`` — cluster-wide convergence over surviving
  stores only.
"""
import numpy as np
import pytest

from repro.core import (ClusterCoordinator, ClusterInvariantChecker,
                        HostState, InvariantChecker, OrchestrationConfig,
                        PeerProfile, ReplicaPlacer, TieredPageStore,
                        POLICIES, PAPER_COSTS, draw_peer_profiles,
                        peers_in_domain, profile_domains)


def make_store(*, pool=128, min_pool=None, n_peers=6, blocks=256, seed=0,
               policy="valet", **kw):
    cfg = OrchestrationConfig(
        policy=POLICIES[policy], costs=PAPER_COSTS, pool_capacity=pool,
        min_pool=pool if min_pool is None else min_pool, max_pool=pool,
        n_peers=n_peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed, **kw)
    return TieredPageStore.from_config(cfg)


def populate(store, n_pages):
    for p in range(n_pages):
        store.write(p)
    store.drain()
    return store


# -- heterogeneous peer profiles ----------------------------------------------

def test_draw_peer_profiles_deterministic_and_striped():
    a = draw_peer_profiles(8, 2, seed=7, latency_scale_us=3.0)
    b = draw_peer_profiles(8, 2, seed=7, latency_scale_us=3.0)
    assert a == b                                # identical seeds, identical set
    assert a != draw_peer_profiles(8, 2, seed=8, latency_scale_us=3.0)
    # contiguous rack stripes: first half domain 0, second half domain 1
    assert [p.domain for p in a] == [0, 0, 0, 0, 1, 1, 1, 1]
    assert all(p.latency_us > 0 for p in a)
    base = 1024
    assert all(base // 2 <= p.capacity_blocks <= base * 3 // 2 for p in a)


def test_draw_peer_profiles_zero_scale_keeps_homogeneous_latency():
    profs = draw_peer_profiles(4, 2, seed=0, latency_scale_us=0.0)
    assert all(p.latency_us == 0.0 for p in profs)


def test_profile_domains_flat_set_is_none():
    flat = tuple(PeerProfile(domain=0) for _ in range(4))
    assert profile_domains(flat) is None
    assert profile_domains(()) is None
    assert profile_domains(draw_peer_profiles(4, 2)) == [0, 0, 1, 1]


def test_peer_profile_length_mismatch_rejected():
    with pytest.raises(ValueError):
        make_store(n_peers=4, peer_profiles=draw_peer_profiles(6, 2))


def test_default_profiles_are_bitwise_invisible():
    """An all-defaults profile tuple (no latency, no capacity override,
    one domain) must run bitwise identically to no profiles at all."""
    flat = tuple(PeerProfile() for _ in range(6))
    plain = populate(make_store(seed=3), 600)
    prof = populate(make_store(seed=3, peer_profiles=flat), 600)
    rng = np.random.default_rng(5)
    pages = rng.integers(0, 600, size=2000, dtype=np.int64)
    is_write = rng.random(2000) < 0.3
    for st in (plain, prof):
        for i in range(0, 2000, 250):
            st.access_batch(pages[i:i + 250], is_write[i:i + 250])
            st.background_tick()
        st.drain()
    assert plain.stats.time_us == prof.stats.time_us
    assert plain.stats.remote_hits == prof.stats.remote_hits
    assert plain.pool.size == prof.pool.size


def test_per_peer_latency_prices_remote_reads():
    """Uniform extra latency does not change placement, so the run with
    profiles costs exactly ``extra`` more per remote read hit than the run
    without — the time delta is an integral multiple of ``extra``."""
    extra = 37.0
    profs = tuple(PeerProfile(latency_us=extra) for _ in range(6))
    plain = populate(make_store(pool=16, seed=9), 200)
    prof = populate(make_store(pool=16, seed=9, peer_profiles=profs), 200)
    for st in (plain, prof):
        for p in range(200):
            st.read(p)
    delta = prof.stats.time_us - plain.stats.time_us
    assert delta > 0
    hits = delta / extra
    assert abs(hits - round(hits)) < 1e-9 and round(hits) >= 1


def test_per_peer_capacity_override():
    profs = (PeerProfile(capacity_blocks=7), PeerProfile())
    st = make_store(n_peers=2, blocks=256, peer_profiles=profs)
    assert st.peers[0].capacity == 7
    assert st.peers[1].capacity == 256


# -- strictly cross-domain replica placement ----------------------------------

def test_replica_placer_strictly_cross_domain():
    domains = [0, 0, 1, 1, 2, 2]
    placer = ReplicaPlacer(np.random.default_rng(0), domains=domains)
    free = [100] * 6
    for primary in range(6):
        for _ in range(50):
            reps = placer.place(primary, free, n_replicas=2)
            doms = {domains[primary]} | {domains[r] for r in reps}
            assert len(doms) == 1 + len(reps)    # all copies distinct racks


def test_replica_placer_short_when_no_cross_domain_peer():
    # every peer shares the primary's rack: strictly cross-domain placement
    # must come up short (no same-rack fallback) — the caller's repair
    # queue owns eventual convergence
    placer = ReplicaPlacer(np.random.default_rng(0), domains=[0, 0, 0])
    assert placer.place(0, [100, 100, 100], n_replicas=1) == []
    # two racks, two replicas wanted: only one distinct rack remains after
    # the first replica, so the set stays short at one copy
    placer = ReplicaPlacer(np.random.default_rng(0), domains=[0, 0, 1, 1])
    assert len(placer.place(0, [100] * 4, n_replicas=2)) == 1


def test_store_replicas_never_share_primary_domain():
    profs = draw_peer_profiles(6, 3, seed=2)
    doms = [p.domain for p in profs]
    st = populate(make_store(n_peers=6, peer_profiles=profs, seed=2), 800)
    assert st._peer_domain == doms
    n_rep = 0
    for (peer, _), reps in st.block_replicas.items():
        for rpeer, _ in reps:
            assert doms[rpeer] != doms[peer]
            n_rep += 1
    assert n_rep > 0                       # the law is vacuous otherwise
    InvariantChecker(st).check()           # includes domain disjointness


def test_rack_crash_loses_nothing_cross_domain():
    """Killing every peer of one rack must recover every page: primary and
    replica never share a rack."""
    profs = draw_peer_profiles(6, 2, seed=4)
    doms = [p.domain for p in profs]
    st = populate(make_store(n_peers=6, peer_profiles=profs, seed=4), 800)
    lost = 0
    for peer in peers_in_domain(doms, 1):
        _, l = st.fail_peer(peer)
        lost += l
    assert lost == 0
    # with the whole far rack dead nothing is legally placeable: the
    # backlog persists (degraded, not crashed) ...
    assert st.repairq
    assert st.repair_quiesce() == 0
    # ... until the rack rejoins, at which point repair converges
    for peer in peers_in_domain(doms, 1):
        st.rejoin_peer(peer)
    st.repair_quiesce()
    chk = InvariantChecker(st)
    chk.check()
    chk.check_replication_restored()


# -- cluster coordinator: host lifecycle --------------------------------------

def test_register_reserves_floor_and_conserves():
    cl = ClusterCoordinator(1000)
    c0 = cl.register_host(min_slab=200, max_slab=600)
    c1 = cl.register_host(min_slab=300)
    assert cl.free() == 500
    assert c0.total_pages == 200 and c1.total_pages == 300
    assert c0.host_id != c1.host_id and c0.cluster is cl
    cl.check_invariants()
    with pytest.raises(ValueError):
        cl.register_host(min_slab=501)     # floor does not fit
    assert cl.deregister_host(c1.host_id) == 300
    assert cl.free() == 800
    cl.check_invariants()


def test_fail_host_reclaims_whole_slab_and_rejoin_restores_floor():
    cl = ClusterCoordinator(1000)
    coord = cl.register_host(min_slab=200, max_slab=600)
    hid = coord.host_id
    assert cl.lease_slab(hid, 150) == 150
    coord.total_pages += 150               # the host folds the grant in
    coord._free += 150
    cl.check_invariants()
    assert cl.fail_host(hid) == 350        # floor + leased, all at once
    rec = cl.hosts()[0]
    assert rec.state is HostState.DOWN and rec.slab == 0
    assert rec.coordinator is None and coord.cluster is None
    assert cl.free() == 1000
    cl.check_invariants()
    assert cl.lease_slab(hid, 50) == 0     # DOWN hosts lease nothing
    fresh = cl.rejoin_host(hid)
    assert fresh is not coord and fresh.total_pages == 200
    assert cl.free() == 800
    cl.check_invariants()
    with pytest.raises(AssertionError):
        cl.rejoin_host(hid)                # already UP


def test_lease_slab_is_grow_only_and_capped():
    cl = ClusterCoordinator(500, storm_window=0)
    coord = cl.register_host(min_slab=100, max_slab=250)
    hid = coord.host_id
    assert cl.lease_slab(hid, 1000) == 150         # capped at max_slab
    coord.total_pages += 150
    coord._free += 150
    assert cl.lease_slab(hid, 10) == 0             # at cap: nothing more
    assert cl.stats.pages_slab_leased == 150
    cl.check_invariants()


# -- recovery-storm admission -------------------------------------------------

def test_storm_sheds_grants_to_floor_and_charges_ladder():
    cl = ClusterCoordinator(2000, backoff_base_us=8.0, storm_window=4)
    survivor = cl.register_host(min_slab=100, max_slab=800)
    victim = cl.register_host(min_slab=100, max_slab=800)
    sid = survivor.host_id
    cl.fail_host(victim.host_id)
    assert cl.storm_active()
    # gated call 1: the survivor sits at its floor — zero deficit, zero
    # grant, first rung of the ladder is free (2^0 - 1)
    assert cl.lease_slab(sid, 300) == 0
    assert cl.stats.n_storm_denials == 1
    assert cl.stats.storm_wait_us == 0.0
    # rungs 2..3 escalate: 8*(2^1-1), then 8*(2^2-1)
    assert cl.lease_slab(sid, 300) == 0
    assert cl.stats.storm_wait_us == 8.0
    assert cl.lease_slab(sid, 300) == 0
    assert cl.stats.storm_wait_us == 8.0 + 24.0
    assert cl.stats.n_storm_denials == 3
    # 4th gated call exhausts the window; afterwards grants flow again
    assert cl.lease_slab(sid, 300) == 0
    assert not cl.storm_active()
    got = cl.lease_slab(sid, 300)
    assert got == 300                      # ungated: full grant
    survivor.total_pages += got
    survivor._free += got
    assert cl.hosts()[0].storm_attempts == 0      # grant resets the ladder
    cl.check_invariants()


def test_storm_grant_covers_floor_deficit():
    """Mid-storm a rejoining host is guaranteed its floor — deficits are
    grantable even while everyone else is shed to zero."""
    cl = ClusterCoordinator(1000, storm_window=8)
    coord = cl.register_host(min_slab=200, max_slab=600)
    hid = coord.host_id
    cl.fail_host(hid)
    fresh = cl.rejoin_host(hid)
    assert fresh.total_pages == 200        # floor re-reserved by rejoin
    assert cl.storm_active()
    assert cl.lease_slab(hid, 100) == 0    # above floor: shed
    assert cl.stats.n_storm_denials == 1
    cl.check_invariants()


def test_headroom_shed_during_storm_and_for_degraded():
    cl = ClusterCoordinator(1000, storm_window=2)
    c0 = cl.register_host(min_slab=100, max_slab=400)
    c1 = cl.register_host(min_slab=100, max_slab=400)
    h0, h1 = c0.host_id, c1.host_id
    assert cl.headroom_for(h0) == 300      # max - slab, free permitting
    cl.note_host_degraded(h0, 17)
    assert cl.headroom_for(h0) == 0        # degraded: floor only
    assert cl.headroom_for(h1) == 300
    cl.note_host_degraded(h0, 0)
    assert cl.headroom_for(h0) == 300      # backlog cleared: released
    assert cl.stats.n_degraded_reports == 1
    assert cl.stats.n_degraded_clears == 1
    cl.fail_host(h1)
    assert cl.headroom_for(h0) == 0        # storm: everyone to floor
    assert cl.headroom_for(h1) == 0        # DOWN: nothing
    cl.lease_slab(h0, 1)
    cl.lease_slab(h0, 1)                   # window (2) consumed
    assert cl.headroom_for(h0) == 300


def test_degraded_host_pinned_to_floor_outside_storm():
    cl = ClusterCoordinator(1000, storm_window=0)
    coord = cl.register_host(min_slab=100, max_slab=500)
    hid = coord.host_id
    cl.note_host_degraded(hid, 5)
    assert cl.lease_slab(hid, 200) == 0    # at floor + degraded: no growth
    cl.note_host_degraded(hid, 0)
    got = cl.lease_slab(hid, 200)
    assert got == 200                      # throttle released with backlog
    coord.total_pages += got
    coord._free += got
    cl.check_invariants()
    cl.note_host_degraded(999, 3)          # unknown host: ignored, no raise


# -- two-level pooling: container -> host -> cluster --------------------------

def test_container_growth_escalates_to_cluster_slab():
    """A container outgrowing its host's slab pulls more slab from the
    cluster transparently through the host coordinator's lease path."""
    cl = ClusterCoordinator(4096, storm_window=0)
    coord = cl.register_host(min_slab=96, max_slab=1024)
    st = make_store(pool=512, min_pool=64, coordinator=coord,
                    container_name="c0", seed=1)
    populate(st, 1500)
    rec = cl.hosts()[0]
    assert rec.slab > 96                   # the host leased beyond its floor
    assert rec.coordinator.total_pages == rec.slab
    assert cl.stats.pages_slab_leased == rec.slab - 96
    assert st.pool.size > 64               # ... and the container grew
    cl.check_invariants()
    ClusterInvariantChecker(cl, {rec.hid: [st]}).check()


def test_available_for_includes_cluster_headroom():
    cl = ClusterCoordinator(4096, storm_window=0)
    coord = cl.register_host(min_slab=96, max_slab=1024)
    lease = coord.register(min_pages=64, max_pages=512)
    solo = ClusterCoordinator(4096).register_host(min_slab=96, max_slab=96)
    solo_lease = solo.register(min_pages=64, max_pages=512)
    # same host slab, but the clustered host advertises its leasable room
    assert lease.available() == solo_lease.available() + (1024 - 96)


def test_cluster_checker_skips_down_hosts_stores():
    cl = ClusterCoordinator(2048, storm_window=0)
    c0 = cl.register_host(min_slab=96, max_slab=512)
    c1 = cl.register_host(min_slab=96, max_slab=512)
    s0 = populate(make_store(pool=128, min_pool=64, coordinator=c0,
                             seed=0), 400)
    s1 = populate(make_store(pool=128, min_pool=64, coordinator=c1,
                             seed=1), 400)
    stores = {c0.host_id: [s0], c1.host_id: [s1]}
    chk = ClusterInvariantChecker(cl, stores)
    chk.check()
    chk.check_recovery_converged()
    s1.fail_peer(0)                        # leaves s1 with an open backlog
    assert s1.repairq
    cl.fail_host(c1.host_id)               # ... but its host dies with it
    chk.check()                            # dead host's store not checked
    chk.check_recovery_converged()
    assert [st for st in chk._live_stores()] == [s0]


def test_cluster_recovery_converges_end_to_end():
    """Host fail + rejoin with fresh containers: the checker proves the
    cluster came back conserved and fully replicated."""
    cl = ClusterCoordinator(2048, storm_window=4)
    c0 = cl.register_host(min_slab=96, max_slab=512)
    c1 = cl.register_host(min_slab=96, max_slab=512)
    s0 = populate(make_store(pool=128, min_pool=64, coordinator=c0,
                             seed=0), 400)
    populate(make_store(pool=128, min_pool=64, coordinator=c1, seed=1), 400)
    stores = {c0.host_id: [s0], c1.host_id: []}
    cl.fail_host(c1.host_id)
    fresh = cl.rejoin_host(c1.host_id)
    s1b = populate(make_store(pool=128, min_pool=64, coordinator=fresh,
                              seed=2), 400)
    stores[c1.host_id] = [s1b]
    for st in (s0, s1b):
        st.drain()
        st.repair_quiesce()
    ClusterInvariantChecker(cl, stores).check_recovery_converged()
    assert cl.stats.n_storms == 2
