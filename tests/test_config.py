"""OrchestrationConfig: the unified API surface and its deprecated aliases.

Pinned contracts:

* every legacy ``TieredPageStore`` keyword still works, emits a
  ``DeprecationWarning`` naming the replacement config field, and produces
  a store *bitwise identical* to ``from_config`` with the same values;
* unknown keywords raise ``TypeError`` exactly as the old signature would;
* ``OrchestrationConfig`` is frozen, ``replace()``-able, and defaults to
  synchronous mode (the bitwise-parity regime);
* the serve engine's ``container_weight`` alias warns and maps to
  ``weight``; ``from_config`` carries the orchestration fields over.
"""
import dataclasses

import numpy as np
import pytest

from repro import (OrchestrationConfig, TieredPageStore, ValetServeEngine,
                   HostMemoryCoordinator)
from repro.core import POLICIES, PAPER_COSTS
from repro.core.config import (LEGACY_STORE_KWARGS, LEGACY_SERVE_KWARGS,
                               config_from_legacy_kwargs)


def small_trace(seed=0, n_pages=300, n_ops=2000):
    rng = np.random.default_rng(seed)
    pages = np.clip(rng.zipf(1.3, n_ops), 1, n_pages) - 1
    is_write = rng.random(n_ops) < 0.4
    return pages.astype(np.int64), is_write


def drive(store, pages, is_write, chunk=128):
    for i in range(0, len(pages), chunk):
        store.access_batch(pages[i:i + chunk], is_write[i:i + chunk])
        store.background_tick()
    store.drain()
    return store


# -- the config object itself --------------------------------------------------

def test_config_is_frozen_and_replaceable():
    cfg = OrchestrationConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.pool_capacity = 2048
    cfg2 = cfg.replace(pool_capacity=2048, async_mode=True)
    assert cfg2.pool_capacity == 2048 and cfg2.async_mode
    assert cfg.pool_capacity == 1024 and not cfg.async_mode  # original intact


def test_config_defaults_are_synchronous():
    st = TieredPageStore.from_config(OrchestrationConfig())
    assert st.orchestrator is None
    assert st.config.async_mode is False


# -- deprecated aliases --------------------------------------------------------

# one representative value per legacy keyword (every alias in the map)
LEGACY_VALUES = {
    "pool_capacity": 96,
    "min_pool": 48,
    "max_pool": 96,
    "n_peers": 3,
    "peer_capacity_blocks": 64,
    "pages_per_block": 8,
    "host_capacity": 1 << 20,
    "free_memory_fn": (lambda: 1 << 20),
    "seed": 7,
    "data_plane": None,
    "batch_reclaim": True,
    "grow_step": 16,
    "coordinator": None,
    "container_name": None,
    "container_weight": 2.0,
    "weight": 2.0,
}


@pytest.mark.parametrize("key", sorted(LEGACY_STORE_KWARGS))
def test_every_legacy_kwarg_warns_and_round_trips(key):
    val = LEGACY_VALUES[key]
    with pytest.warns(DeprecationWarning, match=key):
        cfg = config_from_legacy_kwargs(OrchestrationConfig(), {key: val},
                                        owner="TieredPageStore")
    assert getattr(cfg, LEGACY_STORE_KWARGS[key]) == val


def test_legacy_values_cover_the_alias_map():
    assert set(LEGACY_VALUES) == set(LEGACY_STORE_KWARGS)


def test_unknown_kwarg_raises_type_error():
    with pytest.raises(TypeError, match="unexpected keyword.*bogus"):
        TieredPageStore(POLICIES["valet"], PAPER_COSTS, bogus=3)
    with pytest.raises(TypeError, match="unexpected keyword"):
        config_from_legacy_kwargs(OrchestrationConfig(), {"queue_len": 4},
                                  owner="TieredPageStore")


def test_legacy_store_constructor_warns_per_kwarg():
    with pytest.warns(DeprecationWarning) as rec:
        TieredPageStore(POLICIES["valet"], PAPER_COSTS, pool_capacity=64,
                        min_pool=64, max_pool=64, n_peers=2,
                        peer_capacity_blocks=32)
    assert len([w for w in rec if w.category is DeprecationWarning]) == 5


def test_legacy_and_config_stores_are_bitwise_identical():
    """The alias path folds into a config internally, so both construction
    routes must produce the same store state after a mixed trace —
    identical Stats (including accumulated microseconds), free-list order,
    and page-table arrays."""
    pages, is_write = small_trace(seed=3)
    cfg = OrchestrationConfig(policy=POLICIES["valet"], costs=PAPER_COSTS,
                              pool_capacity=64, min_pool=64, max_pool=64,
                              n_peers=4, peer_capacity_blocks=64,
                              pages_per_block=16, seed=5)
    a = TieredPageStore.from_config(cfg)
    with pytest.warns(DeprecationWarning):
        b = TieredPageStore(POLICIES["valet"], PAPER_COSTS,
                            pool_capacity=64, min_pool=64, max_pool=64,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16, seed=5)
    drive(a, pages, is_write)
    drive(b, pages, is_write)
    assert a.stats == b.stats
    assert a.pool._free == b.pool._free
    assert np.array_equal(a.gpt._l_slot, b.gpt._l_slot)
    assert a.host_pages == b.host_pages


def test_from_config_policy_override_for_sweeps():
    cfg = OrchestrationConfig(pool_capacity=64, min_pool=64, max_pool=64)
    st = TieredPageStore.from_config(cfg, policy=POLICIES["infiniswap"])
    assert st.policy is POLICIES["infiniswap"]
    assert st.config.policy is POLICIES["infiniswap"]   # config reflects it


def test_config_with_coordinator_registers_container():
    coord = HostMemoryCoordinator(512)
    cfg = OrchestrationConfig(pool_capacity=256, min_pool=32, max_pool=256,
                              coordinator=coord, container_name="tenant-a",
                              weight=2.0)
    st = TieredPageStore.from_config(cfg)
    assert st._lease is not None
    rec = coord._containers[st._lease.cid]
    assert rec.name == "tenant-a" and rec.weight == 2.0
    coord.check_invariants()


# -- serve-engine surface ------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    import jax
    from repro.configs import ARCHS, reduced
    from repro.models import transformer as T
    cfg = reduced(ARCHS["granite-3-8b"])
    ctx = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg, ctx


def test_engine_container_weight_alias_warns(tiny_model):
    params, cfg, ctx = tiny_model
    with pytest.warns(DeprecationWarning, match="container_weight"):
        eng = ValetServeEngine(params, cfg, ctx, max_batch=2, max_seq=32,
                               page=4, pool_slots=8, container_weight=3.0)
    assert eng.weight == 3.0
    # the replacement spelling wins when both are given, and is silent
    eng2 = ValetServeEngine(params, cfg, ctx, max_batch=2, max_seq=32,
                            page=4, pool_slots=8, weight=4.0)
    assert eng2.weight == 4.0


def test_engine_from_config_maps_orchestration_fields(tiny_model):
    """PR 8: the serving knobs (page/max_batch/max_seq/pool_slots/
    step_cost_us) ride the config too — from_config takes no loose
    orchestration kwargs."""
    params, cfg, ctx = tiny_model
    ocfg = OrchestrationConfig(policy=POLICIES["valet"], pool_capacity=8,
                               min_pool=8, weight=2.5, seed=11,
                               async_mode=True,
                               max_batch=2, max_seq=32, page=4,
                               step_cost_us=3.0, zero_restore=False)
    eng = ValetServeEngine.from_config(params, cfg, ctx, ocfg)
    assert eng.weight == 2.5
    assert eng.async_mode is True
    assert eng.policy is POLICIES["valet"]
    assert eng.max_batch == 2 and eng.page == 4
    assert eng.max_pages == 32 // 4
    assert eng.pool.size == 8                     # pool_slots -> pool_capacity
    assert eng.step_cost_us == 3.0
    assert eng.zero_restore is False and eng._zero is False


def test_engine_from_config_pool_slots_overrides_capacity(tiny_model):
    params, cfg, ctx = tiny_model
    ocfg = OrchestrationConfig(pool_capacity=64, min_pool=8, pool_slots=16,
                               max_batch=2, max_seq=32, page=4)
    eng = ValetServeEngine.from_config(params, cfg, ctx, ocfg)
    assert eng.pool.max_pages == 16


# one representative value per legacy serve keyword (every alias in the map)
LEGACY_SERVE_VALUES = {
    "max_batch": 2,
    "max_seq": 32,
    "page": 4,
    "pool_slots": 8,
    "step_cost_us": 5.0,
}


def test_serve_values_cover_the_alias_map():
    assert set(LEGACY_SERVE_VALUES) == set(LEGACY_SERVE_KWARGS)


@pytest.mark.parametrize("key", sorted(LEGACY_SERVE_KWARGS))
def test_every_legacy_serve_kwarg_warns_and_round_trips(key):
    val = LEGACY_SERVE_VALUES[key]
    with pytest.warns(DeprecationWarning, match=key):
        cfg = config_from_legacy_kwargs(OrchestrationConfig(), {key: val},
                                        owner="ValetServeEngine",
                                        alias_map=LEGACY_SERVE_KWARGS)
    assert getattr(cfg, LEGACY_SERVE_KWARGS[key]) == val


def test_engine_from_config_legacy_kwargs_warn_but_work(tiny_model):
    params, cfg, ctx = tiny_model
    ocfg = OrchestrationConfig(pool_capacity=8, min_pool=8)
    with pytest.warns(DeprecationWarning) as rec:
        eng = ValetServeEngine.from_config(params, cfg, ctx, ocfg,
                                           max_batch=2, max_seq=32, page=4)
    assert len([w for w in rec if w.category is DeprecationWarning]) == 3
    assert eng.max_batch == 2 and eng.page == 4
    with pytest.raises(TypeError, match="unexpected keyword.*bogus"):
        ValetServeEngine.from_config(params, cfg, ctx, ocfg, bogus=1)
