"""HostMemoryCoordinator: cross-container slab arbitration (§3.4).

Three contracts are pinned here:

* **Conservation** — leased + free always equals the slab, every
  container's lease mirrors its pool size exactly, and no container is
  ever pushed below its ``min_pages`` floor, under randomized interleaved
  traffic with pressure events and forced donations.
* **N=1 parity** — a coordinator with a single container is *bitwise
  identical* to a plain pool whose ``free_memory_fn`` reports the slab
  size: same Stats, same per-op latencies, same pool sizing decisions.
* **Arbitration direction** — under skew the idle container donates and
  the busy one expands (idle-first, weighted-fair, floors respected).
"""
import numpy as np
import pytest

from repro.core import (TieredPageStore, POLICIES, PAPER_COSTS,
                        HostMemoryCoordinator, Tier)


def make_store(*, coordinator=None, free_memory_fn=None, capacity=384,
               min_pool=32, max_pool=320, seed=0, peers=4, blocks=256,
               name=None, weight=1.0, grow_step=None):
    return TieredPageStore(
        POLICIES["valet"], PAPER_COSTS, pool_capacity=capacity,
        min_pool=min_pool, max_pool=max_pool, n_peers=peers,
        peer_capacity_blocks=blocks, pages_per_block=16, seed=seed,
        free_memory_fn=free_memory_fn, grow_step=grow_step,
        coordinator=coordinator, container_name=name,
        container_weight=weight)


# -- N=1 bitwise parity --------------------------------------------------------


def drive_chunks(store, pages, is_write, chunk=64, events=None):
    lats = []
    for i in range(0, len(pages), chunk):
        lats.append(store.access_batch(pages[i:i + chunk],
                                       is_write[i:i + chunk]))
        store.background_tick()
        if events and i in events:
            events[i](store)
    return np.concatenate(lats)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_single_container_bitwise_parity(seed):
    """A 1-container coordinator must be invisible: identical Stats,
    latencies, pool sizing and slot states vs. the plain free_memory_fn
    pool over a mixed trace with ticks, pool pressure and peer pressure."""
    slab = 1024
    rng = np.random.default_rng(seed)
    n_ops = 4000
    pages = np.clip(rng.zipf(1.2, n_ops), 1, 700) - 1
    is_write = rng.random(n_ops) < 0.35
    events = {
        1024: lambda s: s.local_pressure(48),
        2048: lambda s: s.peer_pressure(0, 4),
        3072: lambda s: s.local_pressure(16),
    }

    plain = make_store(free_memory_fn=lambda: slab, seed=seed)
    coord = HostMemoryCoordinator(slab)
    managed = make_store(coordinator=coord, seed=seed, name="only")

    la = drive_chunks(plain, pages, is_write, events=events)
    lb = drive_chunks(managed, pages, is_write, events=events)

    assert np.array_equal(la, lb), "per-op latencies diverged"
    assert plain.stats == managed.stats
    assert plain.step == managed.step
    p, m = plain.pool, managed.pool
    assert p.size == m.size
    assert (p.n_grow, p.n_shrink, p.n_alloc_from_pool, p.n_reclaimed,
            p.n_alloc_failed) == \
        (m.n_grow, m.n_shrink, m.n_alloc_from_pool, m.n_reclaimed,
         m.n_alloc_failed)
    assert p._free == m._free, "free-list (slot assignment order) diverged"
    assert [(s.state, s.logical_page) for s in p.slots] == \
        [(s.state, s.logical_page) for s in m.slots]
    # the page table resolves every page identically
    hi = 700
    for pg in range(hi):
        assert plain.gpt.lookup(pg) == managed.gpt.lookup(pg), pg
    # and the coordinator's books close: one lease covering the pool
    coord.check_invariants()
    assert coord.containers()[0].leased == m.size
    assert coord.free() == slab - m.size


# -- conservation + floors under randomized interleaving -----------------------


@pytest.mark.parametrize("seed", [0, 7])
def test_slab_conservation_randomized(seed):
    """Random interleaved traffic across 3 containers with pressure events:
    after every slice the slab is conserved, leases mirror pool sizes, and
    nobody sits below its floor."""
    total = 512
    mins = [32, 48, 16]
    coord = HostMemoryCoordinator(total)
    stores = [make_store(coordinator=coord, capacity=total, min_pool=mins[c],
                         max_pool=total - sum(mins) + mins[c], seed=seed + c,
                         name=f"c{c}", grow_step=32)
              for c in range(3)]
    rng = np.random.default_rng(seed)
    for step in range(120):
        c = int(rng.integers(3))
        st = stores[c]
        kind = rng.random()
        if kind < 0.70:
            n = int(rng.integers(8, 96))
            pages = rng.integers(0, 400, size=n)
            st.access_batch(pages, rng.random(n) < 0.5)
        elif kind < 0.80:
            st.background_tick()
        elif kind < 0.88:
            st.peer_pressure(int(rng.integers(4)), int(rng.integers(1, 4)))
        elif kind < 0.96:
            st.local_pressure(int(rng.integers(8, 64)))
        else:
            st.drain()
        coord.check_invariants()
        for c2, s2 in enumerate(stores):
            assert s2.pool.size >= mins[c2]
            s2.pool.check_invariants()
    # the tight slab must actually have exercised arbitration
    assert coord.stats.n_lease_calls > 0
    total_leased = sum(r.leased for r in coord.containers())
    assert total_leased + coord.free() == total


def test_min_pages_floor_survives_extreme_skew():
    """One container hammers an oversized working set; the idle ones must
    donate down to — but never through — their floors."""
    total = 320
    coord = HostMemoryCoordinator(total)
    idle = [make_store(coordinator=coord, capacity=total, min_pool=32,
                       max_pool=256, seed=c, name=f"idle{c}")
            for c in range(2)]
    hog = make_store(coordinator=coord, capacity=total, min_pool=32,
                     max_pool=256, seed=9, name="hog", grow_step=64)
    # idle containers build up some pool, then go quiet
    for c, st in enumerate(idle):
        st.access_batch(np.arange(150) + 1000 * c, True)
        st.background_tick()
        st.drain()
        st.background_tick()
    for r in range(30):
        hog.access_batch(np.arange(r * 100, r * 100 + 100), True)
        hog.background_tick()
    coord.check_invariants()
    for st in idle:
        assert st.pool.size >= 32
    assert hog.pool.size > 32, "hog never expanded"
    assert coord.stats.pages_reclaimed > 0, "arbitration never fired"


def test_idle_donates_before_busy():
    """Weighted-fair reclamation is idle-first: with one busy and one idle
    donor holding equal leases, the idle one donates (more)."""
    total = 384
    coord = HostMemoryCoordinator(total)
    busy = make_store(coordinator=coord, capacity=total, min_pool=32,
                      max_pool=320, seed=0, name="busy")
    quiet = make_store(coordinator=coord, capacity=total, min_pool=32,
                       max_pool=320, seed=1, name="quiet")
    grower = make_store(coordinator=coord, capacity=total, min_pool=32,
                        max_pool=320, seed=2, name="grower", grow_step=64)
    for st in (busy, quiet):
        st.access_batch(np.arange(120), True)
        st.background_tick()
        st.drain()
        st.background_tick()
    # only the busy one keeps producing demand signal
    for r in range(6):
        busy.access_batch(np.arange(80), False)
    for r in range(12):
        grower.access_batch(np.arange(r * 80, r * 80 + 80) + 5000, True)
        grower.background_tick()
    recs = {r.name: r for r in coord.containers()}
    assert recs["quiet"].pages_donated_total >= \
        recs["busy"].pages_donated_total
    assert recs["quiet"].pages_donated_total > 0
    coord.check_invariants()


def test_registration_admission_control():
    """Floors are reserved at admission; an overflowing floor is rejected."""
    coord = HostMemoryCoordinator(100)
    coord.register(min_pages=60, max_pages=100)
    with pytest.raises(ValueError):
        coord.register(min_pages=60, max_pages=100)
    # a fitting one is fine afterwards
    coord.register(min_pages=40, max_pages=80)
    coord.check_invariants()


def test_donation_respects_live_data():
    """A donor whose tail slots hold live (IN_USE, staged) data donates only
    what is actually free — never fabricates pages."""
    total = 256
    coord = HostMemoryCoordinator(total)
    donor = make_store(coordinator=coord, capacity=total, min_pool=32,
                       max_pool=224, seed=0, name="donor")
    # fill the donor with unflushed writes (staging holds the only copy)
    donor.access_batch(np.arange(100), True)
    leased_before = donor.pool.size
    got = donor.host_donate(500)
    coord.check_invariants()
    assert donor.pool.size == leased_before - got
    assert donor.pool.size >= 32
    # donation must not lose data: every written page still resolves to a
    # live tier (donation flushes before it sheds, §5.2-safely)
    for pg in range(100):
        loc = donor.gpt.lookup(pg)
        assert loc.tier in (Tier.LOCAL, Tier.PEER, Tier.HOST), (pg, loc)
    donor.pipeline.check_invariants()


# -- leased-pool overrun prediction (plan-once engine, PR-4 follow-up) ---------


def test_leased_prefix_capacity_is_lower_bound_and_nontrivial():
    """For a coordinator-leased pool the overrun predictor must (a) stay a
    lower bound on the allocations that actually land back-to-back and
    (b) exceed the bare free count when the free slab can fund growth —
    the ROADMAP follow-up this PR closes (the old fallback returned the
    free count, so every leased segment ended at the free list)."""
    from repro.core import HostMemoryCoordinator, ValetMempool

    coord = HostMemoryCoordinator(256)
    lease = coord.register(min_pages=32, max_pages=200)
    pool = ValetMempool(256, min_pages=32, max_pages=200, lease=lease,
                        grow_step=16)
    n = 150
    cap = pool.alloc_prefix_capacity(n)
    assert cap > pool.free_count(), "prediction fell back to the free count"
    got = 0
    for i in range(n):
        if pool.alloc(i, step=i) is None:
            break
        got += 1
    assert cap <= got, f"predictor overpromised: {cap} > {got}"
    coord.check_invariants()
    pool.check_invariants()


def test_leased_prefix_capacity_conservative_about_reclamation():
    """The lower bound only counts the uncontended free slab: a real lease
    may additionally reclaim an idle co-tenant's excess, so the actual
    back-to-back allocations can exceed — never undercut — the prediction."""
    from repro.core import HostMemoryCoordinator, ValetMempool

    coord = HostMemoryCoordinator(256)
    donor = make_store(coordinator=coord, capacity=256, min_pool=32,
                       max_pool=200, seed=0, name="donor", grow_step=32)
    donor.access_batch(np.arange(150), True)   # grow the donor's lease
    donor.background_tick()
    donor.drain()
    donor.background_tick()
    lease = coord.register(min_pages=16, max_pages=200)
    pool = ValetMempool(256, min_pages=16, max_pages=200, lease=lease,
                        grow_step=16)
    free_slab = coord.free()
    cap = pool.alloc_prefix_capacity(180)
    assert cap <= pool.free_count() + max(free_slab, 0) + 200
    got = 0
    for i in range(180):
        if pool.alloc(i, step=i) is None:
            break
        got += 1
    assert cap <= got, f"predictor overpromised: {cap} > {got}"
    coord.check_invariants()
    pool.check_invariants()
    donor.pool.check_invariants()


@pytest.mark.parametrize("seed", [3, 11])
def test_leased_pool_parity_under_tight_pressure(seed):
    """Two coordinator worlds built identically — one driven per-op, one
    through access_batch — must stay bitwise equal on a tight slab where
    every batch leans on leased growth and weighted-fair reclamation
    (the plan-once engine's leased-pool predictor at work)."""
    rng = np.random.default_rng(seed)
    n_ops = 2500
    pages = np.clip(rng.zipf(1.15, n_ops), 1, 600) - 1
    is_write = rng.random(n_ops) < 0.4

    def build():
        coord = HostMemoryCoordinator(160)
        grower = make_store(coordinator=coord, capacity=160, min_pool=16,
                            max_pool=128, seed=seed, name="grower",
                            grow_step=16)
        # a co-tenant holding lease keeps the slab tight (no donor callback:
        # its pages are pinned, so grants really are slab-bounded)
        pinned = coord.register(min_pages=64, max_pages=128, name="pinned")
        pinned.lease(48)
        return coord, grower

    ca, a = build()
    cb, b = build()
    la = []
    for i in range(n_ops):
        if is_write[i]:
            la.append(a.write(int(pages[i])))
        else:
            la.append(a.read(int(pages[i])))
        if i % 64 == 0:
            a.background_tick()
    lb = np.empty(n_ops, np.float64)
    i = 0
    while i < n_ops:
        nxt = i if i % 64 == 0 else (i // 64 + 1) * 64
        end = min(n_ops, i + 256, nxt + 1)
        lb[i:end] = b.access_batch(pages[i:end], is_write[i:end])
        if (end - 1) % 64 == 0:
            b.background_tick()
        i = end
    assert np.array_equal(np.asarray(la), lb), "per-op latencies diverged"
    assert a.stats == b.stats
    assert a.pool.size == b.pool.size
    assert a.pool._free == b.pool._free, "free-list order diverged"
    assert a.pool.n_grow == b.pool.n_grow
    assert a.pool.n_alloc_failed == b.pool.n_alloc_failed
    ca.check_invariants()
    cb.check_invariants()


# -- K serving engines against one coordinator ---------------------------------


@pytest.mark.slow
def test_two_engines_share_one_coordinator():
    """Two ValetServeEngines lease KV pool pages from one coordinator under
    an oversubscribed slab; outputs stay exact and the books close."""
    import jax
    from repro.configs import ARCHS, reduced
    from repro.models import transformer as T
    from repro.serve import ValetServeEngine

    cfg = reduced(ARCHS["granite-3-8b"])
    ctx = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(4)]

    def run_pair(coordinated):
        coord = HostMemoryCoordinator(40) if coordinated else None
        engines = []
        for e in range(2):
            kw = dict(max_batch=2, max_seq=64, page=4, pool_slots=32,
                      policy=POLICIES["valet"])
            if coordinated:
                kw.update(min_pool=8, coordinator=coord,
                          container_name=f"eng{e}")
            engines.append(ValetServeEngine(params, cfg, ctx, **kw))
        outs = []
        for e, eng in enumerate(engines):
            for p in prompts[e * 2:(e + 1) * 2]:
                eng.submit(p, max_new=8)
        for eng in engines:
            reqs = eng.run(max_steps=300)
            assert all(r.status == "done" for r in reqs)
            outs.append([r.tokens_out
                         for r in sorted(reqs, key=lambda r: r.rid)])
        return outs, coord, engines

    ref, _, _ = run_pair(coordinated=False)
    got, coord, engines = run_pair(coordinated=True)
    assert got == ref, "coordinated engines diverged from reference decode"
    coord.check_invariants()
    for eng, rec in zip(engines, coord.containers()):
        assert rec.leased == eng.pool.size
        assert rec.leased >= 8


@pytest.mark.slow
def test_two_engine_qos_weights_skew_fair_shares():
    """Per-container QoS weights at the serve API: two engines register
    with skewed ``weight=``; the coordinator's weighted-fair shares follow
    the weights, and under a co-tenant's pressure the LIGHT engine is shed
    further (toward its smaller share) than the heavy one."""
    import jax
    from repro.configs import ARCHS, reduced
    from repro.models import transformer as T
    from repro.serve import ValetServeEngine
    from repro.core.policies import POLICIES

    cfg = reduced(ARCHS["granite-3-8b"])
    ctx = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    total = 96
    coord = HostMemoryCoordinator(total)
    engines = []
    for name, w in (("light", 1.0), ("heavy", 3.0)):
        eng = ValetServeEngine(params, cfg, ctx, max_batch=2, max_seq=64,
                               page=4, pool_slots=40, min_pool=8,
                               policy=POLICIES["valet"], coordinator=coord,
                               container_name=name, weight=w)
        engines.append(eng)
        for p in range(2):
            eng.submit(rng.integers(2, cfg.vocab, size=8), max_new=8)
    for eng in engines:
        reqs = eng.run(max_steps=300)
        assert all(r.status == "done" for r in reqs)
    recs = {r.name: r for r in coord.containers()}
    light, heavy = engines
    assert light.weight == 1.0 and heavy.weight == 3.0
    assert coord.fair_share(recs["light"].cid) \
        < coord.fair_share(recs["heavy"].cid)

    # an admitted co-tenant leases hard; both engines are idle, so the
    # weighted-fair pass sheds the light engine closer to its floor
    before = {n: recs[n].leased for n in ("light", "heavy")}
    hog = coord.register(min_pages=8, max_pages=total, name="hog")
    hog.lease(total)
    coord.check_invariants()
    shed_light = before["light"] - recs["light"].leased
    shed_heavy = before["heavy"] - recs["heavy"].leased
    assert recs["light"].leased >= 8 and recs["heavy"].leased >= 8
    assert recs["light"].leased <= recs["heavy"].leased
    assert shed_light + shed_heavy > 0, "no pages were reclaimed"
    for eng, rec in zip(engines, (recs["light"], recs["heavy"])):
        assert rec.leased == eng.pool.size


# -- coordinated remote (peer) pressure fan-out --------------------------------


def test_peer_pressure_fans_out_idle_first():
    """§3.4 extended to remote memory: a pressured peer signals the
    coordinator once; the coordinator routes the demand to the containers
    that actually occupy that peer, idle-first (lowest decayed demand) and
    capped at each holder's footprint — so busy containers' working sets
    survive while idle ones donate, mirroring host-slab reclamation."""
    coord = HostMemoryCoordinator(4096)
    stores = [make_store(coordinator=coord, name=f"c{i}", capacity=48,
                         min_pool=48, max_pool=48, peers=2, blocks=256,
                         seed=i)
              for i in range(2)]
    # both containers spill well past their pools onto the peers
    for st in stores:
        st.access_batch(np.arange(600, dtype=np.int64), True)
        st.drain()
    fp0 = [st._peer_block_footprint(0) for st in stores]
    assert min(fp0) > 0, "precondition: both containers occupy peer 0"

    # make container 0 the busy one; container 1 idle -> donates first
    recs = sorted(coord.containers(), key=lambda r: r.cid)
    recs[0].demand, recs[1].demand = 100.0, 0.0

    ask = fp0[1] // 2
    freed = coord.peer_pressure(0, ask)
    assert freed == ask
    assert stores[0]._peer_block_footprint(0) == fp0[0]   # busy untouched
    assert stores[1]._peer_block_footprint(0) == fp0[1] - freed
    assert coord.stats.n_peer_pressure_events == 1
    assert coord.stats.peer_blocks_freed == freed
    assert recs[1].peer_blocks_freed_total == freed
    assert recs[0].demand < 100.0                         # decayed

    # overflow the idle holder's remaining footprint: the busy one pays the
    # difference, and the grand total is conserved across holders
    big = stores[1]._peer_block_footprint(0) + 3
    freed2 = coord.peer_pressure(0, big)
    assert freed2 == big
    assert stores[1]._peer_block_footprint(0) == 0
    assert stores[0]._peer_block_footprint(0) == fp0[0] - 3
    assert coord.stats.peer_blocks_freed == freed + freed2
    for st in stores:
        st.pipeline.check_invariants()
    coord.check_invariants()


def test_peer_pressure_without_holders_is_a_noop():
    coord = HostMemoryCoordinator(256)
    assert coord.peer_pressure(0, 8) == 0
    assert coord.peer_pressure(0, 0) == 0
    assert coord.stats.peer_blocks_freed == 0


# -- degraded admission throttle & tenant churn (cluster-scale PR) ------------


def test_degraded_lease_shed_to_floor_until_cleared():
    """While a container reports a repair backlog its lease grants are shed
    to the floor; ``clear_degraded`` releases the throttle exactly once."""
    coord = HostMemoryCoordinator(1024)
    lease = coord.register(min_pages=64, max_pages=512, name="c0")
    assert lease.lease(32) == 32                 # healthy: growth flows
    coord.note_degraded(lease.cid, 9)
    assert lease.lease(64) == 0                  # above floor: shed
    assert coord.stats.n_degraded_denials == 1
    coord.clear_degraded(lease.cid)
    assert coord.stats.n_degraded_clears == 1
    coord.clear_degraded(lease.cid)              # already clear: no-op
    assert coord.stats.n_degraded_clears == 1
    assert lease.lease(64) == 64                 # throttle released
    coord.check_invariants()


def test_repair_drain_clears_degraded_and_growth_resumes():
    """Regression (satellite of the cluster PR): the store reports its
    backlog while repairing and fires ``clear_degraded`` when the queue
    drains — a container that crashed a peer must not stay pinned at its
    floor forever."""
    from repro.core import OrchestrationConfig
    coord = HostMemoryCoordinator(4096)
    st = TieredPageStore.from_config(OrchestrationConfig(
        policy=POLICIES["valet"], costs=PAPER_COSTS, pool_capacity=1024,
        min_pool=64, max_pool=1024, grow_step=64, n_peers=4,
        peer_capacity_blocks=256, pages_per_block=16, seed=0,
        coordinator=coord, container_name="c0", repair_rate=4))
    st.access_batch(np.arange(400, dtype=np.int64), True)
    st.drain()
    assert st.pool.size < 1024                   # headroom left to grow into
    st.fail_peer(0)
    assert len(st.repairq) > 4                   # outlives one drain slice
    st.background_tick()                         # report rides the tick
    rec = next(iter(coord.containers()))
    assert rec.degraded_blocks > 0
    assert coord.stats.n_degraded_reports > 0
    # while degraded, traffic must not grow the pool (admission throttled)
    frozen = st.pool.size
    st.access_batch(np.arange(400, 900, dtype=np.int64), True)
    assert st.pool.size == frozen
    for _ in range(200):
        if not st.repairq:
            break
        st.background_tick()
    assert not st.repairq
    assert rec.degraded_blocks == 0              # cleared on the drain tick
    assert coord.stats.n_degraded_clears == 1
    # growth genuinely resumes: drive more traffic and the pool expands
    st.access_batch(np.arange(900, 1500, dtype=np.int64), True)
    for _ in range(8):
        st.background_tick()
    assert st.pool.size > frozen                 # grants flow again
    assert rec.leased == st.pool.size
    coord.check_invariants()


def test_deregister_returns_full_lease_and_arbitrates_admission():
    """Tenant churn: a leaver returns floor + growth in one call, and a
    joiner whose floor exceeds the bare free slab is admitted by
    reclaiming co-tenants' excess instead of being refused."""
    coord = HostMemoryCoordinator(256)
    a = coord.register(min_pages=64, max_pages=256, name="a")
    held = 64 + a.lease(192)
    assert held == 256 and coord.free() == 0

    # joiner: free slab is 0, but a's excess above its floor is reclaimable
    donated = {"n": 0}

    def donate(n):
        got = min(n, held - 64)
        donated["n"] += got
        coord.release(a.cid, got)
        return got

    coord.set_donor(a.cid, donate)
    b = coord.register(min_pages=64, max_pages=128, name="b")
    assert donated["n"] >= 64                    # admission arbitrated
    coord.check_invariants()

    # leaver: the whole lease (floor included) returns at once
    freed = coord.deregister(b.cid)
    assert freed == 64
    assert coord.stats.n_deregistrations == 1
    coord.check_invariants()
    with pytest.raises(KeyError):
        coord.deregister(b.cid)                  # unknown cid stays loud
