"""Migration protocol + activity-based victim selection (paper §3.5)."""
import numpy as np

from repro.core import (ActivityTracker, TieredPageStore, POLICIES,
                        PAPER_COSTS, select_victims_nad, select_victims_mass,
                        power_of_two_choices)
from repro.core.migration import Phase


def populated_store(policy="valet", n_peers=6, blocks=128):
    store = TieredPageStore(POLICIES[policy], PAPER_COSTS,
                            pool_capacity=256, min_pool=32, max_pool=256,
                            n_peers=n_peers, peer_capacity_blocks=blocks,
                            pages_per_block=16, seed=0)
    for p in range(1500):
        store.write(p)
        if p % 32 == 0:
            store.background_tick()
    store.drain()
    return store


def test_nad_selects_least_active():
    t = ActivityTracker()
    t.on_write([1], step=10)
    t.on_write([2], step=50)
    t.on_write([3], step=90)
    assert select_victims_nad(t, [1, 2, 3], 1, step=100) == [1]
    assert select_victims_nad(t, [1, 2, 3], 2, step=100) == [1, 2]


def test_mass_victim_prefers_cold_pages():
    t = ActivityTracker()
    t.on_write([1, 2, 3], step=1)
    t.on_read_mass([2], [10.0])
    t.on_read_mass([3], [0.5])
    assert select_victims_mass(t, [1, 2, 3], 1, step=5) == [1]


def test_power_of_two_choices_prefers_freer():
    # with 4 peers, the freer peer is in the sampled pair w.p. 1/2 and then
    # always wins -> expected pick rate 50% (vs 25% uniform)
    rng = np.random.default_rng(0)
    picks = [power_of_two_choices([1, 100, 1, 1], rng) for _ in range(200)]
    freq = picks.count(1) / 200
    assert 0.38 < freq < 0.62
    assert all(freq > picks.count(i) / 200 for i in (0, 2, 3))


def test_migration_protocol_phases_and_log():
    store = populated_store()
    keys = [k for k in store.blocks if k[0] == 0][:1]
    bid = store._block_id(*keys[0])
    pages = list(store.blocks[keys[0]])
    mig = store.migrator.migrate_block(0, bid, pages)
    assert mig.phase == Phase.DONE
    kinds = [m.kind for m in mig.log]
    assert kinds == ["ALLOC_REQ", "ALLOC_OK", "PARK_WRITES", "COPY_REQ",
                     "COPY_DONE", "FREE_BLOCK"]
    assert mig.dst_peer != 0
    # pages now resolve to the destination peer
    for pg in pages:
        loc = store.gpt.remote_location(pg)
        assert loc.peer == mig.dst_peer


def test_migration_preserves_reads_no_cold_hits():
    """Figure 23: migration instead of delete -> no eviction impact."""
    store = populated_store("valet")
    freed = store.peer_pressure(0, 8)
    assert freed == 8
    for p in range(1500):
        store.read(p)
    assert store.stats.cold_hits == 0


def test_delete_eviction_causes_cold_hits():
    """Figures 5/23 baseline: deletion sends reads to the cold tier."""
    store = populated_store("infiniswap")
    store.peer_pressure(0, 8)
    for p in range(1500):
        store.read(p)
    assert store.stats.cold_hits > 0


def test_migration_destination_not_source():
    store = populated_store()
    store.peer_pressure(2, 4)
    for mig in store.migrator.completed:
        if mig.src_peer == 2:
            assert mig.dst_peer != 2
