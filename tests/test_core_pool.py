"""ValetMempool unit + property tests (paper §3.4, §4.1, Table 2)."""
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis is a soft dependency (requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pool import ValetMempool, SlotState  # noqa: E402


def make_pool(capacity=64, min_pages=8, max_pages=64, free=64):
    return ValetMempool(capacity, min_pages=min_pages, max_pages=max_pages,
                        free_memory_fn=lambda: free)


def test_use_pool_first():
    """Valet allocates from pre-allocated slots first (Table 2)."""
    pool = make_pool()
    s = pool.alloc(0, step=1)
    assert s is not None
    assert pool.slots[s].state == SlotState.IN_USE
    assert pool.n_alloc_from_pool == 1


def test_grow_at_80_percent():
    pool = make_pool(capacity=100, min_pages=10, max_pages=100)
    for i in range(8):                 # 8/10 = 80% usage triggers growth
        pool.alloc(i, step=i)
    assert pool.size > 10
    pool.check_invariants()


def test_growth_capped_by_host_free_memory():
    """Pool stops at 50% of host free pages (paper §4.1)."""
    free = 30
    pool = ValetMempool(100, min_pages=10, max_pages=100,
                        free_memory_fn=lambda: free)
    for i in range(40):
        pool.alloc(i, step=i)
    assert pool.size <= max(15, 10 + pool.grow_step)  # 50% of 30
    pool.check_invariants()


def test_shrink_respects_min_pages():
    pool = ValetMempool(100, min_pages=10, max_pages=100,
                        free_memory_fn=lambda: 0)
    pool.shrink_for_pressure()
    assert pool.size >= 10
    pool.check_invariants()


def test_reclaim_cycle():
    pool = make_pool()
    s = pool.alloc(7, step=1)
    pool.mark_reclaimable(s)
    assert pool.slots[s].state == SlotState.RECLAIMABLE
    page = pool.reclaim(s)
    assert page == 7
    assert pool.slots[s].state == SlotState.FREE


def test_update_flag_blocks_reclaim():
    """§5.2: a slot with a pending newer write-set is not reclaimed."""
    pool = make_pool()
    s = pool.alloc(7, step=1)
    pool.slots[s].update_flag = True
    pool.mark_reclaimable(s)
    assert pool.slots[s].state == SlotState.IN_USE   # kept
    assert not pool.slots[s].update_flag             # flag consumed
    pool.mark_reclaimable(s)                         # second send completes
    assert pool.slots[s].state == SlotState.RECLAIMABLE


# -- grow/shrink boundary properties ------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 24), st.integers(1, 80), st.integers(1, 512))
def test_shrink_floor_holds_while_slots_in_use(min_pages, n_live, ask):
    """Shrinking — pressure- or donation-driven — never drops below
    ``min_pages`` and never releases a non-FREE slot, no matter how many
    IN_USE slots exist or how large the shrink request is."""
    pool = ValetMempool(128, min_pages=min_pages, max_pages=128,
                        free_memory_fn=lambda: 256)
    live = [s for s in (pool.alloc(pg, step=pg) for pg in range(n_live))
            if s is not None]
    pool.free_memory_fn = lambda: 0        # host pressure: shrink target = 0
    pool.shrink_for_pressure()
    pool.shrink_by(ask)
    assert pool.size >= min_pages
    assert pool.size >= len(live), "a live slot was shed"
    for s in live:
        assert pool.slots[s].state == SlotState.IN_USE
    pool.check_invariants()


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 32), st.integers(16, 96), st.integers(100, 10_000))
def test_maybe_grow_respects_max_pages(min_pages, max_pages, host_free):
    """Growth never exceeds ``max_pages`` even with unbounded host memory,
    and ``maybe_grow`` reports False once the cap binds."""
    max_pages = max(max_pages, min_pages)
    pool = ValetMempool(96, min_pages=min_pages, max_pages=max_pages,
                        free_memory_fn=lambda: host_free)
    for pg in range(3 * max_pages):
        pool.alloc(pg, step=pg)
        assert pool.size <= max_pages
    if pool.size == max_pages:
        assert not pool.maybe_grow()
    pool.check_invariants()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "release", "shrink_by",
                                           "pressure", "grow"]),
                          st.integers(1, 48)),
                min_size=1, max_size=120),
       st.integers(8, 24))
def test_n_shrink_accounting_exact_interleaved(ops, min_pages):
    """``n_shrink`` counts exactly the shrink calls that reduced the size
    (and ``shrink_by`` returns exactly the released delta), under
    interleaved alloc/release traffic."""
    host_free = 96
    pool = ValetMempool(96, min_pages=min_pages, max_pages=96,
                        free_memory_fn=lambda: host_free)
    live = []
    expect_shrinks = 0
    page = 0
    for op, arg in ops:
        before = pool.size
        if op == "alloc":
            s = pool.alloc(page, step=page)
            if s is not None:
                live.append(s)
                page += 1
        elif op == "release" and live:
            pool.release(live.pop())
        elif op == "shrink_by":
            got = pool.shrink_by(arg)
            assert got == before - pool.size
            expect_shrinks += int(got > 0)
        elif op == "pressure":
            host_free = arg
            pool.shrink_for_pressure()
            expect_shrinks += int(pool.size < before)
            host_free = 96
        elif op == "grow":
            pool.maybe_grow()
        pool.check_invariants()
    assert pool.n_shrink == expect_shrinks


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["alloc", "reclaim", "grow", "shrink",
                                 "release"]), min_size=1, max_size=200),
       st.integers(8, 32), st.integers(32, 128))
def test_pool_invariants_hold(ops, min_pages, capacity):
    """Random op sequences never violate the slot-state invariants."""
    free = capacity
    pool = ValetMempool(capacity, min_pages=min_pages, max_pages=capacity,
                        free_memory_fn=lambda: free)
    live = []
    page = 0
    for i, op in enumerate(ops):
        if op == "alloc":
            s = pool.alloc(page, step=i)
            if s is not None:
                live.append(s)
                page += 1
        elif op == "release" and live:
            pool.release(live.pop())
        elif op == "reclaim":
            if live:
                s = live.pop()
                pool.mark_reclaimable(s)
                if pool.slots[s].state == SlotState.RECLAIMABLE:
                    pool.reclaim(s)
        elif op == "grow":
            pool.maybe_grow()
        elif op == "shrink":
            pool.shrink_for_pressure()
        pool.check_invariants()
