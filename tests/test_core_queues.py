"""Staging/Reclaimable queue + §5.2 consistency property tests."""
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis is a soft dependency (requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pool import ValetMempool, SlotState  # noqa: E402
from repro.core.queues import WritePipeline  # noqa: E402


def make_pipeline(capacity=128):
    pool = ValetMempool(capacity, min_pages=capacity, max_pages=capacity)
    return WritePipeline(pool, queue_len=1 << 12)


def test_write_then_flush_then_reclaim():
    wp = make_pipeline()
    ws = wp.write((1, 2, 3), step=1)
    assert ws is not None
    assert len(wp.staging) == 1
    sent = []
    wp.flush(10, lambda w: sent.append(w.pages))
    assert sent == [(1, 2, 3)]
    assert len(wp.staging) == 0
    freed = wp.reclaim(10)
    assert {pg for _, pg in freed} == {1, 2, 3}
    wp.check_invariants()


def test_migration_hold_parks_writes():
    """§3.5: writes to a migrating block stay in the staging queue."""
    wp = make_pipeline()
    wp.write((1,), step=1)
    wp.write((2,), step=2)
    wp.staging.hold_pages([1], True)
    sent = []
    wp.flush(10, lambda w: sent.append(w.pages))
    assert sent == [(2,)]                      # page 1 held
    assert len(wp.staging) == 1
    wp.staging.hold_pages([1], False)          # migration done -> unpark
    wp.flush(10, lambda w: sent.append(w.pages))
    assert sent == [(2,), (1,)]
    wp.check_invariants()


def test_multiple_updates_same_page_update_flag():
    """§5.2: older write-set's slot is not reclaimed before the newer one
    is sent — the Update flag skips it."""
    wp = make_pipeline()
    ws1 = wp.write((5,), step=1)
    ws2 = wp.write((5,), step=2)               # newer update, same page
    assert wp.pool.slots[ws1.slots[0]].update_flag

    # send ONLY the first write-set
    wp.flush(1, lambda w: None)
    # slot1 must not be reclaimable yet (newer data still pending)
    st1 = wp.pool.slots[ws1.slots[0]].state
    assert st1 == SlotState.IN_USE
    freed = wp.reclaim(10)
    assert (ws1.slots[0], 5) not in freed

    # send the second; now both may be reclaimed in order
    wp.flush(1, lambda w: None)
    assert wp.pool.slots[ws2.slots[0]].state == SlotState.RECLAIMABLE
    wp.check_invariants()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["write", "flush", "reclaim"]),
                          st.integers(0, 9)), min_size=1, max_size=150))
def test_pipeline_never_reclaims_latest_pending(ops):
    """Property: a page's newest pending slot is never freed while unsent
    (data-loss freedom of the §5.2 protocol)."""
    wp = make_pipeline(capacity=512)
    latest_slot = {}
    sent_seqs = set()
    for i, (op, pg) in enumerate(ops):
        if op == "write":
            ws = wp.write((pg,), step=i)
            if ws is not None:
                latest_slot[pg] = ws.slots[0]
        elif op == "flush":
            wp.flush(2, lambda w: sent_seqs.add(w.seq))
        else:
            wp.reclaim(4)
        # invariant: the newest slot of each page is FREE only if its
        # write-set was sent
        for page, slot in latest_slot.items():
            m = wp.pool.slots[slot]
            if m.state == SlotState.FREE:
                assert all(page not in w.pages for w in wp.staging.entries())
        wp.check_invariants()
