"""Replication & fault tolerance (paper §5.1, Table 3)."""

from repro.core import TieredPageStore, POLICIES, PAPER_COSTS
from repro.core.page_table import GlobalPageTable, Location, Tier


def test_repoint_replica():
    gpt = GlobalPageTable()
    gpt.map_remote(1, Location(Tier.PEER, peer=0, slot=3,
                               replicas=((2, 7),)))
    assert gpt.repoint_replica(1)
    loc = gpt.remote_location(1)
    assert (loc.peer, loc.slot) == (2, 7)
    assert not gpt.repoint_replica(1)      # replicas exhausted


def test_peer_failure_with_replication_loses_nothing():
    store = TieredPageStore(POLICIES["valet"], PAPER_COSTS,
                            pool_capacity=128, min_pool=16,
                            n_peers=6, peer_capacity_blocks=128,
                            pages_per_block=16)
    for p in range(800):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(1)
    assert lost == 0                       # every page had a replica
    # all reads still resolve off the failed peer
    store.local_pressure(10_000)           # drop local copies
    before_cold = store.stats.cold_hits
    for p in range(800):
        store.read(p)
    assert store.stats.cold_hits == before_cold


def test_peer_failure_without_replication_loses_pages():
    from repro.core.policies import Policy
    pol = Policy(name="valet-norep", use_local_pool=True, lazy_send=True,
                 victim="nad", evict_action="migrate", replication=0)
    store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=128, min_pool=16,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16)
    for p in range(600):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(0)
    assert recovered == 0 and lost > 0     # caching-system semantics


def test_table3_cold_backup_mode():
    store = TieredPageStore(POLICIES["infiniswap"], PAPER_COSTS,
                            pool_capacity=128, min_pool=16,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16)
    for p in range(400):
        store.write(p)
    rec, lost = store.fail_peer(0)
    # cold_backup=True: lost pages fall to the cold tier, not NONE
    for p in range(400):
        loc = store.gpt.lookup(p)
        assert loc.tier != Tier.NONE


def _sum_used(store):
    return sum(p.used for p in store.peers)


def test_delete_eviction_frees_unreferenced_replica_blocks():
    """ROADMAP follow-up fixed in this PR: when a primary block dies on the
    delete-eviction path, replica blocks that no page references any more
    (the pages were overwritten and live elsewhere, so nothing repoints to
    them) used to stay allocated on their peers forever."""
    from repro.core.policies import Policy
    pol = Policy(name="del-repl", use_local_pool=True, lazy_send=True,
                 victim="random", evict_action="delete", replication=1,
                 cold_backup=True)
    for batched in (False, True):
        store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=32,
                                min_pool=32, n_peers=4,
                                peer_capacity_blocks=128, pages_per_block=8,
                                seed=3, batch_reclaim=batched)
        for p in range(300):
            store.write(p)
        store.drain()
        for p in range(300):               # rewrite: old blocks go stale
            store.write(p)
        store.drain()
        # block accounting must balance before and after eviction
        assert _sum_used(store) == len(store.blocks)
        used_before = _sum_used(store)
        evicted = store.peer_pressure(0, 6)
        assert evicted == 6
        freed = used_before - _sum_used(store)
        # at least one victim was a stale primary whose replica block was
        # unreferenced: strictly more blocks freed than victims evicted
        assert freed > evicted, (freed, evicted)
        assert _sum_used(store) == len(store.blocks)
        # no dangling replica indexes may survive
        for rep, prim in store._replica_of.items():
            assert rep in store.blocks and prim in store.blocks
        for prim, reps in store.block_replicas.items():
            for rep in reps:
                assert rep in store.blocks, (prim, rep)


def test_delete_eviction_keeps_promoted_replicas():
    """The flip side: when eviction repoints pages onto a replica block
    (promotion), that block is referenced and must NOT be freed."""
    from repro.core.policies import Policy
    pol = Policy(name="del-repl2", use_local_pool=True, lazy_send=True,
                 victim="random", evict_action="delete", replication=1)
    store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=32,
                            min_pool=32, n_peers=4,
                            peer_capacity_blocks=128, pages_per_block=8,
                            seed=4)
    for p in range(200):
        store.write(p)
    store.drain()
    assert _sum_used(store) == len(store.blocks)
    store.peer_pressure(0, 4)
    assert _sum_used(store) == len(store.blocks)
    # every page still resolves to live remote memory (promotion worked)
    for p in range(200):
        loc = store.gpt.lookup(p)
        assert loc.tier in (Tier.LOCAL, Tier.PEER, Tier.HOST), (p, loc)
