"""Replication & fault tolerance (paper §5.1, Table 3)."""
import copy

import numpy as np

from repro.core import TieredPageStore, POLICIES, PAPER_COSTS
from repro.core.page_table import GlobalPageTable, Location, Tier
from repro.core.replication import fail_peer, fail_peer_batched


def test_repoint_replica():
    gpt = GlobalPageTable()
    gpt.map_remote(1, Location(Tier.PEER, peer=0, slot=3,
                               replicas=((2, 7),)))
    assert gpt.repoint_replica(1)
    loc = gpt.remote_location(1)
    assert (loc.peer, loc.slot) == (2, 7)
    assert not gpt.repoint_replica(1)      # replicas exhausted


def test_peer_failure_with_replication_loses_nothing():
    store = TieredPageStore(POLICIES["valet"], PAPER_COSTS,
                            pool_capacity=128, min_pool=16,
                            n_peers=6, peer_capacity_blocks=128,
                            pages_per_block=16)
    for p in range(800):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(1)
    assert lost == 0                       # every page had a replica
    # all reads still resolve off the failed peer
    store.local_pressure(10_000)           # drop local copies
    before_cold = store.stats.cold_hits
    for p in range(800):
        store.read(p)
    assert store.stats.cold_hits == before_cold


def test_peer_failure_without_replication_loses_pages():
    from repro.core.policies import Policy
    pol = Policy(name="valet-norep", use_local_pool=True, lazy_send=True,
                 victim="nad", evict_action="migrate", replication=0)
    store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=128, min_pool=16,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16)
    for p in range(600):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(0)
    assert recovered == 0 and lost > 0     # caching-system semantics


def test_table3_cold_backup_mode():
    store = TieredPageStore(POLICIES["infiniswap"], PAPER_COSTS,
                            pool_capacity=128, min_pool=16,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16)
    for p in range(400):
        store.write(p)
    rec, lost = store.fail_peer(0)
    # cold_backup=True: lost pages fall to the cold tier, not NONE
    for p in range(400):
        loc = store.gpt.lookup(p)
        assert loc.tier != Tier.NONE


def _sum_used(store):
    return sum(p.used for p in store.peers)


# -- batched recovery sweep: bitwise parity against the scalar reference ------

def _synthetic_gpt(seed=0, n_pages=512, n_peers=5):
    """A page table mixing every recovery case: replicated pages (some with
    multiple replicas, some whose replicas sit on other dead peers),
    unreplicated pages, and pages not on the failed peer at all."""
    rng = np.random.default_rng(seed)
    gpt = GlobalPageTable()
    for pg in range(n_pages):
        peer = int(rng.integers(0, n_peers))
        n_reps = int(rng.integers(0, 3))
        reps = tuple((int(rng.integers(0, n_peers)),
                      int(rng.integers(0, 64))) for _ in range(n_reps))
        gpt.map_remote(pg, Location(Tier.PEER, peer=peer,
                                    slot=int(rng.integers(0, 64)),
                                    replicas=reps))
    return gpt


def _gpt_state(gpt):
    hi = len(gpt._r_tier)
    return (gpt._r_tier[:hi].tolist(), gpt._r_peer[:hi].tolist(),
            gpt._r_slot[:hi].tolist(), gpt._r_mapped[:hi].tolist(),
            dict(gpt._replicas))


def test_fail_peer_batched_bitwise_parity():
    """Satellite: the bulk sweep is pinned bitwise against the scalar
    reference — identical (recovered, lost) and identical page-table state
    — across cold-fetch modes and a correlated-failure alive filter."""
    dead_also = {3}
    for cold in (None, lambda pg: None):
        for alive in (None, lambda q: q not in dead_also):
            a = _synthetic_gpt()
            b = copy.deepcopy(a)
            ra = fail_peer(a, 1, cold_fetch=cold, peer_alive=alive)
            rb = fail_peer_batched(b, 1, cold_fetch=cold, peer_alive=alive)
            assert ra == rb
            assert _gpt_state(a) == _gpt_state(b)
    # empty sweep: nothing on the peer
    g = GlobalPageTable()
    assert fail_peer_batched(g, 0) == fail_peer(g, 0) == (0, 0)


def test_store_fail_peer_parity_scalar_vs_batched():
    """Store level: batch_reclaim toggles the sweep implementation; the
    crash outcome and the surviving state must match exactly."""
    outcomes = []
    for batched in (False, True):
        st = TieredPageStore(POLICIES["valet"], PAPER_COSTS,
                             pool_capacity=128, min_pool=16, n_peers=6,
                             peer_capacity_blocks=128, pages_per_block=16,
                             seed=2, batch_reclaim=batched)
        for p in range(800):
            st.write(p)
        st.drain()
        res = st.fail_peer(1)
        outcomes.append((res, _gpt_state(st.gpt), sorted(st.blocks),
                         sorted(st.block_replicas.items()),
                         sorted(st._replica_of.items()),
                         sorted(st.repairq._set),
                         [p.used for p in st.peers]))
    assert outcomes[0] == outcomes[1]


# -- stale replica tuples on survivors are purged -----------------------------

def test_crash_purges_stale_replica_tuples():
    """Satellite regression: after a crash, no surviving page may keep a
    replica tuple naming the DOWN peer — a later repoint (second failure)
    would otherwise promote into dead memory."""
    st = TieredPageStore(POLICIES["valet"], PAPER_COSTS, pool_capacity=128,
                         min_pool=16, n_peers=6, peer_capacity_blocks=128,
                         pages_per_block=16, seed=5)
    for p in range(800):
        st.write(p)
    st.drain()
    assert any(r[0] == 2 for reps in st.gpt._replicas.values()
               for r in reps)              # peer 2 actually holds replicas
    st.fail_peer(2)
    for pg, reps in st.gpt._replicas.items():
        assert all(r[0] != 2 for r in reps), (pg, reps)
    assert all(rep[0] != 2 for rep in st._replica_of)
    assert all(r[0] != 2 for reps in st.block_replicas.values()
               for r in reps)
    # a second failure after the purge promotes only live replicas
    st.repair_quiesce()
    rec, lost = st.fail_peer(3)
    assert lost == 0


def test_purge_replicas_on_peer_unit():
    gpt = GlobalPageTable()
    gpt.map_remote(0, Location(Tier.PEER, peer=0, slot=1,
                               replicas=((2, 5), (3, 6))))
    gpt.map_remote(1, Location(Tier.PEER, peer=1, slot=2,
                               replicas=((2, 7),)))
    gpt.map_remote(2, Location(Tier.PEER, peer=1, slot=3,
                               replicas=((3, 8),)))
    assert gpt.purge_replicas_on_peer(2) == 2
    assert gpt._replicas[0] == ((3, 6),)
    assert 1 not in gpt._replicas          # emptied entry is deleted
    assert gpt._replicas[2] == ((3, 8),)
    assert gpt.purge_replicas_on_peer(2) == 0


def test_delete_eviction_frees_unreferenced_replica_blocks():
    """ROADMAP follow-up fixed in this PR: when a primary block dies on the
    delete-eviction path, replica blocks that no page references any more
    (the pages were overwritten and live elsewhere, so nothing repoints to
    them) used to stay allocated on their peers forever."""
    from repro.core.policies import Policy
    pol = Policy(name="del-repl", use_local_pool=True, lazy_send=True,
                 victim="random", evict_action="delete", replication=1,
                 cold_backup=True)
    for batched in (False, True):
        store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=32,
                                min_pool=32, n_peers=4,
                                peer_capacity_blocks=128, pages_per_block=8,
                                seed=3, batch_reclaim=batched)
        for p in range(300):
            store.write(p)
        store.drain()
        for p in range(300):               # rewrite: old blocks go stale
            store.write(p)
        store.drain()
        # block accounting must balance before and after eviction
        assert _sum_used(store) == len(store.blocks)
        used_before = _sum_used(store)
        evicted = store.peer_pressure(0, 6)
        assert evicted == 6
        freed = used_before - _sum_used(store)
        # at least one victim was a stale primary whose replica block was
        # unreferenced: strictly more blocks freed than victims evicted
        assert freed > evicted, (freed, evicted)
        assert _sum_used(store) == len(store.blocks)
        # no dangling replica indexes may survive
        for rep, prim in store._replica_of.items():
            assert rep in store.blocks and prim in store.blocks
        for prim, reps in store.block_replicas.items():
            for rep in reps:
                assert rep in store.blocks, (prim, rep)


def test_delete_eviction_keeps_promoted_replicas():
    """The flip side: when eviction repoints pages onto a replica block
    (promotion), that block is referenced and must NOT be freed."""
    from repro.core.policies import Policy
    pol = Policy(name="del-repl2", use_local_pool=True, lazy_send=True,
                 victim="random", evict_action="delete", replication=1)
    store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=32,
                            min_pool=32, n_peers=4,
                            peer_capacity_blocks=128, pages_per_block=8,
                            seed=4)
    for p in range(200):
        store.write(p)
    store.drain()
    assert _sum_used(store) == len(store.blocks)
    store.peer_pressure(0, 4)
    assert _sum_used(store) == len(store.blocks)
    # every page still resolves to live remote memory (promotion worked)
    for p in range(200):
        loc = store.gpt.lookup(p)
        assert loc.tier in (Tier.LOCAL, Tier.PEER, Tier.HOST), (p, loc)
