"""Replication & fault tolerance (paper §5.1, Table 3)."""

from repro.core import TieredPageStore, POLICIES, PAPER_COSTS
from repro.core.page_table import GlobalPageTable, Location, Tier


def test_repoint_replica():
    gpt = GlobalPageTable()
    gpt.map_remote(1, Location(Tier.PEER, peer=0, slot=3,
                               replicas=((2, 7),)))
    assert gpt.repoint_replica(1)
    loc = gpt.remote_location(1)
    assert (loc.peer, loc.slot) == (2, 7)
    assert not gpt.repoint_replica(1)      # replicas exhausted


def test_peer_failure_with_replication_loses_nothing():
    store = TieredPageStore(POLICIES["valet"], PAPER_COSTS,
                            pool_capacity=128, min_pool=16,
                            n_peers=6, peer_capacity_blocks=128,
                            pages_per_block=16)
    for p in range(800):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(1)
    assert lost == 0                       # every page had a replica
    # all reads still resolve off the failed peer
    store.local_pressure(10_000)           # drop local copies
    before_cold = store.stats.cold_hits
    for p in range(800):
        store.read(p)
    assert store.stats.cold_hits == before_cold


def test_peer_failure_without_replication_loses_pages():
    from repro.core.policies import Policy
    pol = Policy(name="valet-norep", use_local_pool=True, lazy_send=True,
                 victim="nad", evict_action="migrate", replication=0)
    store = TieredPageStore(pol, PAPER_COSTS, pool_capacity=128, min_pool=16,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16)
    for p in range(600):
        store.write(p)
    store.drain()
    recovered, lost = store.fail_peer(0)
    assert recovered == 0 and lost > 0     # caching-system semantics


def test_table3_cold_backup_mode():
    store = TieredPageStore(POLICIES["infiniswap"], PAPER_COSTS,
                            pool_capacity=128, min_pool=16,
                            n_peers=4, peer_capacity_blocks=64,
                            pages_per_block=16)
    for p in range(400):
        store.write(p)
    rec, lost = store.fail_peer(0)
    # cold_backup=True: lost pages fall to the cold tier, not NONE
    for p in range(400):
        loc = store.gpt.lookup(p)
        assert loc.tier != Tier.NONE
