"""Incremental decode (Valet paged caches) must match the full forward pass
position-by-position for every assigned architecture."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.models import decode as D

CTX = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_incremental_decode_matches_forward(name):
    cfg = reduced(ARCHS[name])
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S_prompt, n_dec, page = 2, 12, 6, 4
    S_total = S_prompt + n_dec
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab)
    fe = None
    if cfg.n_frontend_tokens:
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens, cfg.d_model))

    h, _ = T.forward_hidden(params, toks, cfg, CTX, frontend=fe)
    w = T.unembed_matrix(params, cfg)
    ref_logits = jnp.einsum("bsd,dv->bsv", h, w)

    max_pages = (S_total + page - 1) // page + 1
    caches = D.init_caches(cfg, B, pool_slots=B * max_pages + 2, page=page)
    bt = np.arange(B * max_pages, dtype=np.int32).reshape(B, max_pages)
    bt_j = jnp.array(bt)
    logits, caches = D.prefill(params, toks[:, :S_prompt], cfg, CTX, caches,
                               bt_j, frontend=fe)
    np.testing.assert_allclose(
        np.asarray(logits[:, : cfg.vocab]),
        np.asarray(ref_logits[:, S_prompt - 1, : cfg.vocab]), atol=5e-2)

    for t in range(S_prompt, S_total - 1):
        app_slot = jnp.array(bt[:, t // page])
        app_off = jnp.full((B,), t % page, jnp.int32)
        logits, caches = D.decode_step(params, caches, toks[:, t], cfg, CTX,
                                       bt_j, app_slot, app_off)
        np.testing.assert_allclose(
            np.asarray(logits[:, : cfg.vocab]),
            np.asarray(ref_logits[:, t, : cfg.vocab]), atol=5e-2,
            err_msg=f"position {t}")


def test_inactive_slots_do_not_corrupt_state():
    """Masked decode: a hole in the batch neither appends nor advances."""
    cfg = reduced(ARCHS["granite-3-8b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, page = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0, cfg.vocab)
    max_pages = 4
    caches = D.init_caches(cfg, B, pool_slots=B * max_pages, page=page)
    bt = jnp.arange(B * max_pages, dtype=jnp.int32).reshape(B, max_pages)
    _, caches = D.prefill(params, toks[:, :S], cfg, CTX, caches, bt)

    # step only batch slot 0; slot 1 is a hole
    active = jnp.array([True, False])
    app_slot = bt[:, S // page]
    app_off = jnp.full((B,), S % page, jnp.int32)
    logits1, caches1 = D.decode_step(params, caches, toks[:, S], cfg, CTX,
                                     bt, app_slot, app_off, active=active)
    assert int(caches1["lengths"][0]) == S + 1
    assert int(caches1["lengths"][1]) == S       # hole did not advance

    # now step slot 1; it must produce the same logits as if no hole ran
    logits_both, caches_both = D.decode_step(
        params, caches, toks[:, S], cfg, CTX, bt, app_slot, app_off)
    active2 = jnp.array([False, True])
    logits2, _ = D.decode_step(params, caches1, toks[:, S], cfg, CTX,
                               bt, app_slot, app_off, active=active2)
    np.testing.assert_allclose(np.asarray(logits2[1]),
                               np.asarray(logits_both[1]), atol=1e-4)
