"""Serving-engine behaviour: exactness under memory pressure for every
policy, plus the cost separation the paper reports."""
import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.core.policies import POLICIES
from repro.models import transformer as T
from repro.serve import ValetServeEngine

CTX = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-3-8b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(6)]
    ref = run_engine(params, cfg, prompts, "valet", slots=64)
    return cfg, params, prompts, ref


def run_engine(params, cfg, prompts, policy, slots):
    eng = ValetServeEngine(params, cfg, CTX, max_batch=3, max_seq=64,
                           page=4, pool_slots=slots,
                           policy=POLICIES[policy])
    for p in prompts:
        eng.submit(p, max_new=10)
    reqs = eng.run(max_steps=500)
    outs = [r.tokens_out for r in sorted(reqs, key=lambda r: r.rid)]
    return outs, eng.stats, reqs


@pytest.mark.parametrize("policy", ["valet", "valet-mass", "infiniswap",
                                    "os-swap"])
def test_constrained_pool_outputs_exact(setup, policy):
    cfg, params, prompts, (ref_outs, _, _) = setup
    outs, stats, reqs = run_engine(params, cfg, prompts, policy, slots=10)
    assert all(r.status == "done" for r in reqs)
    assert outs == ref_outs, f"{policy} diverged under memory pressure"


def test_cost_separation_matches_paper(setup):
    """Valet < os-swap << infiniswap on simulated critical-path time
    (Figures 19-21 relative ordering)."""
    cfg, params, prompts, _ = setup
    _, s_valet, _ = run_engine(params, cfg, prompts, "valet", slots=10)
    _, s_osswap, _ = run_engine(params, cfg, prompts, "os-swap", slots=10)
    _, s_inf, _ = run_engine(params, cfg, prompts, "infiniswap", slots=10)
    assert s_valet.sim_time_us < s_osswap.sim_time_us < s_inf.sim_time_us
    assert s_valet.recomputes == 0
    assert s_inf.recomputes > 0
    # valet spills are off the critical path (lazy sending)
    assert s_valet.bg_time_us > 0


def test_unconstrained_pool_never_preempts(setup):
    cfg, params, prompts, _ = setup
    _, stats, _ = run_engine(params, cfg, prompts, "valet", slots=64)
    assert stats.pauses == 0
    assert stats.spilled_pages == 0


@pytest.mark.parametrize("mode", ["zero", "legacy"])
def test_preempt_restore_roundtrips_kv_exactly(setup, mode):
    """Preempt then restore must return every KV page to the pool
    bit-identically.  Legacy mode spills to / drains from host blobs;
    zero-restore mode demotes in place (device tier) and comes back as a
    pure block-table repoint — same bytes, zero copies."""
    cfg, params, prompts, _ = setup
    eng = ValetServeEngine(params, cfg, CTX, max_batch=2, max_seq=64,
                           page=4, pool_slots=32, policy=POLICIES["valet"],
                           zero_restore=(mode == "zero"))
    rid = eng.submit(prompts[0], max_new=8)
    req = eng._requests[rid]
    assert eng._admit(req) and req.status == "active"
    assert req.pages

    slots = {pg: eng.gpt.local_slot(pg) for pg in req.pages}
    before = {}
    for li in eng.paged_layers:
        pool = eng.caches["layers"][li]["pool"]
        before[li] = {pg: (np.asarray(pool.k[s]), np.asarray(pool.v[s]))
                      for pg, s in slots.items()}

    eng._preempt(req)
    assert req.status == "paused"
    assert eng.stats.spilled_pages == len(req.pages)
    for pg in req.pages:
        assert eng.gpt.local_slot(pg) is None
        if mode == "zero":
            assert pg in eng.device                # demoted, bytes in place
            assert pg not in eng.host              # no copy made yet
        else:
            assert pg in eng.host                  # spilled, not deleted

    if mode == "zero":
        # the background flush secures host copies without losing device
        # residency (clean pages stay repointable)
        assert eng._flush_demoted(None) == len(req.pages)
        assert eng.stats.bg_time_us > 0
        for pg in req.pages:
            assert pg in eng.device and pg in eng.host

    assert eng._resume(req) and req.status == "active"
    for li in eng.paged_layers:
        pool = eng.caches["layers"][li]["pool"]
        for pg in req.pages:
            s = eng.gpt.local_slot(pg)
            assert s is not None
            np.testing.assert_array_equal(np.asarray(pool.k[s]),
                                          before[li][pg][0])
            np.testing.assert_array_equal(np.asarray(pool.v[s]),
                                          before[li][pg][1])
    for pg in req.pages:
        assert pg not in eng.host                  # blobs drained on restore
    assert eng.stats.restored_pages == eng.stats.spilled_pages
    if mode == "zero":
        # nothing was reallocated in between: every page repoints to its
        # exact old slot, zero streamed
        assert eng.stats.repointed_pages == len(req.pages)
        assert eng.stats.streamed_pages == 0
        for pg, s in slots.items():
            assert eng.gpt.local_slot(pg) == s


def test_engine_hybrid_arch_with_rings():
    """Engine also serves SWA/hybrid archs (ring + paged mixtures)."""
    cfg = reduced(ARCHS["gemma3-4b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(4)]
    ref, _, _ = run_engine(params, cfg, prompts, "valet", slots=64)
    out, _, reqs = run_engine(params, cfg, prompts, "valet", slots=8)
    assert all(r.status == "done" for r in reqs)
    assert out == ref
