"""Fault-injection subsystem (core/faults.py + the store's fault API).

Pins the four layers the recovery benchmark stacks on top of each other:

* the ``PeerHealth`` state machine (legal edges taken, illegal edges are
  no-ops, SUSPECT deadlines escalate),
* the store-side degradation semantics — retry/backoff pricing on SUSPECT
  accesses, placement steering away from sick peers, timeout escalation to
  a full ``fail_peer``,
* background re-replication — the repair queue restores
  ``policy.replication`` after crashes and rejoin storms (asserted by
  ``check_replication_restored``), degrades gracefully when nothing is
  placeable, and never touches a healthy run,
* the deterministic ``FaultInjector`` — replayed seeded schedules produce
  identical logs, including mid-epoch schedules against the async engine.
"""
import numpy as np
import pytest

from repro.core import (FaultEvent, FaultInjector, HealthState,
                        InvariantChecker, OrchestrationConfig, PeerHealth,
                        RepairQueue, TieredPageStore, POLICIES, PAPER_COSTS,
                        random_schedule, standard_schedule)


def make_store(*, pool=128, min_pool=None, n_peers=6, blocks=256, seed=0,
               async_mode=False, policy="valet", **kw):
    cfg = OrchestrationConfig(
        policy=POLICIES[policy], costs=PAPER_COSTS, pool_capacity=pool,
        min_pool=pool if min_pool is None else min_pool, max_pool=pool,
        n_peers=n_peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed, async_mode=async_mode, **kw)
    return TieredPageStore.from_config(cfg)


def populate(store, n_pages):
    for p in range(n_pages):
        store.write(p)
    store.drain()
    return store


# -- PeerHealth state machine -------------------------------------------------

def test_health_legal_cycle():
    h = PeerHealth(4, suspect_timeout_us=100.0)
    assert h.state_of(0) is HealthState.UP
    assert h.suspect(0, now=10.0)
    assert h.state_of(0) is HealthState.SUSPECT
    assert h.recover(0, now=20.0)
    assert h.state_of(0) is HealthState.UP
    assert h.down(1, now=30.0)
    assert h.rejoin(1, now=40.0)
    assert h.state_of(1) is HealthState.REJOINING
    assert h.activate(1, now=50.0)
    assert h.state_of(1) is HealthState.UP
    # the log carries every taken edge, in order, with timestamps
    assert [(p, a, b) for p, a, b, _ in h.transitions] == [
        (0, "UP", "SUSPECT"), (0, "SUSPECT", "UP"), (1, "UP", "DOWN"),
        (1, "DOWN", "REJOINING"), (1, "REJOINING", "UP")]


def test_health_illegal_edges_are_noops():
    h = PeerHealth(3)
    assert not h.recover(0, now=0.0)       # UP -> UP via recover
    assert not h.rejoin(0, now=0.0)        # UP -> REJOINING
    assert not h.activate(0, now=0.0)      # UP -> UP via activate
    h.down(1, now=1.0)
    assert not h.suspect(1, now=2.0)       # DOWN -> SUSPECT
    assert not h.down(1, now=2.0)          # DOWN -> DOWN
    assert h.state_of(1) is HealthState.DOWN
    # a rejoining peer may crash again
    h.rejoin(1, now=3.0)
    assert h.down(1, now=4.0)


def test_suspect_deadline_expiry():
    h = PeerHealth(2, suspect_timeout_us=100.0)
    h.suspect(0, now=50.0)
    assert h.expired_suspects(now=149.0) == []
    assert h.expired_suspects(now=150.0) == [0]
    # recovering clears the deadline
    h.recover(0, now=60.0)
    assert h.expired_suspects(now=1e9) == []
    assert not h.any_transient()


def test_repair_queue_dedup_and_counters():
    q = RepairQueue()
    assert q.push((0, 1)) and not q.push((0, 1))
    q.push((1, 2))
    assert len(q) == 2 and (0, 1) in q
    assert q.pop() == (0, 1)
    q.requeue((1, 2))                      # already queued: no-op
    assert len(q) == 1
    q.requeue((0, 1))
    assert q.n_enqueued == 2 and q.n_requeued == 1
    assert q.pop() == (1, 2) and q.pop() == (0, 1)
    assert not q


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent(10, "explode", (0,))


# -- store-side degradation ---------------------------------------------------

def _peer_page(st, peer=None):
    """Drop local copies, return a page resident on ``peer`` (or any)."""
    st.local_pressure(10_000)
    for p in range(600):
        loc = st.gpt.lookup(p)
        if loc.tier.name == "PEER" and (peer is None or loc.peer == peer):
            return p, loc.peer
    raise AssertionError("no PEER-resident page found")


def test_suspect_access_pays_retry_backoff():
    st = populate(make_store(), 600)
    # every PEER read prices identically; reads promote to local, so each
    # probe re-pressures and picks a page still resident on the peer
    pg, peer = _peer_page(st)
    base = st.read(pg)
    assert st.mark_suspect(peer)
    pg2, _ = _peer_page(st, peer)
    degraded = st.read(pg2)
    ladder = st.config.backoff_base_us * ((1 << st.config.retry_limit) - 1)
    assert degraded == pytest.approx(base + ladder)
    assert st.stats.retries == st.config.retry_limit
    assert st.stats.retry_wait_us == pytest.approx(ladder)
    # healing stops the penalty
    assert st.clear_suspect(peer)
    pg3, _ = _peer_page(st, peer)
    assert st.read(pg3) == pytest.approx(base)


def test_suspect_peer_excluded_from_placement():
    st = make_store(pool=32, min_pool=32)
    assert st.mark_suspect(2)
    populate(st, 600)
    assert st.peers[2].used == 0           # nothing landed on the suspect
    assert sum(p.used for p in st.peers) > 0


def test_suspect_timeout_escalates_to_down():
    st = make_store(suspect_timeout_us=50.0)
    populate(st, 600)
    assert st.mark_suspect(1)
    deadline = st.stats.time_us + 50.0
    p = 0
    while st.stats.time_us <= deadline:
        st.read(p % 600)
        p += 1
    st.read(p % 600)                       # first op past the deadline polls
    assert st.peers[1].failed
    assert st.health.state_of(1) is HealthState.DOWN
    InvariantChecker(st).check()           # sweep ran: nothing maps peer 1


def test_fail_peer_is_idempotent_and_marks_down():
    st = populate(make_store(), 600)
    rec, lost = st.fail_peer(1)
    assert rec + lost > 0
    assert st.health.state_of(1) is HealthState.DOWN
    assert st.fail_peer(1) == (0, 0)       # second crash is a no-op


# -- background re-replication repair -----------------------------------------

def test_repair_restores_replication_after_crash():
    st = populate(make_store(), 800)
    rec, lost = st.fail_peer(1)
    assert lost == 0 and len(st.repairq) > 0
    copied = st.repair_quiesce()
    assert copied > 0 and not st.repairq
    assert st.stats.repair_pages == copied
    chk = InvariantChecker(st)
    chk.check()
    chk.check_replication_restored()


def test_repair_rides_background_ticks():
    st = populate(make_store(), 800)
    st.fail_peer(1)
    assert st.repairq
    for _ in range(200):
        if not st.repairq:
            break
        st.background_tick()
    assert not st.repairq                  # drained without an explicit barrier
    InvariantChecker(st).check_replication_restored()


def test_rejoin_storm_reuses_returned_capacity():
    st = populate(make_store(), 800)
    st.fail_peer(1)
    st.fail_peer(2)
    assert st.rejoin_peer(1) and st.rejoin_peer(2)
    assert not st.rejoin_peer(3)           # never failed: no-op
    st.repair_quiesce()
    chk = InvariantChecker(st)
    chk.check()
    chk.check_replication_restored()
    st.read(0)                             # health poll activates rejoiners
    assert st.health.state_of(1) is HealthState.UP
    assert st.health.state_of(2) is HealthState.UP


def test_graceful_degradation_when_nothing_placeable():
    # two peers total: after one dies there is no distinct peer left to
    # re-replicate onto — the queue must persist (degraded, not crashed)
    # and the store keeps serving
    st = populate(make_store(n_peers=2, blocks=128), 400)
    rec, lost = st.fail_peer(1)
    assert lost == 0
    backlog = len(st.repairq)
    assert backlog > 0
    assert st.repair_quiesce() == 0        # zero progress, no spin
    assert len(st.repairq) == backlog
    for p in range(400):
        st.read(p)
    InvariantChecker(st).check()
    with pytest.raises(AssertionError):
        InvariantChecker(st).check_replication_restored()


def test_healthy_run_never_touches_fault_counters():
    st = populate(make_store(), 800)
    for p in range(800):
        st.read(p)
    s = st.stats
    assert s.retries == 0 and s.retry_wait_us == 0.0
    assert s.repair_pages == 0 and s.repair_us == 0.0
    assert not st.repairq and not st.health.transitions


# -- deterministic injector ---------------------------------------------------

def _drive_with_injector(st, inj, pages, is_write, chunk=100,
                         check_every=None):
    chk = InvariantChecker(st)
    for i in range(0, len(pages), chunk):
        st.access_batch(pages[i:i + chunk], is_write[i:i + chunk])
        st.background_tick()
        inj.advance(min(chunk, len(pages) - i))
        if check_every and (i // chunk) % check_every == 0:
            chk.check()
    st.drain()
    st.repair_quiesce()
    chk.check()
    return chk


def test_injector_replay_is_deterministic():
    rng = np.random.default_rng(5)
    pages = rng.integers(0, 600, size=4000, dtype=np.int64)
    is_write = rng.random(4000) < 0.3
    logs = []
    for _ in range(2):
        st = populate(make_store(seed=9), 600)
        inj = FaultInjector(st, random_schedule(4000, 6, seed=3))
        _drive_with_injector(st, inj, pages, is_write)
        logs.append(list(inj.log))
    assert logs[0] == logs[1]
    assert len(logs[0]) == 8 and FaultInjector(object(), []).done


def test_standard_schedule_on_sync_store():
    st = populate(make_store(suspect_timeout_us=1e15), 600)
    rng = np.random.default_rng(6)
    pages = rng.integers(0, 600, size=6000, dtype=np.int64)
    inj = FaultInjector(st, standard_schedule(6000))
    _drive_with_injector(st, inj, pages, np.zeros(6000, bool))
    assert inj.done
    crash_results = [r for _, k, _, r in inj.log if k == "crash"]
    rec, lost = crash_results[0]
    assert rec > 0 and lost == 0           # replica-covered single crash
    InvariantChecker(st).check_replication_restored()


def test_mid_epoch_faults_async():
    """Fault events landing mid-epoch (chunks of 100 vs epoch_len 64) keep
    every invariant, and recovery completes before the trace ends."""
    st = populate(make_store(async_mode=True, suspect_timeout_us=1e15), 600)
    rng = np.random.default_rng(7)
    pages = rng.integers(0, 600, size=6000, dtype=np.int64)
    is_write = rng.random(6000) < 0.4
    inj = FaultInjector(st, standard_schedule(6000))
    chk = _drive_with_injector(st, inj, pages, is_write, chunk=100,
                               check_every=5)
    assert chk.n_checks > 2 and inj.done
    chk.check_replication_restored()
    # no page may have silently vanished: replication=1 and only the
    # correlated two-peer crash can lose pages (primary+replica in the pair)
    from repro.core.page_table import Tier
    gone = sum(st.gpt.lookup(p).tier is Tier.NONE for p in range(600))
    crash_lost = sum(r[1] for _, k, _, r in inj.log if k == "crash")
    assert gone <= crash_lost


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("async_mode", [False, True])
def test_randomized_fault_fuzz_keeps_invariants(seed, async_mode):
    """Seeded random fault schedules (redundant/no-op events included)
    against zipf traces in both orchestration modes: every invariant holds
    at every checkpoint, including mid-epoch DOWN transitions while staged
    flushes are in flight."""
    st = populate(make_store(async_mode=async_mode,
                             suspect_timeout_us=2_000.0, seed=seed), 500)
    rng = np.random.default_rng(100 + seed)
    pages = (np.clip(rng.zipf(1.3, 5000), 1, 500) - 1).astype(np.int64)
    is_write = rng.random(5000) < 0.4
    inj = FaultInjector(st, random_schedule(5000, 6, seed=seed,
                                            n_events=10))
    chk = _drive_with_injector(st, inj, pages, is_write, chunk=100,
                               check_every=4)
    assert chk.n_checks > 3
    assert len(inj.log) == 10              # every event fired (maybe no-op)


def test_async_daemon_drains_repairs():
    st = populate(make_store(async_mode=True), 800)
    st.fail_peer(1)
    assert st.repairq
    before = st.stats.daemon_us
    for _ in range(400):
        if not st.repairq:
            break
        st.background_tick()
    assert not st.repairq
    assert st.stats.daemon_us > before     # repairs billed to the daemon
    InvariantChecker(st).check_replication_restored()


# -- rejoin warm-up ramp (cluster-scale PR) -----------------------------------

def test_rejoin_ramp_phases_capacity_back_in():
    """A rejoined peer re-enters placement at a discounted advertised-free
    weight that ramps linearly to full over its first
    ``rejoin_ramp_grants`` block grants (never below 1 while room exists,
    so the peer stays placeable and can actually warm up)."""
    st = populate(make_store(rejoin_ramp_grants=4), 600)
    st.fail_peer(1)
    assert int(st._ramp_left[1]) == 0 and not st._any_ramp
    assert st.rejoin_peer(1)
    assert st._any_ramp and int(st._ramp_left[1]) == 4
    # linear schedule pinned exactly: 0/4, 1/4, 2/4, 3/4 of true free
    assert st._ramp_free(1, 100) == 1       # floor of one, never zero
    st._ramp_note_grant(1)
    assert st._ramp_free(1, 100) == 25
    st._ramp_note_grant(1)
    assert st._ramp_free(1, 100) == 50
    st._ramp_note_grant(1)
    assert st._ramp_free(1, 100) == 75
    st._ramp_note_grant(1)                  # k-th grant: ramp exhausted
    assert not st._any_ramp
    assert st._ramp_free(1, 100) == 100
    # peers that never crashed are never dampened, even mid-ramp
    assert st._ramp_free(0, 100) == 100


def test_rejoin_ramp_drains_through_repair_grants():
    """The ramp is consumed by real placement traffic: draining the
    post-rejoin repair backlog lands block grants on the warming-up peer
    and walks the ramp to zero without any direct ramp calls."""
    # two peers: after the crash every repair's only legal replica target
    # is the rejoined peer itself, so the drain must grant through the ramp
    st = populate(make_store(n_peers=2, rejoin_ramp_grants=2), 600)
    st.fail_peer(1)
    assert st.repairq                       # crash degraded some blocks
    st.rejoin_peer(1)
    assert st._any_ramp
    st.repair_quiesce()
    assert not st.repairq
    assert not st._any_ramp and int(st._ramp_left[1]) == 0
    InvariantChecker(st).check_replication_restored()


def test_rejoin_ramp_disabled_and_cancelled_by_crash():
    """``rejoin_ramp_grants=0`` turns the feature off entirely, and a
    crash mid-warm-up zeroes the ramp (the peer starts over on its next
    rejoin)."""
    st = populate(make_store(rejoin_ramp_grants=0), 400)
    st.fail_peer(1)
    st.rejoin_peer(1)
    assert not st._any_ramp                 # disabled: no discount at all
    assert st._ramp_free(1, 100) == 100
    st2 = populate(make_store(rejoin_ramp_grants=8), 400)
    st2.fail_peer(1)
    st2.rejoin_peer(1)
    assert st2._any_ramp
    st2.fail_peer(1)                        # REJOINING -> DOWN mid-ramp
    assert int(st2._ramp_left[1]) == 0 and not st2._any_ramp


# -- failure-domain schedule builders (cluster-scale PR) ----------------------

def test_domain_builders_deterministic_and_domain_scoped():
    """The rack-scale builders target exactly the peers of one failure
    domain and are pure functions of their inputs."""
    from repro.core import (peers_in_domain, domain_correlated_crash,
                            domain_recovery_storm, cluster_schedule)
    domains = [0, 0, 1, 1, 1, 2]
    assert peers_in_domain(domains, 1) == (2, 3, 4)
    assert peers_in_domain(domains, 2) == (5,)
    crash = domain_correlated_crash(domains, 1, at_op=40)
    assert [(e.at_op, e.kind, e.peers) for e in crash] == \
        [(40, "crash", (2, 3, 4))]
    storm = domain_recovery_storm(domains, 1, at_op=70)
    assert [(e.at_op, e.kind, e.peers) for e in storm] == \
        [(70, "rejoin", (2, 3, 4))]
    # empty domains are a caller bug, not a silent no-op schedule
    with pytest.raises(AssertionError):
        domain_correlated_crash(domains, 7, at_op=0)
    with pytest.raises(AssertionError):
        domain_recovery_storm(domains, 7, at_op=0)
    # canonical churn schedule: crash at 2n/5, rack rejoin at 7n/10, far
    # rack by default, and identical inputs -> identical schedule
    sched = cluster_schedule(10_000, domains)
    assert sched == cluster_schedule(10_000, domains)
    assert [(e.at_op, e.kind, e.peers) for e in sched] == \
        [(4000, "crash", (5,)), (7000, "rejoin", (5,))]
    near = cluster_schedule(10_000, domains, crash_domain=0)
    assert [(e.at_op, e.kind, e.peers) for e in near] == \
        [(4000, "crash", (0, 1)), (7000, "rejoin", (0, 1))]


def test_cluster_schedule_converges_on_every_surviving_host():
    """Injector-driven rack churn against two federated hosts: after the
    crash + rack-wide recovery storm drain out, replication is restored on
    every host's store and the cluster-level invariants hold."""
    from repro.core import (ClusterCoordinator, ClusterInvariantChecker,
                            cluster_schedule, draw_peer_profiles)
    profs = draw_peer_profiles(6, 2, seed=3)
    domains = [p.domain for p in profs]
    cluster = ClusterCoordinator(4096, storm_window=8)
    stores, injs = {}, []
    for hid in range(2):
        coord = cluster.register_host(min_slab=96, max_slab=1024)
        st = populate(make_store(pool=96, min_pool=48, seed=20 + hid,
                                 coordinator=coord, peer_profiles=profs,
                                 container_name=f"h{hid}"), 500)
        stores[hid] = [st]
        injs.append(FaultInjector(st, cluster_schedule(4000, domains)))
    rng = np.random.default_rng(21)
    pages = rng.integers(0, 500, size=4000, dtype=np.int64)
    is_write = rng.random(4000) < 0.3
    for hid, st in ((h, s[0]) for h, s in stores.items()):
        _drive_with_injector(st, injs[hid], pages, is_write)
    assert all(i.done for i in injs)
    # the rack crash was replica-covered on both hosts
    assert sum(r[1] for i in injs for _, k, _, r in i.log
               if k == "crash") == 0
    chk = ClusterInvariantChecker(cluster, stores)
    chk.check_recovery_converged()
    for st in (s[0] for s in stores.values()):
        InvariantChecker(st).check_replication_restored()
