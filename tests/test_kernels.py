"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_scan


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 4, 4, 32),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 256, 4, 1, 128),     # MQA
    (2, 128, 2, 2, 96),      # odd head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(b, s, hq, hkv, d, dtype, causal, window):
    q = rand(0, (b, s, hq, d), dtype)
    k = rand(1, (b, s, hkv, d), dtype)
    v = rand(2, (b, s, hkv, d), dtype)
    qk = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    out = flash_attention(qk, kk, vk, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    out = out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    ref = ref_lib.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,hq,hkv,d,page,npages", [
    (2, 4, 2, 64, 16, 4),
    (3, 8, 8, 32, 8, 6),
    (1, 8, 1, 128, 32, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(b, hq, hkv, d, page, npages, dtype):
    n_slots = b * npages + 4
    q = rand(0, (b, hq, d), dtype)
    kp = rand(1, (n_slots, page, hkv, d), dtype)
    vp = rand(2, (n_slots, page, hkv, d), dtype)
    rng = np.random.default_rng(0)
    bt = np.full((b, npages), -1, np.int32)
    lens = rng.integers(1, npages * page, size=b).astype(np.int32)
    for i in range(b):
        used = int(np.ceil((lens[i] + 1) / page))
        bt[i, :used] = rng.choice(n_slots, used, replace=False)
    out = paged_attention(q, kp, vp, jnp.array(bt), jnp.array(lens),
                          interpret=True)
    ref = ref_lib.paged_attention_ref(q, kp, vp, jnp.array(bt),
                                      jnp.array(lens))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 8, 1, 16, 16),
    (2, 64, 4, 16, 2, 8, 32),
    (1, 128, 8, 8, 2, 4, 16),
])
def test_ssd_scan_sweep(b, s, h, p, g, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    y, hT = ssd_scan(x, dt, A, Bm, Cm, chunk, interpret=True)
    yr, hr = ref_lib.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                               atol=3e-4, rtol=3e-4)


def test_paged_attention_skips_invalid_pages():
    """-1 block-table entries contribute nothing (Valet GPT miss -> pad)."""
    b, hq, hkv, d, page = 1, 2, 1, 16, 8
    kp = rand(1, (8, page, hkv, d), jnp.float32)
    vp = rand(2, (8, page, hkv, d), jnp.float32)
    q = rand(0, (b, hq, d), jnp.float32)
    bt_full = jnp.array([[0, 1, -1, -1]], jnp.int32)
    bt_short = jnp.array([[0, 1]], jnp.int32)
    lens = jnp.array([2 * page - 1], jnp.int32)
    a = paged_attention(q, kp, vp, bt_full, lens, interpret=True)
    b_ = paged_attention(q, kp, vp, bt_short, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


def test_local_write_batch_round_trip():
    """Bulk page scatter == sequential per-page appends for distinct slots
    (the data-plane half of a batched access_batch alloc run)."""
    import jax.numpy as jnp
    from repro.core import device_ops as dev
    n_slots, page, n_kv, hd = 8, 4, 2, 16
    pool = dev.make_kv_pool(n_slots, page, n_kv, hd, jnp.float32)
    k = rand(3, (3, page, n_kv, hd), jnp.float32)
    v = rand(4, (3, page, n_kv, hd), jnp.float32)
    slots = jnp.array([5, 1, 6], jnp.int32)
    out = dev.local_write_batch(pool, k, v, slots)
    ref = pool
    for i in range(3):
        ref = dev.insert_blocks(ref, k[i:i + 1], v[i:i + 1], slots[i:i + 1])
    np.testing.assert_array_equal(np.asarray(out.k), np.asarray(ref.k))
    np.testing.assert_array_equal(np.asarray(out.v), np.asarray(ref.v))
    # untouched slots stay zero
    assert float(jnp.abs(out.k[0]).sum()) == 0.0
