"""Model-layer math: attention, SSD, MoE, RoPE (oracle comparisons +
hypothesis properties)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis is a soft dependency (requirements.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import MoEConfig  # noqa: E402
from repro.models.attention import (blockwise_attention, reference_attention,  # noqa: E402
                                    decode_partial, combine_partials)
from repro.models.layers import apply_rope, rms_norm, KeyGen  # noqa: E402
from repro.models.moe import init_moe, moe_ffn, moe_ffn_reference  # noqa: E402
from repro.models.ssm import ssd_chunked  # noqa: E402


def test_blockwise_matches_reference_all_modes():
    key = jax.random.PRNGKey(1)
    B, S, Hq, Hkv, D = 2, 96, 4, 2, 16       # S not a block multiple
    q = jax.random.normal(key, (B, S, Hq, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, Hkv, D))
    for causal, window in [(True, 0), (True, 24), (False, 0)]:
        ref = reference_attention(q, k, v, causal=causal, window=window)
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_block=32, kv_block=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)


def test_blockwise_cross_attention_ragged_kv():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 77, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 77, 2, 16))
    ref = reference_attention(q, k, v, causal=False)
    out = blockwise_attention(q, k, v, causal=False, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 4))
def test_decode_partial_combine_is_exact(b, n_shards, hkv):
    """Flash-decoding property: sharded partial+combine == full attention."""
    t = 8 * n_shards
    hq = hkv * 2
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, hkv, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, hkv, 16))
    parts = [decode_partial(q, k[:, i*8:(i+1)*8], v[:, i*8:(i+1)*8],
                            jnp.ones((b, 8), bool))
             for i in range(n_shards)]
    m = jnp.stack([p[0] for p in parts])
    l = jnp.stack([p[1] for p in parts])
    a = jnp.stack([p[2] for p in parts])
    out = combine_partials((m, l, a), jnp.float32)
    ref = reference_attention(q[:, None], k, v, causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on (m - n)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def score(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 10_000.0)
        kn = apply_rope(k, jnp.array([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))
    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(5, 3) - score(7, 3)) > 1e-4   # sanity: not constant


def test_moe_matches_oracle_high_capacity():
    moe = MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=16,
                    capacity_factor=8.0)
    kg = KeyGen(jax.random.PRNGKey(0))
    params = init_moe(kg, 32, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    ref, aux_r = moe_ffn_reference(params, x.reshape(-1, 32), moe)
    out, aux = moe_ffn(params, x, moe)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                               np.asarray(ref), atol=1e-5)
    assert abs(float(aux - aux_r)) < 1e-6


def test_moe_renorm_topk_gates():
    moe = MoEConfig(n_experts=4, top_k=2, d_expert=8, renorm_topk=True,
                    capacity_factor=8.0)
    kg = KeyGen(jax.random.PRNGKey(0))
    params = init_moe(kg, 16, moe)
    from repro.models.moe import router_topk
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    _, gates, _ = router_topk(params, x, moe)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)


def test_ssd_chunk_invariance():
    """Property: chunk size never changes the SSD result."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, P, G, N = 1, 64, 2, 4, 1, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jnp.ones((H,))
    y8, h8 = ssd_chunked(x, dt, A, Bm, Cm, D, 8)
    y32, h32 = ssd_chunked(x, dt, A, Bm, Cm, D, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32), atol=1e-4)


def test_rms_norm_scale_invariance_direction():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    w = jnp.zeros((8,))
    a = rms_norm(w, x)
    b = rms_norm(w, 10.0 * x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
