"""Batched vs scalar reclaim/flush/migration parity (the PR-2 contract).

``batch_reclaim=True`` (the default) routes ``_flush`` placement, victim
selection + migration, and delete-style eviction through the vectorized
pipeline; ``batch_reclaim=False`` keeps the scalar reference.  Both must
reach bitwise-identical state: ``Stats`` (including ``evictions`` and
``migrations`` counters and the accumulated microseconds), per-op latencies,
pool/page-table/block state, and the activity-tracker timestamps.

Randomness comes from seeded numpy generators so the suite needs no extra
dependencies.
"""
import numpy as np
import pytest

from repro.core import (TieredPageStore, POLICIES, PAPER_COSTS,
                        ActivityTracker, select_victims_nad,
                        select_victims_topk)
from repro.core.migration import MigrationEngine, Phase
from repro.core.page_table import GlobalPageTable, Location, Tier

ALL_POLICIES = ("valet", "valet-mass", "infiniswap", "nbdx", "os-swap")


def make_store(policy, pool=128, *, batched, n_peers=4, blocks=64, seed=0,
               dynamic=False):
    return TieredPageStore(
        POLICIES[policy], PAPER_COSTS, pool_capacity=pool,
        min_pool=max(pool // 8, 8) if dynamic else pool, max_pool=pool,
        n_peers=n_peers, peer_capacity_blocks=blocks, pages_per_block=16,
        seed=seed, batch_reclaim=batched)


def random_trace(rng, n_pages, n_ops, write_frac=0.4):
    pages = np.clip(rng.zipf(1.3, n_ops), 1, n_pages) - 1
    return pages.astype(np.int64), rng.random(n_ops) < write_frac


def drive(store, pages, is_write, tick_every=32, events=None):
    """Scalar op loop with background ticks + injected pressure events —
    both stores see the identical op/tick/event sequence."""
    lats = []
    for i in range(len(pages)):
        if is_write[i]:
            lats.append(store.write(int(pages[i])))
        else:
            lats.append(store.read(int(pages[i])))
        if i % tick_every == 0:
            store.background_tick()
        if events and i in events:
            events[i](store)
    return np.asarray(lats)


def assert_full_parity(a, b, la=None, lb=None):
    assert a.stats == b.stats, f"\nscalar : {a.stats}\nbatched: {b.stats}"
    if la is not None:
        assert np.array_equal(la, lb), "per-op latencies diverged"
    assert a.step == b.step
    assert a.pool.free_count() == b.pool.free_count()
    assert a.pool.n_alloc_from_pool == b.pool.n_alloc_from_pool
    assert a.pool.n_reclaimed == b.pool.n_reclaimed
    assert len(a.pipeline.staging) == len(b.pipeline.staging)
    assert len(a.pipeline.reclaimable) == len(b.pipeline.reclaimable)
    # block state: same MR blocks with the same page lists
    assert set(a.blocks.keys()) == set(b.blocks.keys())
    for k in a.blocks:
        assert a.blocks[k] == b.blocks[k], f"block {k} diverged"
    for pa, pb in zip(a.peers, b.peers):
        assert (pa.used, pa.connected, pa.mapped_blocks, pa.failed) == \
            (pb.used, pb.connected, pb.mapped_blocks, pb.failed)
    # page table: every page resolves identically
    n = max(len(a.gpt), len(b.gpt), 1)
    for pg in range(2 * n):
        assert a.gpt.lookup(pg) == b.gpt.lookup(pg), f"page {pg} diverged"
    # activity tags on all live blocks
    for k in a.blocks:
        bid = a._block_id(*k)
        assert a.tracker.last(bid) == b.tracker.last(bid)
    a.pipeline.check_invariants()
    b.pipeline.check_invariants()


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("pool", [32, 128])
def test_reclaim_parity_random_traces(policy, pool):
    """Randomized mixed traces with periodic peer pressure, a hard peer
    failure, and local pool pressure — scalar vs batched reclaim."""
    for seed in range(2):
        pages, is_write = random_trace(np.random.default_rng(seed), 500, 4000)
        events = {
            800: lambda s: s.peer_pressure(0, 4),
            1600: lambda s: s.peer_pressure(1, 8),
            2500: lambda s: s.fail_peer(2),
            3200: lambda s: s.local_pressure(64),
        }
        a = make_store(policy, pool, batched=False, seed=seed)
        b = make_store(policy, pool, batched=True, seed=seed)
        la = drive(a, pages, is_write, events=events)
        lb = drive(b, pages, is_write, events=events)
        assert_full_parity(a, b, la, lb)


def test_reclaim_parity_under_dynamic_pool():
    pages, is_write = random_trace(np.random.default_rng(9), 600, 5000)
    a = make_store("valet", 256, batched=False, dynamic=True)
    b = make_store("valet", 256, batched=True, dynamic=True)
    la = drive(a, pages, is_write)
    lb = drive(b, pages, is_write)
    assert_full_parity(a, b, la, lb)


def test_flush_parity_drain_and_stall():
    """Bulk ``_flush`` placement: lazy drain AND in-critical-path stalls
    (tiny pool forces synchronous flushes; write_stall_us must match)."""
    a = make_store("valet", 16, batched=False)
    b = make_store("valet", 16, batched=True)
    pages = np.arange(400, dtype=np.int64)
    la = np.array([a.write(int(p)) for p in pages])
    lb = np.array([b.write(int(p)) for p in pages])
    assert a.stats.write_stall_us > 0          # stalls actually happened
    assert_full_parity(a, b, la, lb)
    a.drain()
    b.drain()
    assert_full_parity(a, b)


def test_access_batch_rides_batched_reclaim():
    """The access_batch driver with batch_reclaim on vs the scalar-everything
    reference: full pipeline (critical path + flush + pressure) parity."""
    for policy in ("valet", "infiniswap"):
        pages, is_write = random_trace(np.random.default_rng(4), 500, 4000)
        events = {1000: lambda s: s.peer_pressure(0, 6),
                  3000: lambda s: s.peer_pressure(1, 6)}
        a = make_store(policy, 64, batched=False, seed=1)
        b = make_store(policy, 64, batched=True, seed=1)
        la = drive(a, pages, is_write, events=events)
        n = len(pages)
        lb = np.empty(n, np.float64)
        i = 0
        while i < n:
            nxt = i if i % 32 == 0 else (i // 32 + 1) * 32
            nxt_ev = min([e for e in events if e >= i], default=n)
            end = min(n, i + 256, nxt + 1, nxt_ev + 1)
            lb[i:end] = b.access_batch(pages[i:end], is_write[i:end])
            if (end - 1) % 32 == 0:
                b.background_tick()
            if (end - 1) in events:
                events[end - 1](b)
            i = end
        assert_full_parity(a, b, la, lb)


def test_migrate_batch_matches_scalar_loop():
    """Direct migration parity: identical victims (order included), rng
    stream, page repoints, and Stats.migrations under repeated pressure."""
    def populated(batched):
        s = make_store("valet", 256, batched=batched, n_peers=6, blocks=128)
        for p in range(1500):
            s.write(p)
            if p % 32 == 0:
                s.background_tick()
        s.drain()
        return s

    a, b = populated(False), populated(True)
    for peer in (0, 1, 0, 2):
        fa = a.peer_pressure(peer, 8)
        fb = b.peer_pressure(peer, 8)
        assert fa == fb
    assert a.stats.migrations == b.stats.migrations > 0
    assert [m.block for m in a.migrator.completed] == \
        [m.block for m in b.migrator.completed]
    assert [m.dst_peer for m in a.migrator.completed] == \
        [m.dst_peer for m in b.migrator.completed]
    assert_full_parity(a, b)


def test_delete_eviction_batched_parity():
    """Infiniswap/nbdX delete-style eviction: bulk scatter vs per-page."""
    for policy in ("infiniswap", "nbdx"):
        def populated(batched):
            s = make_store(policy, 64, batched=batched, n_peers=4, blocks=32)
            for p in range(900):
                s.write(p)
            return s
        a, b = populated(False), populated(True)
        for peer in (0, 1, 0):
            assert a.peer_pressure(peer, 6) == b.peer_pressure(peer, 6)
        assert a.stats.evictions == b.stats.evictions > 0
        assert_full_parity(a, b)


def test_topk_matches_nad_selection():
    """Dense top-k must equal the stable-argsort reference, ties included."""
    rng = np.random.default_rng(0)
    t = ActivityTracker()
    blocks = list(range(300))
    # heavy ties: timestamps drawn from a tiny range
    t.on_write_at(blocks, rng.integers(0, 8, size=300))
    for n in (0, 1, 7, 64, 299, 300, 500):
        assert select_victims_topk(t, blocks, n, step=100) == \
            select_victims_nad(t, blocks, n, step=100), f"n={n}"
    # and on a permuted candidate order
    perm = rng.permutation(blocks).tolist()
    assert select_victims_topk(t, perm, 50, step=100) == \
        select_victims_nad(t, perm, 50, step=100)


class _ScriptedRng:
    """Deterministic stand-in: returns scripted ``integers`` draws."""

    def __init__(self, vals):
        self.vals = list(vals)

    def integers(self, *a, **k):
        return self.vals.pop(0)


def test_destination_fallback_scans_all_peers():
    """When p2c samples two pressured peers, the engine must fall back to a
    full scan (freest peer) instead of aborting into eviction."""
    gpt = GlobalPageTable()
    gpt.map_remote(7, Location(Tier.PEER, peer=3, slot=0))
    allocs = []
    eng = MigrationEngine(
        gpt, ActivityTracker(),
        free_counts_fn=lambda: [0, 0, 5, 0],      # only peer 2 has room
        copy_fn=lambda *a: None,
        alloc_fn=lambda p: (allocs.append(p), 0)[1],
        free_fn=lambda p, b: None,
        park_fn=lambda pages, hold: None,
        rng=_ScriptedRng([0, 0]))                 # p2c pair -> (0, 1), both full
    mig = eng.migrate_block(3, block=123, pages=[7])
    assert mig.phase == Phase.DONE
    assert mig.dst_peer == 2
    assert allocs == [2]
    assert gpt.remote_location(7).peer == 2


def test_destination_fallback_aborts_when_truly_full():
    eng = MigrationEngine(
        GlobalPageTable(), ActivityTracker(),
        free_counts_fn=lambda: [0, 0, 0, 4],      # only the SOURCE has room
        copy_fn=lambda *a: None, alloc_fn=lambda p: 0,
        free_fn=lambda p, b: None, park_fn=lambda pages, hold: None,
        rng=_ScriptedRng([0, 0]))
    mig = eng.migrate_block(3, block=1, pages=[9])
    assert mig.phase == Phase.ABORTED
    assert mig.log[-1].kind == "NO_DESTINATION"


def test_pair_sampler_draw_batch_matches_sequential():
    from repro.core.activity import PairSampler
    s1 = PairSampler(6, np.random.default_rng(3), buf=64)
    s2 = PairSampler(6, np.random.default_rng(3), buf=64)
    seq = [s1.draw() for _ in range(200)]          # crosses refill boundaries
    a, b = s2.draw_batch(150)
    rest = [s2.draw() for _ in range(50)]
    got = list(zip(a.tolist(), b.tolist())) + rest
    assert seq == got


# -- dense-state engine: deep state equality (SoA vs scalar reference) ---------


def assert_deep_soa_state(a, b):
    """Beyond Stats: the whole orchestration state layer — pool metadata
    columns, free-stack order (it fixes future allocation order), staging
    occupancy (rows, seqs, §5.2 pending/deferred maps) and the reclaimable
    queue's content — must be identical between the dense and scalar
    modes."""
    pa, pb = a.pool, b.pool
    assert np.array_equal(pa.state, pb.state)
    assert np.array_equal(pa.owner, pb.owner)
    assert np.array_equal(pa.update_flag, pb.update_flag)
    assert np.array_equal(pa.reclaim_flag, pb.reclaim_flag)
    assert pa._free == pb._free, "free-stack order diverged"
    sa = [(ws.seq, ws.pages, ws.slots, ws.migrating_hold)
          for ws in a.pipeline.staging.entries()]
    sb = [(ws.seq, ws.pages, ws.slots, ws.migrating_hold)
          for ws in b.pipeline.staging.entries()]
    assert sa == sb, "staging occupancy diverged"
    ra = [(ws.pages, ws.slots) for ws in a.pipeline.reclaimable.entries()]
    rb = [(ws.pages, ws.slots) for ws in b.pipeline.reclaimable.entries()]
    assert ra == rb, "reclaimable queue content diverged"
    assert a.pipeline._pending_slot == b.pipeline._pending_slot
    assert a.pipeline._n_deferred == b.pipeline._n_deferred
    assert a.blocks == b.blocks, "block table diverged"
    assert a.block_replicas == b.block_replicas
    assert a._replica_of == b._replica_of
    for p in range(len(a.peers)):
        hi = max(a._next_block_slot[p], b._next_block_slot[p])
        assert np.array_equal(a._blk_live[p][:hi], b._blk_live[p][:hi])
        assert np.array_equal(a._blk_replica[p][:hi],
                              b._blk_replica[p][:hi])


def test_property_deep_state_parity_dense_vs_scalar():
    """Hypothesis property: over randomized traces with interleaved
    reclaim / flush / migration / eviction pressure and peer failures, the
    dense (batch_reclaim=True, access_batch) engine reaches deep state
    equality with the scalar reference — free-stack order, staging rows,
    reclaimable content, §5.2 maps, block tables (hypothesis is a soft
    dependency, as in test_core_pool)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000),
           pool=st.sampled_from([24, 48, 96]),
           write_frac=st.floats(0.2, 0.8),
           policy=st.sampled_from(["valet", "infiniswap"]))
    def prop(seed, pool, write_frac, policy):
        rng = np.random.default_rng(seed)
        pages, is_write = random_trace(rng, 300, 1500, write_frac)
        spec = [(int(e), int(k), int(p), int(nblk))
                for e, k, p, nblk in zip(
                    rng.choice(1500, size=4, replace=False),
                    rng.integers(0, 3, size=4),
                    rng.integers(0, 4, size=4),
                    rng.integers(1, 6, size=4))]

        def mk(k, p, nblk):
            if k == 0:
                return lambda s: s.peer_pressure(p, nblk)
            if k == 1:
                return lambda s: s.local_pressure(nblk * 8)
            return lambda s: s.fail_peer(p)

        events = {e: mk(k, p, nblk) for e, k, p, nblk in spec}
        a = make_store(policy, pool, batched=False, seed=seed)
        b = make_store(policy, pool, batched=True, seed=seed)
        la = drive(a, pages, is_write, events=events)
        n = len(pages)
        lb = np.empty(n, np.float64)
        i = 0
        while i < n:
            nxt = i if i % 32 == 0 else (i // 32 + 1) * 32
            nxt_ev = min([e for e in events if e >= i], default=n)
            end = min(n, i + 256, nxt + 1, nxt_ev + 1)
            lb[i:end] = b.access_batch(pages[i:end], is_write[i:end])
            if (end - 1) % 32 == 0:
                b.background_tick()
            if (end - 1) in events:
                events[end - 1](b)
            i = end
        assert_full_parity(a, b, la, lb)
        assert_deep_soa_state(a, b)

    prop()
