"""LatencyReservoir p999: the stride-doubling systematic sample is pinned
exactly — the SLO-grade workload suite gates on these percentiles, so the
sampling semantics must not drift."""
import numpy as np

from repro.core.reservoir import LatencyReservoir
from repro.core.tiering import Stats
from repro.serve.engine import EngineStats


def test_p999_exact_below_cap():
    """Under the cap, no decimation: p999 is np.percentile of the stream."""
    res = LatencyReservoir(cap=1 << 16)
    xs = np.arange(10_000, dtype=np.float64)
    res.record_many(xs)
    assert res.p999() == float(np.percentile(xs, 99.9))
    assert res.p999() >= res.p99() >= res.p50()


def test_p999_pinned_under_stride_doubling():
    """Past the cap, the retained set is exactly every ``stride``-th
    observation of the stream (the documented systematic sample), so p999
    is np.percentile over that deterministic subsample — pinned here so
    the decimation scheme cannot silently change."""
    cap = 1024
    res = LatencyReservoir(cap=cap)
    xs = np.arange(5000, dtype=np.float64)
    res.record_many(xs)
    stride = res._stride
    assert stride > 1, "test must exercise the decimated regime"
    expected = xs[::stride]
    retained = res._buf[:len(res)]
    np.testing.assert_array_equal(retained, expected)
    assert res.p999() == float(np.percentile(expected, 99.9))
    # and the whole path is deterministic: a second identical stream gives
    # bitwise-identical percentiles
    res2 = LatencyReservoir(cap=cap)
    res2.record_many(xs)
    assert res2.p999() == res.p999()


def test_p999_chunked_feed_matches_single_feed():
    """Chunk boundaries must not change the systematic sample."""
    xs = np.arange(5000, dtype=np.float64)
    one = LatencyReservoir(cap=1024)
    one.record_many(xs)
    many = LatencyReservoir(cap=1024)
    for i in range(0, len(xs), 257):
        many.record_many(xs[i:i + 257])
    assert many.p999() == one.p999()
    assert many.p99() == one.p99()


def test_summary_and_stats_wiring():
    """summary() exposes p999_us; Stats/EngineStats expose latency_p999."""
    res = LatencyReservoir()
    assert res.summary()["p999_us"] == 0.0       # empty
    res.record_many(np.arange(2000, dtype=np.float64))
    s = res.summary()
    assert s["p999_us"] == res.p999()
    assert s["p99_us"] <= s["p999_us"] <= s["max_us"]

    for stats in (Stats(), EngineStats()):
        stats.lat.record_many(np.arange(2000, dtype=np.float64))
        assert stats.latency_p999() == stats.lat.p999()
        assert stats.latency_p999() >= stats.latency_p99()


def test_latency_summary_helper_includes_p999():
    from benchmarks.common import latency_summary
    st = Stats()
    st.lat.record_many(np.arange(4000, dtype=np.float64))
    out = latency_summary(st)
    assert out["p999_us"] == st.latency_p999()
    assert out["p50_us"] == st.latency_p50()
